"""Data pipeline determinism + checkpoint roundtrip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.data import make_feature_shards, synthetic_lm_batch, synthetic_lm_batches


def test_lm_batch_deterministic():
    b1 = synthetic_lm_batch(jax.random.key(7), 4, 32, 100)
    b2 = synthetic_lm_batch(jax.random.key(7), 4, 32, 100)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_lm_batch_has_structure():
    """tok_{t+1} = (7·tok_t + 1) mod V for ~90% of steps — learnable."""
    b = synthetic_lm_batch(jax.random.key(0), 8, 128, 97)
    toks = np.asarray(b["tokens"])
    pred = (7 * toks[:, :-1] + 1) % 97
    frac = np.mean(pred == toks[:, 1:])
    assert frac > 0.8


def test_labels_are_shifted_tokens():
    b = synthetic_lm_batch(jax.random.key(1), 2, 16, 50)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_stream_shards_disjoint():
    it0 = synthetic_lm_batches(0, 8, 16, 100, shard_index=0, num_shards=2)
    it1 = synthetic_lm_batches(0, 8, 16, 100, shard_index=1, num_shards=2)
    b0, b1 = next(it0), next(it1)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_feature_shards_heterogeneity():
    Xs0, _, _ = make_feature_shards(0, 4, 50, 3, heterogeneity=0.0)
    Xsh, _, _ = make_feature_shards(0, 4, 50, 3, heterogeneity=3.0)
    means0 = np.asarray(Xs0).mean(axis=1)
    meansh = np.asarray(Xsh).mean(axis=1)
    assert np.std(meansh) > np.std(means0)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3)},
        "step": jnp.asarray(7),
    }
    save(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    out = restore(str(tmp_path), 7, tree)
    np.testing.assert_array_equal(out["params"]["w"], tree["params"]["w"])
    np.testing.assert_array_equal(out["step"], tree["step"])


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save(str(tmp_path), 1, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore(str(tmp_path), 1, {"w": jnp.ones((3, 3))})


def test_latest_step_empty(tmp_path):
    assert latest_step(str(tmp_path / "nope")) is None
