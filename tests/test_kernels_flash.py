"""Flash-attention Pallas kernel vs pure-jnp oracle (shape/dtype sweep)."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import ops, ref

SHAPES = [
    # (B, T, S, Hq, Hkv, D, causal, window)
    (2, 64, 64, 4, 2, 32, True, 0),
    (1, 128, 128, 8, 8, 64, True, 0),
    (2, 96, 96, 4, 1, 16, True, 0),  # padding (96 % 32 != 0 with bq=64)
    (2, 64, 64, 8, 2, 32, True, 24),  # sliding window
    (1, 48, 48, 4, 4, 64, False, 0),  # bidirectional
]


def _mk(key, B, T, S, Hq, Hkv, D, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, Hq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D)).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("case", SHAPES)
def test_flash_matches_ref_f32(case):
    B, T, S, Hq, Hkv, D, causal, window = case
    q, k, v = _mk(jax.random.key(sum(case[:6])), B, T, S, Hq, Hkv, D, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window, bq=32, bk=32)
    exp = ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal, window=window,
    ).transpose(0, 2, 1, 3)
    assert float(jnp.max(jnp.abs(out - exp))) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_dtypes(dtype):
    q, k, v = _mk(jax.random.key(9), 2, 64, 64, 4, 2, 32, dtype)
    out = ops.flash_attention(q, k, v, bq=32, bk=32)
    exp = ref.attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    ).transpose(0, 2, 1, 3)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    assert out.dtype == dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - exp.astype(jnp.float32)))) < tol


def test_block_shape_independence():
    """Result must not depend on the BlockSpec tile size."""
    q, k, v = _mk(jax.random.key(3), 1, 128, 128, 4, 4, 32, jnp.float32)
    o1 = ops.flash_attention(q, k, v, bq=32, bk=32)
    o2 = ops.flash_attention(q, k, v, bq=64, bk=128)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 2e-5


def test_matches_model_attention_path():
    """Kernel should agree with the model's _sdpa reference semantics."""
    from repro.models.attention import _sdpa, causal_mask

    q, k, v = _mk(jax.random.key(4), 2, 64, 64, 4, 2, 32, jnp.float32)
    out_kernel = ops.flash_attention(q, k, v, bq=32, bk=32)
    mask = causal_mask(64, 64)
    out_model = _sdpa(q, k, v, mask, scale=32 ** -0.5)
    assert float(jnp.max(jnp.abs(out_kernel - out_model))) < 2e-5
