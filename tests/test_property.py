"""Hypothesis property-based tests on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment"
)
from hypothesis import given, settings, strategies as st

from repro.api.wire import make_wire
from repro.core import schedules, server
from repro.core.compression import topk_compress
from repro.ml.clustering import kmeans, pdist
from repro.telemetry.roofline import roofline
from repro.utils.tree import tree_axpy, tree_dot, tree_norm, tree_sub

SETTINGS = dict(max_examples=15, deadline=None)


# ----------------------------------------------------------------------------
# §5 protocol invariants
# ----------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    K=st.integers(2, 6),
    rounds=st.integers(1, 4),
    seed=st.integers(0, 100),
)
def test_protocol_is_exact_function_composition(K, rounds, seed):
    """For ANY per-node affine update, the sequential-handoff protocol equals
    plain function composition in schedule order (the §5 equivalence)."""
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(K, 3, 3)) * 0.2 + np.eye(3) * 0.5)
    b = jnp.asarray(rng.normal(size=(K, 3)))

    def F(k, theta):
        return A[k] @ theta + b[k]

    sched = schedules.round_robin(K, rounds)
    final, _ = server.run_protocol(jnp.zeros(3), F, sched)
    theta = jnp.zeros(3)
    for t in range(len(sched)):
        theta = F(int(sched[t]), theta)
    np.testing.assert_allclose(final.theta, theta, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(K=st.integers(1, 8), n=st.integers(10, 200), seed=st.integers(0, 50))
def test_async_schedule_support(K, n, seed):
    sched = schedules.asynchronous(jax.random.key(seed), K, n)
    assert sched.shape == (n,)
    assert int(jnp.min(sched)) >= 0 and int(jnp.max(sched)) < K


# ----------------------------------------------------------------------------
# compression invariants
# ----------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(4, 200),
    frac=st.floats(0.05, 1.0),
    seed=st.integers(0, 100),
)
def test_topk_idempotent_and_contractive(n, frac, seed):
    x = jax.random.normal(jax.random.key(seed), (n,))
    c1 = topk_compress({"x": x}, frac).tree["x"]
    c2 = topk_compress({"x": c1}, frac).tree["x"]
    k = max(1, int(round(frac * n)))
    assert 1 <= int(jnp.sum(c1 != 0)) <= k
    np.testing.assert_allclose(c1, c2)  # idempotent
    assert float(jnp.linalg.norm(c1)) <= float(jnp.linalg.norm(x)) + 1e-6


# ----------------------------------------------------------------------------
# clustering invariants
# ----------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(12, 60),
    k=st.integers(1, 4),
    seed=st.integers(0, 100),
)
def test_kmeans_inertia_no_worse_than_init(n, k, seed):
    X = jax.random.normal(jax.random.key(seed), (n, 3))
    C0 = X[:k]
    res = kmeans(X, C0, num_clusters=k, iters=10)
    inertia0 = float(jnp.sum(jnp.min(pdist(X, C0, metric="l2sq"), axis=1)))
    assert float(res.inertia) <= inertia0 + 1e-4


@settings(**SETTINGS)
@given(seed=st.integers(0, 100), metric=st.sampled_from(["l1", "l2", "linf"]))
def test_pdist_metric_axioms(seed, metric):
    X = jax.random.normal(jax.random.key(seed), (10, 4))
    D = pdist(X, X, metric=metric)
    assert bool(jnp.all(D >= -1e-6))
    np.testing.assert_allclose(jnp.diag(D), 0.0, atol=1e-5)
    np.testing.assert_allclose(D, D.T, atol=1e-5)


# ----------------------------------------------------------------------------
# wire invariants — every codec family, arbitrary shapes and seeds
# ----------------------------------------------------------------------------

#: one spec per wire family (chains cover composition); parameters are
#: arbitrary-but-fixed — hypothesis varies the DATA, not the spec grid
WIRE_SPECS = [
    "dense", "topk:0.25", "topk:0.25+ef", "thresh:0.5", "thresh:0.5+ef",
    "int8", "int8+ef", "dp:1.0,0.5", "secagg", "dp:1.0,0.5>topk:0.25+ef",
    "topk:0.25+ef>secagg",
]


def _msgs(K, n, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(K, n)) * 2.0, jnp.float32)


@settings(**SETTINGS)
@given(
    spec=st.sampled_from(WIRE_SPECS),
    K=st.integers(2, 6),
    n=st.integers(4, 64),
    seed=st.integers(0, 1000),
)
def test_lossless_wires_roundtrip_bit_exact(spec, K, n, seed):
    """A wire claiming ``lossless`` must return the messages IDENTICALLY —
    the aggregate a transport computes from its output is then the exact
    aggregate of what the nodes sent (secagg's whole guarantee)."""
    wi = make_wire(spec)
    msgs = _msgs(K, n, seed)
    st_ = wi.init_state(msgs[0], K)
    _, hat, _ = wi.encode_updates(st_, msgs)
    if wi.lossless:
        np.testing.assert_array_equal(np.asarray(hat), np.asarray(msgs))
    elif not spec.startswith("thresh:"):
        # and a lossy wire must actually be lossy on generic data
        # (thresh exempted: small fleets can draw all entries above τ)
        assert not np.array_equal(np.asarray(hat), np.asarray(msgs))


@settings(**SETTINGS)
@given(
    spec=st.sampled_from(WIRE_SPECS),
    K=st.integers(2, 6),
    n=st.integers(4, 64),
    seed=st.integers(0, 1000),
)
def test_metered_bytes_equal_payload_size(spec, K, n, seed):
    """The traced byte scalar a wire reports equals the size of the
    payload that actually crosses the wire, for every family:

    * dense / dp / secagg — K dense messages (noise and masks never
      compress; secagg's masked payload is exactly message-sized);
    * topk — K · k·(4 + itemsize) (index + value per survivor);
    * thresh — (4 + itemsize) per entry that survived the threshold;
    * int8 — K · (n·1 + 4) (one byte per entry + the absmax scale);
    * chains — the LAST re-pricing stage's count.
    """
    wi = make_wire(spec)
    msgs = _msgs(K, n, seed)
    st_ = wi.init_state(msgs[0], K)
    _, hat, nb = wi.encode_updates(st_, msgs)
    nb = int(np.asarray(nb))
    # the effective pricing stage: secagg preserves the previous stage's
    # byte count, so drop it off the end of a chain before dispatching
    parts = [p for p in spec.split(">") if p != "secagg"] or ["secagg"]
    base = parts[-1]
    if base in ("dense", "secagg") or base.startswith("dp:"):
        assert nb == K * n * 4
        if spec == "secagg":
            # the masked payloads are message-shaped → same dense size
            pay = wi.uplink_payloads(st_, msgs)
            assert np.asarray(pay).nbytes == nb
    elif base.startswith("topk:"):
        k = max(1, int(round(0.25 * n)))
        assert nb == K * k * (4 + 4)
    elif base.startswith("thresh:"):
        kept = int(np.sum(np.abs(np.asarray(hat)) > 0))
        survivors = int(np.sum(np.abs(np.asarray(hat)) >= 0.5))
        assert nb == survivors * (4 + 4)
        assert kept <= survivors  # kept values all cleared the threshold
    elif base.startswith("int8"):
        assert nb == K * (n * 1 + 4)
    else:  # pragma: no cover - spec grid is closed
        raise AssertionError(base)


@settings(**SETTINGS)
@given(
    spec=st.sampled_from(["topk:0.25+ef", "thresh:0.5+ef", "int8+ef"]),
    K=st.integers(2, 6),
    n=st.integers(4, 64),
    seed=st.integers(0, 1000),
)
def test_error_feedback_conserves_mass(spec, K, n, seed):
    """EF-SGD's invariant: sent + residual == message + old residual,
    EXACTLY — whatever the codec drops lands in the residual, nothing is
    silently lost or double-counted across rounds."""
    wi = make_wire(spec)
    msgs = _msgs(K, n, seed)
    # sparsifiers conserve bitwise (residual = masked-out entries,
    # untouched); int8's dequantized values re-round in c − out
    exact = not spec.startswith("int8")
    check = (
        np.testing.assert_array_equal if exact
        else lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
    )
    res0 = wi.init_state(msgs[0], K)
    res1, hat, _ = wi.encode_updates(res0, msgs)
    check(np.asarray(hat) + np.asarray(res1),
          np.asarray(msgs) + np.asarray(res0))
    # and the residual keeps conserving on the NEXT round too
    res2, hat2, _ = wi.encode_updates(res1, msgs)
    check(np.asarray(hat2) + np.asarray(res2),
          np.asarray(msgs) + np.asarray(res1))


@settings(**SETTINGS)
@given(K=st.integers(2, 6), n=st.integers(4, 64), seed=st.integers(0, 1000))
def test_secagg_masks_cancel_in_the_sum(K, n, seed):
    """For ANY fleet size and message content: every per-node payload is
    masked away from its raw message, while the payload sum recovers the
    raw aggregate to fp tolerance (pairwise antisymmetry)."""
    wi = make_wire("secagg")
    msgs = _msgs(K, n, seed)
    st_ = wi.init_state(msgs[0], K)
    pay = np.asarray(wi.uplink_payloads(st_, msgs))
    raw = np.asarray(msgs)
    for k in range(K):
        assert not np.allclose(pay[k], raw[k], atol=1e-3)
    np.testing.assert_allclose(
        pay.sum(axis=0), raw.sum(axis=0), rtol=1e-3, atol=1e-3
    )


@settings(**SETTINGS)
@given(
    clip=st.floats(0.1, 5.0),
    K=st.integers(2, 6),
    n=st.integers(4, 64),
    seed=st.integers(0, 1000),
)
def test_dp_clip_bounds_every_node(clip, K, n, seed):
    """With σ=0 the privatized norm is min(‖m‖, clip) for every node —
    the clip is a hard per-node bound, never an average."""
    wi = make_wire(f"dp:{clip},0.0")
    msgs = _msgs(K, n, seed)
    _, hat, _ = wi.encode_updates(wi.init_state(msgs[0], K), msgs)
    want = np.minimum(np.linalg.norm(np.asarray(msgs), axis=1), clip)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(hat), axis=1), want, rtol=1e-4
    )


# ----------------------------------------------------------------------------
# tree algebra + roofline
# ----------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 100), alpha=st.floats(-2.0, 2.0))
def test_tree_axpy_dot_identities(seed, alpha):
    k1, k2 = jax.random.split(jax.random.key(seed))
    x = {"a": jax.random.normal(k1, (5,)), "b": jax.random.normal(k2, (2, 3))}
    y = jax.tree.map(lambda v: v * 2.0, x)
    z = tree_axpy(alpha, x, y)
    # <z, z> = a²<x,x> + 2a<x,y> + <y,y>
    lhs = float(tree_dot(z, z))
    rhs = (
        alpha ** 2 * float(tree_dot(x, x))
        + 2 * alpha * float(tree_dot(x, y))
        + float(tree_dot(y, y))
    )
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)
    assert float(tree_norm(tree_sub(x, x))) == 0.0


@settings(**SETTINGS)
@given(
    f=st.floats(1e6, 1e15),
    b=st.floats(1e3, 1e12),
    c=st.floats(0.0, 1e12),
)
def test_roofline_dominant_is_max(f, b, c):
    r = roofline(
        flops_per_device=f, bytes_per_device=b,
        collective_bytes_per_device=c, chips=256,
    )
    terms = {"compute": r.compute_s, "memory": r.memory_s, "collective": r.collective_s}
    assert r.dominant == max(terms, key=terms.get)
    assert all(v >= 0 for v in terms.values())
