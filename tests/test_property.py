"""Hypothesis property-based tests on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment"
)
from hypothesis import given, settings, strategies as st

from repro.core import schedules, server
from repro.core.compression import topk_compress
from repro.ml.clustering import kmeans, pdist
from repro.telemetry.roofline import roofline
from repro.utils.tree import tree_axpy, tree_dot, tree_norm, tree_sub

SETTINGS = dict(max_examples=15, deadline=None)


# ----------------------------------------------------------------------------
# §5 protocol invariants
# ----------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    K=st.integers(2, 6),
    rounds=st.integers(1, 4),
    seed=st.integers(0, 100),
)
def test_protocol_is_exact_function_composition(K, rounds, seed):
    """For ANY per-node affine update, the sequential-handoff protocol equals
    plain function composition in schedule order (the §5 equivalence)."""
    rng = np.random.default_rng(seed)
    A = jnp.asarray(rng.normal(size=(K, 3, 3)) * 0.2 + np.eye(3) * 0.5)
    b = jnp.asarray(rng.normal(size=(K, 3)))

    def F(k, theta):
        return A[k] @ theta + b[k]

    sched = schedules.round_robin(K, rounds)
    final, _ = server.run_protocol(jnp.zeros(3), F, sched)
    theta = jnp.zeros(3)
    for t in range(len(sched)):
        theta = F(int(sched[t]), theta)
    np.testing.assert_allclose(final.theta, theta, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(K=st.integers(1, 8), n=st.integers(10, 200), seed=st.integers(0, 50))
def test_async_schedule_support(K, n, seed):
    sched = schedules.asynchronous(jax.random.key(seed), K, n)
    assert sched.shape == (n,)
    assert int(jnp.min(sched)) >= 0 and int(jnp.max(sched)) < K


# ----------------------------------------------------------------------------
# compression invariants
# ----------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(4, 200),
    frac=st.floats(0.05, 1.0),
    seed=st.integers(0, 100),
)
def test_topk_idempotent_and_contractive(n, frac, seed):
    x = jax.random.normal(jax.random.key(seed), (n,))
    c1 = topk_compress({"x": x}, frac).tree["x"]
    c2 = topk_compress({"x": c1}, frac).tree["x"]
    k = max(1, int(round(frac * n)))
    assert 1 <= int(jnp.sum(c1 != 0)) <= k
    np.testing.assert_allclose(c1, c2)  # idempotent
    assert float(jnp.linalg.norm(c1)) <= float(jnp.linalg.norm(x)) + 1e-6


# ----------------------------------------------------------------------------
# clustering invariants
# ----------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(12, 60),
    k=st.integers(1, 4),
    seed=st.integers(0, 100),
)
def test_kmeans_inertia_no_worse_than_init(n, k, seed):
    X = jax.random.normal(jax.random.key(seed), (n, 3))
    C0 = X[:k]
    res = kmeans(X, C0, num_clusters=k, iters=10)
    inertia0 = float(jnp.sum(jnp.min(pdist(X, C0, metric="l2sq"), axis=1)))
    assert float(res.inertia) <= inertia0 + 1e-4


@settings(**SETTINGS)
@given(seed=st.integers(0, 100), metric=st.sampled_from(["l1", "l2", "linf"]))
def test_pdist_metric_axioms(seed, metric):
    X = jax.random.normal(jax.random.key(seed), (10, 4))
    D = pdist(X, X, metric=metric)
    assert bool(jnp.all(D >= -1e-6))
    np.testing.assert_allclose(jnp.diag(D), 0.0, atol=1e-5)
    np.testing.assert_allclose(D, D.T, atol=1e-5)


# ----------------------------------------------------------------------------
# tree algebra + roofline
# ----------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 100), alpha=st.floats(-2.0, 2.0))
def test_tree_axpy_dot_identities(seed, alpha):
    k1, k2 = jax.random.split(jax.random.key(seed))
    x = {"a": jax.random.normal(k1, (5,)), "b": jax.random.normal(k2, (2, 3))}
    y = jax.tree.map(lambda v: v * 2.0, x)
    z = tree_axpy(alpha, x, y)
    # <z, z> = a²<x,x> + 2a<x,y> + <y,y>
    lhs = float(tree_dot(z, z))
    rhs = (
        alpha ** 2 * float(tree_dot(x, x))
        + 2 * alpha * float(tree_dot(x, y))
        + float(tree_dot(y, y))
    )
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)
    assert float(tree_norm(tree_sub(x, x))) == 0.0


@settings(**SETTINGS)
@given(
    f=st.floats(1e6, 1e15),
    b=st.floats(1e3, 1e12),
    c=st.floats(0.0, 1e12),
)
def test_roofline_dominant_is_max(f, b, c):
    r = roofline(
        flops_per_device=f, bytes_per_device=b,
        collective_bytes_per_device=c, chips=256,
    )
    terms = {"compute": r.compute_s, "memory": r.memory_s, "collective": r.collective_s}
    assert r.dominant == max(terms, key=terms.get)
    assert all(v >= 0 for v in terms.values())
