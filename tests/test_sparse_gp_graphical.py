"""Sparse GP ([66]/[23], §3.3) and distributed MPLE ([38], §3.4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ml import gp, graphical


@pytest.fixture(scope="module")
def sine():
    rng = np.random.default_rng(5)
    N = 120
    X = jnp.asarray(np.sort(rng.uniform(-3, 3, size=(N, 1)), 0))
    y = jnp.asarray(np.sin(2 * np.asarray(X)[:, 0]) + 0.05 * rng.normal(size=N))
    Xq = jnp.asarray(np.linspace(-2.5, 2.5, 15)[:, None])
    hyp = gp.fit_hypers(X, y, steps=120)
    return X, y, Xq, hyp


def test_sgpr_stats_additive(sine):
    """The [23] decomposition: shard statistics sum to the full-data stats."""
    X, y, Xq, hyp = sine
    Z = jnp.asarray(np.linspace(-3, 3, 12)[:, None])
    full = gp.sgpr_local_stats(hyp, Z, X, y)
    parts = jax.vmap(
        lambda Xk, yk: gp.sgpr_local_stats(hyp, Z, Xk, yk)
    )(X.reshape(4, 30, 1), y.reshape(4, 30))
    agg = gp.sgpr_aggregate(parts)
    np.testing.assert_allclose(agg.A, full.A, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(agg.b, full.b, rtol=1e-4, atol=1e-4)
    assert float(agg.n) == float(full.n)


def test_distributed_sgpr_matches_centralized(sine):
    X, y, Xq, hyp = sine
    Z = jnp.asarray(np.linspace(-3, 3, 16)[:, None])
    stats = gp.sgpr_local_stats(hyp, Z, X, y)
    mu_c, var_c = gp.sgpr_posterior(hyp, Z, stats, Xq)
    mu_d, var_d, wire = gp.distributed_sgpr(
        hyp, Z, X.reshape(4, 30, 1), y.reshape(4, 30), Xq
    )
    np.testing.assert_allclose(mu_d, mu_c, atol=5e-2)
    # communication is O(M²), independent of N
    assert wire == (16 * 16 + 16 + 2) * 4


def test_sgpr_approaches_exact_gp(sine):
    X, y, Xq, hyp = sine
    Z = jnp.asarray(np.linspace(-3, 3, 16)[:, None])
    mu_e, _ = gp.gp_posterior(hyp, X, y, Xq)
    mu_s, var_s = gp.sgpr_posterior(
        hyp, Z, gp.sgpr_local_stats(hyp, Z, X, y), Xq
    )
    assert float(jnp.sqrt(jnp.mean((mu_s - mu_e) ** 2))) < 0.05
    assert bool(jnp.all(var_s > 0))


def test_sgpr_more_inducing_is_better(sine):
    X, y, Xq, hyp = sine
    mu_e, _ = gp.gp_posterior(hyp, X, y, Xq)

    def rmse(M):
        Z = jnp.asarray(np.linspace(-3, 3, M)[:, None])
        mu, _ = gp.sgpr_posterior(hyp, Z, gp.sgpr_local_stats(hyp, Z, X, y), Xq)
        return float(jnp.sqrt(jnp.mean((mu - mu_e) ** 2)))

    assert rmse(16) <= rmse(4) + 1e-6


# ----------------------------------------------------------------------------
# §3.4 Gaussian-MRF MPLE
# ----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chain_gmrf():
    d = 6
    Theta = jnp.eye(d) * 1.5
    for i in range(d - 1):
        Theta = Theta.at[i, i + 1].set(0.5).at[i + 1, i].set(0.5)
    X = graphical.sample_gmrf(jax.random.key(0), Theta, 2000)
    return Theta, X


def test_mple_recovers_chain_support(chain_gmrf):
    Theta, X = chain_gmrf
    Th = graphical.mple_centralized(X, iters=800)
    assert float(graphical.support_f1(Th, Theta)) > 0.95


def test_consensus_mple_matches_centralized(chain_gmrf):
    """[38]: the ADMM consensus MPLE agrees with the centralized solver."""
    Theta, X = chain_gmrf
    Th_c = graphical.mple_centralized(X, iters=800)
    Th_d, res = graphical.mple_consensus(
        X.reshape(4, 500, 6), iters=50, inner_iters=50
    )
    assert float(graphical.support_f1(Th_d, Theta)) > 0.95
    np.testing.assert_allclose(Th_d, Th_c, atol=5e-2)
    hist = np.asarray(res.history)
    assert hist[-1, 0] < hist[2, 0]  # primal residual shrinks


def test_pseudo_loglik_convex_descent(chain_gmrf):
    Theta, X = chain_gmrf
    th0 = graphical.flatten_sym(jnp.eye(6))
    l0 = float(graphical.neg_pseudo_loglik(th0, X))
    th_star = graphical.flatten_sym(graphical.mple_centralized(X, iters=400))
    l1 = float(graphical.neg_pseudo_loglik(th_star, X))
    assert l1 < l0
