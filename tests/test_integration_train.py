"""End-to-end integration: the real launchers on reduced configs (CPU)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_loss_decreases():
    hist = train_mod.main(
        [
            "--arch", "tinyllama-1.1b", "--reduced", "--steps", "60",
            "--batch", "8", "--seq", "64", "--log-every", "20", "--lr", "1e-3",
        ]
    )
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1


def test_train_with_staleness_and_compression():
    hist = train_mod.main(
        [
            "--arch", "qwen2-1.5b", "--reduced", "--steps", "40",
            "--batch", "4", "--seq", "32", "--log-every", "20",
            "--staleness", "2", "--compress-topk", "0.2", "--lr", "1e-3",
        ]
    )
    assert all(jnp.isfinite(jnp.asarray(h["loss"])) for h in hist)
    assert hist[-1]["loss"] < hist[0]["loss"] + 0.5  # no divergence


def test_train_checkpointing(tmp_path):
    from repro.checkpoint import latest_step

    train_mod.main(
        [
            "--arch", "xlstm-125m", "--reduced", "--steps", "10",
            "--batch", "2", "--seq", "16", "--log-every", "5",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
        ]
    )
    assert latest_step(str(tmp_path)) == 10


def test_serve_generates():
    out = serve_mod.main(
        [
            "--arch", "qwen2-1.5b", "--reduced", "--batch", "2",
            "--prompt-len", "8", "--gen", "4",
        ]
    )
    assert out.shape == (2, 4)
    assert bool(jnp.all((out >= 0) & (out < 512)))


def test_serve_greedy_deterministic():
    a = serve_mod.main(
        ["--arch", "tinyllama-1.1b", "--reduced", "--batch", "1",
         "--prompt-len", "6", "--gen", "3"]
    )
    b = serve_mod.main(
        ["--arch", "tinyllama-1.1b", "--reduced", "--batch", "1",
         "--prompt-len", "6", "--gen", "3"]
    )
    assert jnp.array_equal(a, b)
