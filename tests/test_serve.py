"""Serving-subsystem tests: the train→serve executor swap.

* ``Strategy.predict`` protocol — linear GD, k-windows cluster
  assignment, cascade-SVM decision values, LM decode closures.
* ``ServeEngine`` — fit → publish → serve round trips, hot-swap,
  inference byte metering through ``CommLedger``.
* ``MicroBatcher`` — bucketed-padding batches answer bit-exactly what
  per-request calls answer; timeout flush; static compiled-shape set.
* ``ModelRegistry`` — round-trip through ``checkpoint/io``, atomic
  LATEST hot-swap.
* 8-fake-device acceptance in a subprocess: mesh-sharded params, with
  per-request bytes visible on the ledger.
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.allreduce import CommLedger
from repro.core.schedules import round_robin
from repro.ml.linear import lsq_loss
from repro.serve import MicroBatcher, ModelRegistry, ServeEngine, ServeMetrics
from repro.utils.tree import tree_bytes


def _linear_problem(K=8, Nk=10, n=5, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(K, Nk, n)))
    w = jnp.asarray(rng.normal(size=(n,)))
    y = jnp.einsum("kni,i->kn", X, w)
    return X, y, w, n


@pytest.fixture(scope="module")
def gd_fit():
    X, y, w, n = _linear_problem()
    strategy = api.GradientDescent(lsq_loss, lr=0.1)
    res = api.fit(strategy, (X, y), transport="allreduce", steps=150)
    return strategy, res, n


@pytest.fixture(scope="module")
def kwindows_fit():
    from repro.ml.kwindows import KWindowsStrategy

    rng = np.random.default_rng(1)
    centers = rng.normal(size=(3, 2)) * 4.0
    Xs = jnp.asarray(
        centers[rng.integers(0, 3, size=(4, 64))]
        + rng.normal(size=(4, 64, 2)) * 0.3
    )
    strategy = KWindowsStrategy(jax.random.key(0), num_windows=6, r=1.0)
    res = api.fit(strategy, Xs, transport="sequential_server",
                  schedule=round_robin(4, 1))
    return strategy, res, jnp.asarray(centers, dtype=jnp.float32)


# ----------------------------------------------------------------------------
# Strategy.predict protocol
# ----------------------------------------------------------------------------


class TestPredictProtocol:
    def test_gd_linear_score(self, gd_fit):
        strategy, res, n = gd_fit
        Xq = jnp.asarray(np.random.default_rng(2).normal(size=(7, n)))
        np.testing.assert_array_equal(
            np.asarray(strategy.predict(res.theta, Xq)),
            np.asarray(Xq @ res.theta),
        )

    def test_kwindows_cluster_assignment(self, kwindows_fit):
        strategy, res, centers = kwindows_fit
        labels = strategy.predict(res.theta, centers)
        # every true center is captured by some merged window
        assert bool(jnp.all(labels >= 0))
        far = jnp.full((2, 2), 100.0)
        np.testing.assert_array_equal(
            np.asarray(strategy.predict(res.theta, far)), [-1, -1]
        )

    def test_cascade_svm_decision_values(self):
        from repro.ml.svm import CascadeStrategy, decision_function

        rng = np.random.default_rng(3)
        Xs = jnp.asarray(rng.normal(size=(4, 8, 2)))
        ys = jnp.sign(Xs[..., 0] + Xs[..., 1] + 1e-3)
        strategy = CascadeStrategy(C=1.0, iters=50)
        res = api.fit(strategy, (Xs, ys), transport="allreduce", steps=2)
        Xq = jnp.asarray(rng.normal(size=(9, 2)))
        np.testing.assert_array_equal(
            np.asarray(strategy.predict(res.theta, Xq)),
            np.asarray(decision_function(res.theta, Xq)),
        )

    def test_base_strategy_not_servable(self):
        with pytest.raises(NotImplementedError, match="cannot be served"):
            api.Strategy().predict(jnp.zeros(3), jnp.zeros((2, 3)))

    def test_optimizer_strategy_needs_predict_fn(self):
        s = api.OptimizerStrategy(lambda t, b: 0.0, None)
        with pytest.raises(NotImplementedError, match="predict_fn"):
            s.predict(jnp.zeros(3), jnp.zeros((2, 3)))


# ----------------------------------------------------------------------------
# ServeEngine
# ----------------------------------------------------------------------------


class TestServeEngine:
    def test_from_fit_predicts(self, gd_fit):
        strategy, res, n = gd_fit
        engine = ServeEngine.from_fit(res, strategy)
        Xq = jnp.asarray(
            np.random.default_rng(4).normal(size=(5, n)).astype(np.float32)
        )
        np.testing.assert_array_equal(
            np.asarray(engine.predict(Xq)), np.asarray(Xq @ res.theta)
        )

    def test_inference_bytes_metered(self, gd_fit):
        strategy, res, n = gd_fit
        engine = ServeEngine.from_fit(res, strategy)
        Xq = jnp.zeros((6, n), jnp.float32)
        Y = engine.predict(Xq)
        assert engine.ledger.uplink_bytes == tree_bytes(Xq)
        assert engine.ledger.downlink_bytes == tree_bytes(Y)
        assert engine.ledger.events[0][0] == "inference"
        assert engine.stats()["requests"] == 6

    def test_valid_rows_trimmed_and_metered(self, gd_fit):
        strategy, res, n = gd_fit
        engine = ServeEngine.from_fit(res, strategy)
        Xq = jnp.zeros((8, n), jnp.float32)
        Y = engine.predict(Xq, valid=3)
        assert Y.shape == (3,)
        assert engine.ledger.uplink_bytes == 3 * n * 4  # only real requests
        assert engine.metrics.padded_slots == 5

    def test_hot_swap_changes_predictions(self, gd_fit):
        strategy, res, n = gd_fit
        engine = ServeEngine.from_fit(res, strategy)
        Xq = jnp.ones((2, n), jnp.float32)
        before = np.asarray(engine.predict(Xq))
        engine.swap(2.0 * res.theta)
        np.testing.assert_allclose(
            np.asarray(engine.predict(Xq)), 2.0 * before, rtol=1e-6
        )

    def test_swap_rejects_structure_change(self, gd_fit):
        strategy, res, n = gd_fit
        engine = ServeEngine.from_fit(res, strategy)
        with pytest.raises(ValueError, match="structure"):
            engine.swap({"w": res.theta})

    def test_shared_metrics_across_engines(self, gd_fit):
        strategy, res, n = gd_fit
        metrics = ServeMetrics()
        a = ServeEngine.from_fit(res, strategy, metrics=metrics, tag="a")
        b = ServeEngine.from_fit(res, strategy, metrics=metrics, tag="b")
        a.predict(jnp.zeros((2, n), jnp.float32))
        b.predict(jnp.zeros((3, n), jnp.float32))
        assert metrics.requests == 5
        assert [e[1] for e in metrics.ledger.events] == ["a", "b"]


# ----------------------------------------------------------------------------
# MicroBatcher
# ----------------------------------------------------------------------------


class TestMicroBatcher:
    def test_padding_invariance_bit_exact(self, gd_fit):
        """Padded bucketed batches answer exactly what unpadded
        per-request predicts answer."""
        strategy, res, n = gd_fit
        rng = np.random.default_rng(5)
        for count in (1, 2, 3, 5, 7):
            engine = ServeEngine.from_fit(res, strategy)
            batcher = MicroBatcher(engine, max_batch=8)
            xs = [rng.normal(size=(n,)).astype(np.float32) for _ in range(count)]
            tickets = [batcher.submit(x) for x in xs]
            batcher.flush()
            got = np.asarray([t.result() for t in tickets])
            ref = np.asarray([
                np.asarray(engine.predict(jnp.asarray(x)[None]))[0] for x in xs
            ])
            np.testing.assert_array_equal(got, ref)

    def test_padded_slots_not_metered(self, gd_fit):
        strategy, res, n = gd_fit
        engine = ServeEngine.from_fit(res, strategy)
        batcher = MicroBatcher(engine, max_batch=8)
        for _ in range(3):  # bucket 4 → one padded slot
            batcher.submit(np.zeros(n, np.float32))
        batcher.flush()
        assert engine.ledger.uplink_bytes == 3 * n * 4
        assert engine.metrics.padded_slots == 1

    def test_static_shape_set(self):
        """Ragged traffic lowers to |shape groups| × |buckets| shapes."""
        seen = []

        def predict(X):
            seen.append(X.shape)
            return X.sum(axis=tuple(range(1, X.ndim)))

        batcher = MicroBatcher(predict, max_batch=4)
        rng = np.random.default_rng(6)
        for count in (1, 3, 2, 4, 3, 1):  # ragged arrival pattern
            for _ in range(count):
                batcher.submit(rng.normal(size=(5,)).astype(np.float32))
            batcher.flush()
        for _ in range(3):  # a second shape group
            batcher.submit(rng.normal(size=(9,)).astype(np.float32))
        batcher.flush()
        assert set(s[0] for s in seen) <= {1, 2, 4}
        assert set(s[1:] for s in seen) == {(5,), (9,)}

    def test_max_batch_auto_flush(self):
        calls = []
        batcher = MicroBatcher(lambda X: (calls.append(len(X)), X)[1],
                               max_batch=4)
        tickets = [batcher.submit(np.zeros(2, np.float32)) for _ in range(4)]
        assert calls == [4]  # flushed without an explicit flush()
        assert all(t.done for t in tickets)

    def test_timeout_flush_with_injected_clock(self):
        now = [0.0]
        batcher = MicroBatcher(lambda X: X, max_batch=8, timeout_s=0.5,
                               clock=lambda: now[0])
        batcher.submit(np.zeros(2, np.float32))
        assert batcher.poll() == 0  # younger than the timeout
        now[0] = 0.6
        assert batcher.poll() == 1
        assert batcher.pending() == 0

    def test_ticket_result_forces_service(self):
        batcher = MicroBatcher(lambda X: 2.0 * X, max_batch=8)
        t = batcher.submit(np.ones(3, np.float32))
        assert not t.done
        np.testing.assert_array_equal(np.asarray(t.result()), 2.0 * np.ones(3))

    def test_bucket_resolution(self):
        batcher = MicroBatcher(lambda X: X, max_batch=8)
        assert batcher.buckets == (1, 2, 4, 8)
        assert [batcher.bucket_for(k) for k in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]

    def test_inconsistent_buckets_rejected(self):
        """Explicit buckets that contradict max_batch must raise, not be
        silently clamped."""
        with pytest.raises(ValueError, match="largest bucket"):
            MicroBatcher(lambda X: X, max_batch=16, buckets=(2, 4))
        b = MicroBatcher(lambda X: X, max_batch=4, buckets=(2, 4))
        assert b.buckets == (2, 4) and b.max_batch == 4

    def test_predict_runs_outside_the_lock(self):
        """A slow predict must not block submits of other shape groups."""
        import threading

        started, release = threading.Event(), threading.Event()

        def slow(X):
            started.set()
            assert release.wait(timeout=5)
            return X

        batcher = MicroBatcher(slow, max_batch=8)
        batcher.submit(np.zeros(3, np.float32))
        flusher = threading.Thread(target=batcher.flush)
        flusher.start()
        try:
            assert started.wait(timeout=5)
            batcher.submit(np.zeros(5, np.float32))  # would deadlock before
            assert batcher.pending() == 1
        finally:
            release.set()
            flusher.join(timeout=5)
        assert not flusher.is_alive()

    def test_result_waits_for_in_flight_batch(self):
        """result() on a ticket whose batch another thread is already
        serving must wait for the real answer, not return None."""
        import threading

        release = threading.Event()

        def slow(X):
            assert release.wait(timeout=5)
            return 2.0 * X

        batcher = MicroBatcher(slow, max_batch=8)
        t = batcher.submit(np.ones(3, np.float32))
        flusher = threading.Thread(target=batcher.flush)
        flusher.start()  # pops the group and blocks inside predict
        try:
            with pytest.raises(TimeoutError):
                t.result(timeout=0.05)  # in flight, not yet resolved
            release.set()
            np.testing.assert_array_equal(np.asarray(t.result(timeout=5)),
                                          2.0 * np.ones(3))
        finally:
            release.set()
            flusher.join(timeout=5)

    def test_concurrent_submits_never_overshoot_buckets(self):
        """Racing submits must not grow a group past max_batch (which
        would serve an unbucketed shape and force a fresh compile)."""
        import threading

        sizes = []

        def predict(X):
            sizes.append(len(X))
            return X

        batcher = MicroBatcher(predict, max_batch=4)
        barrier = threading.Barrier(8)

        def client():
            barrier.wait()
            for _ in range(25):
                batcher.submit(np.zeros(2, np.float32))

        threads = [threading.Thread(target=client) for _ in range(8)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=10)
        batcher.flush()
        assert sizes and set(sizes) <= set(batcher.buckets)

    def test_concurrent_clients_meter_exactly(self, gd_fit):
        """Counter/ledger updates must not interleave when batches
        resolve on several client threads at once."""
        import threading

        strategy, res, n = gd_fit
        engine = ServeEngine.from_fit(res, strategy)
        batcher = MicroBatcher(engine, max_batch=4)
        per_thread, n_threads = 20, 6
        barrier = threading.Barrier(n_threads)

        def client():
            barrier.wait()
            for _ in range(per_thread):
                batcher.submit(np.zeros(n, np.float32))

        threads = [threading.Thread(target=client) for _ in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=10)
        batcher.flush()
        total = per_thread * n_threads
        assert engine.metrics.requests == total
        assert engine.ledger.uplink_bytes == total * n * 4

    def test_predict_failure_propagates_to_tickets(self):
        """A failing predict resolves every ticket with the error — no
        request is silently lost as a None result."""

        def broken(X):
            raise RuntimeError("model exploded")

        batcher = MicroBatcher(broken, max_batch=8)
        t1 = batcher.submit(np.zeros(3, np.float32))
        t2 = batcher.submit(np.zeros(3, np.float32))
        with pytest.raises(RuntimeError, match="exploded"):
            batcher.flush()
        assert t1.done and t2.done
        with pytest.raises(RuntimeError, match="exploded"):
            t1.result()
        assert batcher.pending() == 0


# ----------------------------------------------------------------------------
# ModelRegistry
# ----------------------------------------------------------------------------


class TestModelRegistry:
    def test_round_trip_bare_array(self, tmp_path, gd_fit):
        _, res, _ = gd_fit
        reg = ModelRegistry(str(tmp_path))
        v = reg.publish("lin", res.theta)
        assert v == 1
        np.testing.assert_array_equal(
            np.asarray(reg.load("lin")), np.asarray(res.theta)
        )

    def test_round_trip_dict_tree(self, tmp_path):
        reg = ModelRegistry(str(tmp_path))
        theta = {"a": {"w": jnp.arange(6.0).reshape(2, 3)}, "b": jnp.ones(2)}
        reg.publish("m", theta)
        out = reg.load("m")
        np.testing.assert_array_equal(np.asarray(out["a"]["w"]),
                                      np.asarray(theta["a"]["w"]))
        np.testing.assert_array_equal(np.asarray(out["b"]),
                                      np.asarray(theta["b"]))

    def test_round_trip_namedtuple_with_like(self, tmp_path, kwindows_fit):
        _, res, _ = kwindows_fit
        reg = ModelRegistry(str(tmp_path))
        reg.publish("kw", res.theta)
        out = reg.load("kw", like=res.theta)
        assert type(out).__name__ == "KWindows"
        np.testing.assert_array_equal(np.asarray(out.centers),
                                      np.asarray(res.theta.centers))

    def test_versioning_and_hot_swap(self, tmp_path):
        reg = ModelRegistry(str(tmp_path))
        reg.publish("m", jnp.zeros(3))
        reg.publish("m", jnp.ones(3))
        assert reg.versions("m") == [1, 2]
        assert reg.latest("m") == 2
        reg.set_latest("m", 1)  # atomic rollback
        np.testing.assert_array_equal(np.asarray(reg.load("m")), np.zeros(3))
        with open(os.path.join(str(tmp_path), "m", "LATEST")) as f:
            assert f.read().strip() == "1"

    def test_publish_without_activate_keeps_pointer(self, tmp_path):
        reg = ModelRegistry(str(tmp_path))
        reg.publish("m", jnp.zeros(3))
        reg.publish("m", jnp.ones(3), activate=False)
        assert reg.latest("m") == 1
        assert reg.versions("m") == [1, 2]

    def test_staged_only_model_is_not_served(self, tmp_path):
        """activate=False on a fresh name must not become 'latest'."""
        reg = ModelRegistry(str(tmp_path))
        reg.publish("dark", jnp.zeros(3), activate=False)
        assert reg.latest("dark") is None
        with pytest.raises(FileNotFoundError):
            reg.load("dark")
        np.testing.assert_array_equal(  # explicit version still loads
            np.asarray(reg.load("dark", 1)), np.zeros(3)
        )

    def test_engine_hot_swaps_from_registry(self, tmp_path, gd_fit):
        strategy, res, n = gd_fit
        reg = ModelRegistry(str(tmp_path))
        reg.publish("lin", res.theta)
        engine = ServeEngine.from_registry(reg, "lin", strategy)
        Xq = jnp.ones((2, n), jnp.float32)
        before = np.asarray(engine.predict(Xq))
        reg.publish("lin", 3.0 * res.theta)  # new version goes live
        engine.swap(reg.load("lin"))
        np.testing.assert_allclose(np.asarray(engine.predict(Xq)),
                                   3.0 * before, rtol=1e-6)

    def test_publish_skips_claimed_versions(self, tmp_path):
        """A version another publisher has claimed (sentinel present but
        payload not yet written) is never reused."""
        reg = ModelRegistry(str(tmp_path))
        reg.publish("m", jnp.zeros(3))
        open(os.path.join(str(tmp_path), "m", "step_00000002.claim"),
             "w").close()
        assert reg.publish("m", jnp.ones(3)) == 3
        assert reg.versions("m") == [1, 3]
        assert reg.latest("m") == 3

    def test_meta_and_models(self, tmp_path):
        reg = ModelRegistry(str(tmp_path))
        reg.publish("m", jnp.zeros(3), meta={"transport": "allreduce"})
        assert reg.meta("m")["transport"] == "allreduce"
        assert reg.models() == ["m"]

    def test_missing_version_raises(self, tmp_path):
        reg = ModelRegistry(str(tmp_path))
        with pytest.raises(FileNotFoundError):
            reg.load("ghost")
        reg.publish("m", jnp.zeros(3))
        with pytest.raises(FileNotFoundError):
            reg.set_latest("m", 7)


# ----------------------------------------------------------------------------
# CommLedger inference pricing
# ----------------------------------------------------------------------------


class TestInferenceLedger:
    def test_priced_like_training_messages(self):
        led = CommLedger()
        req = jnp.zeros((4, 16), jnp.float32)
        resp = jnp.zeros((4,), jnp.float32)
        led.record_inference(req, resp, tag="q")
        assert led.uplink_bytes == 4 * 16 * 4
        assert led.downlink_bytes == 4 * 4
        assert led.events == [("inference", "q", 4 * 16 * 4 + 4 * 4)]

    def test_merges_with_training_ledger(self, gd_fit):
        """One accounting path: a fit's ledger absorbs serving traffic."""
        strategy, res, n = gd_fit
        engine = ServeEngine.from_fit(res, strategy)
        engine.predict(jnp.zeros((2, n), jnp.float32))
        total = CommLedger()
        total.merge(res.ledger)
        total.merge(engine.ledger)
        assert total.uplink_bytes == (
            res.ledger.uplink_bytes + engine.ledger.uplink_bytes
        )
        kinds = {e[0] for e in total.events}
        assert "inference" in kinds and len(kinds) > 1


# ----------------------------------------------------------------------------
# Vectorized prefill (launch/serve satellite)
# ----------------------------------------------------------------------------


class TestVectorizedPrefill:
    @pytest.mark.parametrize("arch", ["qwen2-1.5b", "minicpm3-4b"])
    def test_batched_matches_loop(self, arch):
        """One batched prefill call ≡ the token loop, for plain attention
        (qwen2) and MLA (minicpm3) cache appends."""
        from repro.configs import get_config
        from repro.launch import serve as sv
        from repro.models import transformer as tf

        cfg = dataclasses.replace(
            get_config(arch).reduced(), compute_dtype="float32"
        )
        assert sv.batched_prefill_supported(cfg)
        params = tf.init_params(jax.random.key(0), cfg)
        prompts = jax.random.randint(
            jax.random.key(1), (3, 12), 0, cfg.vocab_size
        )
        loop = sv.prefill_and_decode(
            cfg, params, prompts, gen=5, cache_len=20, prefill="loop"
        )
        batched = sv.prefill_and_decode(
            cfg, params, prompts, gen=5, cache_len=20, prefill="batched"
        )
        np.testing.assert_array_equal(np.asarray(loop), np.asarray(batched))

    def test_sampled_decode_is_padding_invariant(self):
        """temperature > 0 uses per-row sample keys, so appending padded
        rows cannot change a real request's tokens."""
        from repro.configs import get_config
        from repro.launch import serve as sv
        from repro.models import transformer as tf

        cfg = dataclasses.replace(
            get_config("qwen2-1.5b").reduced(), compute_dtype="float32"
        )
        params = tf.init_params(jax.random.key(0), cfg)
        prompts = jax.random.randint(jax.random.key(1), (3, 6), 0,
                                     cfg.vocab_size)
        padded = jnp.concatenate([prompts, prompts[-1:]])  # bucket pad
        a = sv.prefill_and_decode(cfg, params, prompts, gen=4, cache_len=12,
                                  temperature=0.8)
        b = sv.prefill_and_decode(cfg, params, padded, gen=4, cache_len=12,
                                  temperature=0.8)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b[:3]))

    def test_recurrent_archs_keep_the_loop(self):
        from repro.configs import get_config
        from repro.launch import serve as sv

        cfg = get_config("xlstm-125m").reduced()
        assert not sv.batched_prefill_supported(cfg)
        with pytest.raises(ValueError, match="recurrent"):
            sv.prefill_and_decode(
                cfg, None, jnp.zeros((1, 4), jnp.int32), gen=1, cache_len=8,
                prefill="batched",
            )


# ----------------------------------------------------------------------------
# ServingExecutor: train→serve as an executor swap
# ----------------------------------------------------------------------------


class TestServingExecutor:
    def test_fit_returns_live_engine(self, gd_fit):
        strategy, ref, n = gd_fit
        X, y, w, _ = _linear_problem()
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=150, executor="serve")
        engine = res.metrics["serve_engine"]
        assert isinstance(engine, ServeEngine)
        Xq = jnp.ones((2, n), jnp.float32)
        np.testing.assert_array_equal(np.asarray(engine.predict(Xq)),
                                      np.asarray(Xq @ ref.theta))

    def test_server_transport_finalizes_through_executor(self, kwindows_fit):
        """k-windows trains on a server transport; executor="serve" hands
        its MERGED windows to the engine."""
        from repro.ml.kwindows import KWindowsStrategy

        strategy, ref, centers = kwindows_fit
        Xs_strategy = KWindowsStrategy(jax.random.key(0), num_windows=6, r=1.0)
        rng = np.random.default_rng(1)
        cs = rng.normal(size=(3, 2)) * 4.0
        Xs = jnp.asarray(
            cs[rng.integers(0, 3, size=(4, 64))]
            + rng.normal(size=(4, 64, 2)) * 0.3
        )
        res = api.fit(Xs_strategy, Xs, transport="sequential_server",
                      schedule=round_robin(4, 1), executor="serve")
        engine = res.metrics["serve_engine"]
        labels = engine.predict(jnp.asarray(cs, dtype=jnp.float32))
        assert bool(jnp.all(labels >= 0))

    def test_publishes_when_given_registry(self, tmp_path, gd_fit):
        X, y, w, n = _linear_problem()
        reg = ModelRegistry(str(tmp_path))
        ex = api.ServingExecutor(registry=reg, publish_as="lin")
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=20, executor=ex)
        assert reg.latest("lin") == 1
        np.testing.assert_array_equal(np.asarray(reg.load("lin")),
                                      np.asarray(res.theta))

    def test_registry_needs_name(self):
        with pytest.raises(ValueError, match="publish_as"):
            api.ServingExecutor(registry=ModelRegistry("/tmp/x"))

    def test_registered_in_executor_table(self):
        assert "serve" in api.EXECUTORS
        assert isinstance(api.make_executor("serve"), api.ServingExecutor)

    def test_admm_accepts_serving_executor(self, tmp_path):
        """The executor swap covers admm_consensus too: the consensus z
        trains locally and is published/stood up like any other theta."""
        from repro.ml.linear import lasso_prox_builder

        X, y, w, n = _linear_problem(K=4)
        reg = ModelRegistry(str(tmp_path))
        ex = api.ServingExecutor(registry=reg, publish_as="lasso")
        res = api.fit(api.ProxStrategy(lasso_prox_builder), (X, y),
                      transport="admm_consensus", steps=10, g="l1",
                      g_lam=0.1, executor=ex)
        assert reg.latest("lasso") == 1
        assert "serve_engine" in res.metrics
        np.testing.assert_array_equal(np.asarray(reg.load("lasso")),
                                      np.asarray(res.theta))


# ----------------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------------


class TestServeCLI:
    def test_strategy_path_publishes_and_serves(self, tmp_path):
        from repro.launch import serve as serve_mod

        preds = serve_mod.main(
            ["--strategy", "gd", "--registry", str(tmp_path),
             "--requests", "5", "--batch", "4"]
        )
        assert len(preds) == 5
        reg = ModelRegistry(str(tmp_path))
        assert reg.latest("gd") == 1


# ----------------------------------------------------------------------------
# Acceptance: 8 fake devices, mesh-sharded serving
# ----------------------------------------------------------------------------


class TestServeMeshEightDevices:
    """fit → publish → serve with params placed on a ("data", "model")
    mesh over 8 fake CPU devices, bytes visible on the ledger (XLA device
    count is fixed at jax init, so this runs in a subprocess)."""

    SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import tempfile
import jax
import jax.numpy as jnp
import numpy as np
from repro import api
from repro.core.schedules import round_robin
from repro.ml.kwindows import KWindowsStrategy
from repro.ml.linear import lsq_loss
from repro.serve import MicroBatcher, ModelRegistry, ServeEngine

rng = np.random.default_rng(0)
out = {"num_devices": jax.device_count()}
mesh = jax.make_mesh((4, 2), ("data", "model"))
reg = ModelRegistry(tempfile.mkdtemp())

# linear GD: trained on the mesh executor, served on the same mesh
X = jnp.asarray(rng.normal(size=(8, 10, 5)))
w = jnp.asarray(rng.normal(size=(5,)))
y = jnp.einsum("kni,i->kn", X, w)
gd = api.GradientDescent(lsq_loss, lr=0.1)
res = api.fit(gd, (X, y), transport="allreduce", steps=100, executor="mesh")
reg.publish("lin", res.theta)
eng = ServeEngine.from_registry(reg, "lin", gd, mesh=mesh)
local = ServeEngine(gd, res.theta)
Xq = jnp.asarray(rng.normal(size=(6, 5)).astype(np.float32))
bat = MicroBatcher(eng, max_batch=8)
tickets = [bat.submit(np.asarray(x)) for x in Xq]
bat.flush()
got = np.asarray([t.result() for t in tickets])
out["gd"] = {
    "matches_local": bool(np.allclose(got, np.asarray(local.predict(Xq)),
                                      rtol=1e-6, atol=1e-7)),
    "uplink": eng.ledger.uplink_bytes,
    "downlink": eng.ledger.downlink_bytes,
    "events": [e[0] for e in eng.ledger.events],
}

# k-windows: server-transport fit, mesh-served cluster assignment
centers = rng.normal(size=(3, 2)) * 4.0
Xs = jnp.asarray(centers[rng.integers(0, 3, size=(4, 64))]
                 + rng.normal(size=(4, 64, 2)) * 0.3)
kw = KWindowsStrategy(jax.random.key(0), num_windows=6, r=1.0)
rkw = api.fit(kw, Xs, transport="sequential_server",
              schedule=round_robin(4, 1))
reg.publish("kw", rkw.theta)
ekw = ServeEngine.from_registry(reg, "kw", kw, like=rkw.theta, mesh=mesh)
labels = ekw.predict(jnp.asarray(centers, dtype=jnp.float32))
lref = ServeEngine(kw, rkw.theta).predict(jnp.asarray(centers, dtype=jnp.float32))
out["kwindows"] = {
    "matches_local": bool(np.array_equal(np.asarray(labels), np.asarray(lref))),
    "uplink": ekw.ledger.uplink_bytes,
}
print(json.dumps(out))
"""

    def test_fit_publish_serve_on_8_devices(self, fake_devices):
        out = fake_devices(self.SCRIPT)
        assert out["num_devices"] == 8
        assert out["gd"]["matches_local"], out
        assert out["gd"]["uplink"] == 6 * 5 * 4  # 6 requests × 5 f32 features
        assert out["gd"]["downlink"] == 6 * 4
        assert out["gd"]["events"] == ["inference"]
        assert out["kwindows"]["matches_local"], out
        assert out["kwindows"]["uplink"] == 3 * 2 * 4


# ----------------------------------------------------------------------------
# LM decode through the engine (host mesh; heavier compile kept small)
# ----------------------------------------------------------------------------


class TestLMServing:
    def test_lm_decode_engine_with_batcher(self):
        from repro.api.strategy import OptimizerStrategy
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.launch.serve import lm_predict_fn
        from repro.models import transformer as tf

        cfg = dataclasses.replace(
            get_config("qwen2-1.5b").reduced(), compute_dtype="float32"
        )
        params = tf.init_params(jax.random.key(0), cfg)
        strategy = OptimizerStrategy(
            None, None, predict_fn=lm_predict_fn(cfg, gen=3)
        )
        assert not strategy.predict_jit
        engine = ServeEngine(strategy, params, mesh=make_host_mesh())
        prompts = jax.random.randint(jax.random.key(1), (3, 8), 0,
                                     cfg.vocab_size)
        batcher = MicroBatcher(engine, max_batch=4)
        tickets = [batcher.submit(np.asarray(p)) for p in prompts]
        batcher.flush()
        got = np.asarray([t.result() for t in tickets])
        ref = np.asarray(strategy.predict(params, prompts))
        np.testing.assert_array_equal(got, ref)
        # prompts up (int32), generated ids down
        assert engine.ledger.uplink_bytes == 3 * 8 * 4
        assert engine.ledger.downlink_bytes == 3 * 3 * 4
