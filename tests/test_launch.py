"""Launch-layer coverage on the host (1×1) mesh: the same build_jitted /
spec machinery the 512-device dry-run uses, exercised end-to-end on CPU
with reduced configs — catches spec/structure mismatches without the
device-count env flag."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config
from repro.launch import specs as S
from repro.launch.mesh import make_host_mesh
from repro.sharding.rules import set_mesh_context


@pytest.fixture(autouse=True)
def _clear_ctx():
    yield
    set_mesh_context(None)


def _build(arch, kind, B, S_len, **kw):
    mesh = make_host_mesh()
    cfg = get_config(arch).reduced()
    set_mesh_context(S.make_mesh_context_for(mesh, cfg, B))
    return cfg, S.build_jitted(cfg, kind, mesh, B, S_len, **kw)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "olmoe-1b-7b", "xlstm-125m"])
def test_train_step_lowers_and_runs(arch):
    cfg, (jitted, args, params_shape) = _build(arch, "train", 2, 16)
    compiled = jitted.lower(*args).compile()
    assert compiled.cost_analysis() is not None
    # run it for real with concrete arrays
    key = jax.random.key(0)
    from repro.models import transformer as tf

    params = tf.init_params(key, cfg)
    opt = S.make_optimizer(cfg)
    opt_state = opt.init(params)
    batch = {
        "tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
    }
    p2, o2, metrics = jitted(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])


def test_decode_step_lowers_and_runs():
    cfg, (jitted, args, _) = _build("qwen2-1.5b", "decode", 2, 24)
    compiled = jitted.lower(*args).compile()
    from repro.models import transformer as tf

    params = tf.init_params(jax.random.key(0), cfg)
    cache = tf.init_cache(cfg, 2, 24, jnp.bfloat16, index=4)
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (2, 1), 0, cfg.vocab_size),
        "cache": cache,
    }
    logits, new_cache = jitted(params, batch)
    assert logits.shape[:2] == (2, 1)
    idx = [l for l in jax.tree.leaves(new_cache) if l.dtype == jnp.int32][0]
    assert int(idx.reshape(-1)[0]) == 5


def test_prefill_step_whisper():
    cfg, (jitted, args, _) = _build("whisper-base", "prefill", 2, 16)
    jitted.lower(*args).compile()


@pytest.mark.parametrize("strategy", ["tp", "dp", "dp_fsdp", "serve"])
def test_strategies_lower(strategy):
    mesh = make_host_mesh()
    cfg = get_config("tinyllama-1.1b").reduced()
    set_mesh_context(S.make_mesh_context_for(mesh, cfg, 2, strategy=strategy))
    kind = "decode" if strategy == "serve" else "train"
    jitted, args, _ = S.build_jitted(cfg, kind, mesh, 2, 16, strategy=strategy)
    jitted.lower(*args).compile()


def test_input_specs_cover_all_shapes():
    for arch in ("tinyllama-1.1b", "whisper-base", "qwen2-vl-2b", "jamba-1.5-large-398b"):
        for shape in SHAPES:
            specs = S.input_specs(arch, shape)
            assert "tokens" in specs
            leaves = jax.tree.leaves(specs)
            assert all(hasattr(l, "shape") for l in leaves)


def test_shape_adapted_config_rules():
    # long_500k → sliding window for dense, none for ssm
    assert S.shape_adapted_config("tinyllama-1.1b", "long_500k").sliding_window == 8192
    assert S.shape_adapted_config("xlstm-125m", "long_500k").sliding_window == 0
    # train → remat + q-chunk; decode → no remat, no MTP
    assert S.shape_adapted_config("tinyllama-1.1b", "train_4k").remat_policy == "full"
    assert S.shape_adapted_config("deepseek-v3-671b", "decode_32k").num_mtp_layers == 0
    # giants get bf16 params
    assert S.shape_adapted_config("deepseek-v3-671b", "train_4k").param_dtype == "bfloat16"
