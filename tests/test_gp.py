"""Distributed Gaussian Processes (paper §3.3)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ml import gp


@pytest.fixture(scope="module")
def sine_data():
    rng = np.random.default_rng(11)
    X = np.linspace(-3, 3, 64)[:, None]
    y = np.sin(X[:, 0]) + 0.05 * rng.normal(size=64)
    Xq = np.linspace(-2.5, 2.5, 12)[:, None]
    return jnp.asarray(X), jnp.asarray(y), jnp.asarray(Xq)


def test_exact_gp_fits_sine(sine_data):
    X, y, Xq = sine_data
    hyp = gp.fit_hypers(X, y, steps=120)
    mu, var = gp.gp_posterior(hyp, X, y, Xq)
    rmse = float(jnp.sqrt(jnp.mean((mu - jnp.sin(Xq[:, 0])) ** 2)))
    assert rmse < 0.1
    assert bool(jnp.all(var > 0))


def test_fit_improves_likelihood(sine_data):
    X, y, _ = sine_data
    h0 = gp.default_hypers()
    h1 = gp.fit_hypers(X, y, steps=100)
    assert float(gp.log_marginal_likelihood(h1, X, y)) > float(
        gp.log_marginal_likelihood(h0, X, y)
    )


def test_single_expert_reduces_to_exact(sine_data):
    """With K=1 expert every combination rule must equal the exact GP."""
    X, y, Xq = sine_data
    hyp = gp.fit_hypers(X, y, steps=60)
    preds = gp.expert_predictions(hyp, X[None], y[None], Xq)
    mu_full, var_full = gp.gp_posterior(hyp, X, y, Xq)
    pv = gp.prior_variance(hyp, Xq)
    for rule in (
        gp.poe,
        lambda p: gp.bcm(p, pv),
        lambda p: gp.gbcm(p, pv, beta=jnp.ones(1)),  # β=1 ⇒ exact identity
        lambda p: gp.gpoe(p, beta=jnp.ones(1)),
    ):
        mu, var = rule(preds)
        np.testing.assert_allclose(mu, mu_full, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(var, var_full, rtol=1e-3, atol=1e-5)


def test_expert_combinations_close_to_full(sine_data):
    X, y, Xq = sine_data
    hyp = gp.fit_hypers(X, y, steps=100)
    Xs = X.reshape(4, 16, 1)
    ys = y.reshape(4, 16)
    preds = gp.expert_predictions(hyp, Xs, ys, Xq)
    mu_full, _ = gp.gp_posterior(hyp, X, y, Xq)
    pv = gp.prior_variance(hyp, Xq)
    for name, (mu, _) in {
        "poe": gp.poe(preds),
        "gpoe": gp.gpoe(preds),
        "bcm": gp.bcm(preds, pv),
        "gbcm": gp.gbcm(preds, pv),
    }.items():
        rmse = float(jnp.sqrt(jnp.mean((mu - mu_full) ** 2)))
        assert rmse < 0.12, name


def test_gpoe_falls_back_to_prior_far_away(sine_data):
    """Σβ = 1 ⇒ predictive variance → prior variance outside the data
    (the paper's stated property of the gPoE/central-server coordination)."""
    X, y, _ = sine_data
    hyp = gp.fit_hypers(X, y, steps=60)
    far = jnp.asarray([[40.0]])
    Xs = X.reshape(4, 16, 1)
    ys = y.reshape(4, 16)
    preds = gp.expert_predictions(hyp, Xs, ys, far)
    _, var = gp.gpoe(preds)  # default β = 1/K sums to 1
    pv = gp.prior_variance(hyp, far)
    np.testing.assert_allclose(var, pv, rtol=0.05)


def test_poe_overconfident_far_away(sine_data):
    """PoE's known failure (paper: 'tend to be overconfident'): far from
    data its variance is K× too small vs the prior."""
    X, y, _ = sine_data
    hyp = gp.fit_hypers(X, y, steps=60)
    far = jnp.asarray([[40.0]])
    preds = gp.expert_predictions(hyp, X.reshape(4, 16, 1), y.reshape(4, 16), far)
    _, var_poe = gp.poe(preds)
    pv = gp.prior_variance(hyp, far)
    assert float(var_poe[0]) < 0.5 * float(pv[0])


def test_distributed_hyper_training(sine_data):
    X, y, _ = sine_data
    Xs = X.reshape(4, 16, 1)
    ys = y.reshape(4, 16)
    hyp = gp.fit_hypers_distributed(Xs, ys, steps=100)
    lls = sum(
        float(gp.log_marginal_likelihood(hyp, Xs[k], ys[k])) for k in range(4)
    )
    lls0 = sum(
        float(gp.log_marginal_likelihood(gp.default_hypers(), Xs[k], ys[k]))
        for k in range(4)
    )
    assert lls > lls0


def test_moe_map_assignment():
    means = jnp.asarray([[0.0, 0.0], [5.0, 5.0]])
    V = jnp.ones(2)
    X = jnp.asarray([[0.1, -0.2], [4.9, 5.3], [0.4, 0.1]])
    z = gp.moe_map_assign(X, means, V)
    np.testing.assert_array_equal(z, jnp.asarray([0, 1, 0]))


def test_moe_predict(sine_data):
    X, y, Xq = sine_data
    hyp = gp.fit_hypers(X, y, steps=60)
    means = jnp.asarray([[-1.5], [1.5]])
    mu, var = gp.moe_predict(hyp, X, y, Xq, means, jnp.ones(1))
    rmse = float(jnp.sqrt(jnp.mean((mu - jnp.sin(Xq[:, 0])) ** 2)))
    assert rmse < 0.25
