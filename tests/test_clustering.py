"""Distributed clustering (paper §4.1)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ml import clustering


@pytest.fixture(scope="module")
def blobs():
    rng = np.random.default_rng(13)
    centers = np.asarray([(-5.0, -5.0), (0.0, 5.0), (5.0, -2.0)])
    X = np.concatenate([rng.normal(size=(60, 2)) * 0.7 + c for c in centers])
    return jnp.asarray(X), jnp.asarray(centers)


def _init(X, K, seed=0):
    import jax

    return clustering.kmeans_pp_init(jax.random.key(seed), X, K)


def _best_of_restarts(X, K, metric="l2", iters=30, seeds=range(4)):
    """k-means++ is randomized; recovery claims use the standard
    best-of-restarts protocol (lowest inertia over a few seeds)."""
    results = [
        clustering.kmeans(X, _init(X, K, seed=s), num_clusters=K,
                          metric=metric, iters=iters)
        for s in seeds
    ]
    return min(results, key=lambda r: float(r.inertia))


def test_kmeans_recovers_centers(blobs):
    X, centers = blobs
    res = _best_of_restarts(X, 3)
    found = np.sort(np.asarray(res.centroids), axis=0)
    np.testing.assert_allclose(found, np.sort(np.asarray(centers), 0), atol=0.5)


def test_distributed_kmeans_identical_to_centralized(blobs):
    """Sufficient-statistics Allreduce ⇒ exactly the centralized trajectory."""
    X, _ = blobs
    C0 = _init(X, 3)
    res_c = clustering.kmeans(X, C0, num_clusters=3, metric="l2sq", iters=25)
    Xs = X.reshape(3, 60, 2)
    res_d = clustering.distributed_kmeans(Xs, C0, num_clusters=3, iters=25)
    np.testing.assert_allclose(res_c.centroids, res_d.centroids, atol=1e-5)
    np.testing.assert_allclose(float(res_c.inertia), float(res_d.inertia), rtol=1e-5)


@pytest.mark.parametrize("metric", ["l1", "l2", "linf"])
def test_metrics_all_separate_blobs(blobs, metric):
    X, centers = blobs
    res = _best_of_restarts(X, 3, metric=metric)
    found = np.sort(np.asarray(res.centroids), axis=0)
    np.testing.assert_allclose(found, np.sort(np.asarray(centers), 0), atol=0.7)


def test_l1_mstep_is_median():
    X = jnp.asarray([[0.0, 0.0], [1.0, 0.0], [10.0, 0.0]])
    C, counts = clustering._m_step(X, jnp.zeros(3, dtype=jnp.int32), 1, "l1")
    assert float(C[0, 0]) == 1.0  # median, not mean (≈3.67)


def test_linf_mstep_is_midrange():
    X = jnp.asarray([[0.0, 0.0], [1.0, 0.0], [10.0, 0.0]])
    C, counts = clustering._m_step(X, jnp.zeros(3, dtype=jnp.int32), 1, "linf")
    assert float(C[0, 0]) == 5.0  # (min+max)/2


def test_consensus_kmeans_homogeneous(blobs):
    """[21] assumes homogeneous node data (paper §4.1: 'Since the model is
    the same in each agent when dealing with homogenous data, ADMM can also
    be used') — shards are i.i.d. shuffles here."""
    X, centers = blobs
    rng = np.random.default_rng(3)
    Xsh = jnp.asarray(np.asarray(X)[rng.permutation(X.shape[0])])
    Xs = Xsh.reshape(3, 60, 2)
    C, res = clustering.consensus_kmeans(Xs, _init(X, 3), iters=40)
    found = np.sort(np.asarray(C), axis=0)
    np.testing.assert_allclose(found, np.sort(np.asarray(centers), 0), atol=0.8)


def test_consensus_kmeans_heterogeneous_with_alignment(blobs):
    """BEYOND-PAPER: [21] assumes homogeneous shards; with the greedy
    slot-alignment step our consensus k-means survives maximally
    heterogeneous shards (node k = blob k) within ~20% of centralized
    inertia.  Without alignment this collapses (slot-permutation mixing)."""
    X, centers = blobs
    Xs = X.reshape(3, 60, 2)  # node k = blob k (maximally heterogeneous)
    C, _ = clustering.consensus_kmeans(Xs, _init(X, 3), iters=40)
    inertia_het = float(
        jnp.sum(jnp.min(clustering.pdist(X, C, metric="l2sq"), axis=1))
    )
    res_central = clustering.kmeans(X, _init(X, 3), num_clusters=3, iters=30)
    assert inertia_het < 1.2 * float(res_central.inertia)


def test_summarize_representatives(blobs):
    X, _ = blobs
    reps, mask = clustering.summarize_representatives(
        X, eps=1.0, min_pts=5, max_reps=30
    )
    n = int(jnp.sum(mask))
    assert 3 <= n <= 30
    # every representative's eps-ball holds >= min_pts points
    d = clustering.pdist(X, reps[mask > 0], metric="l2")
    assert bool(jnp.all(jnp.sum(d <= 1.0, axis=0) >= 5))


def test_radius_t_clustering(blobs):
    X, centers = blobs
    C, counts, mask = clustering.radius_t_clustering(X, T=2.5, max_clusters=20)
    n = int(jnp.sum(mask))
    assert 3 <= n <= 8  # roughly one cluster per blob
    assert float(jnp.sum(counts)) == X.shape[0]


def test_merge_centroids():
    C = jnp.asarray([[0.0, 0.0], [0.2, 0.0], [5.0, 5.0]])
    counts = jnp.asarray([10.0, 30.0, 5.0])
    mask = jnp.ones(3)
    C2, counts2, mask2 = clustering.merge_centroids(C, counts, mask, T=1.0)
    assert int(jnp.sum(mask2)) == 2
    # merged centroid is the count-weighted mean
    np.testing.assert_allclose(C2[0], jnp.asarray([0.15, 0.0]), atol=1e-6)
