"""Tests for the paper's §5 central-information-server algorithm."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedules, server


def _make_problem(K=4, Nk=10, n=5, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(K, Nk, n)))
    w = jnp.asarray(rng.normal(size=(n,)))
    y = jnp.einsum("kni,i->kn", X, w)
    lr = 0.05

    def F(k, theta):
        Xk, yk = X[k], y[k]
        g = Xk.T @ (Xk @ theta - yk) / Nk
        return theta - lr * g

    return F, w, n


class TestRoundRobinEquivalence:
    """Paper §5: round-robin composition ≡ serial mini-batch gradient
    descent over the union of shards."""

    def test_matches_serial_composition(self):
        F, w, n = _make_problem()
        sched = schedules.round_robin(4, 5)
        final, traj = server.run_protocol(jnp.zeros(n), F, sched)
        theta = jnp.zeros(n)
        for t in range(len(sched)):
            theta = F(int(sched[t]), theta)
        np.testing.assert_allclose(final.theta, theta, rtol=1e-5, atol=1e-6)

    def test_converges_to_truth(self):
        F, w, n = _make_problem()
        sched = schedules.round_robin(4, 100)
        final, _ = server.run_protocol(jnp.zeros(n), F, sched)
        assert float(jnp.linalg.norm(final.theta - w)) < 1e-2

    def test_trajectory_shape(self):
        F, w, n = _make_problem()
        sched = schedules.round_robin(4, 3)
        _, traj = server.run_protocol(jnp.zeros(n), F, sched)
        assert traj.shape == (12, n)


class TestStaleHandoff:
    """The literal θ_{t-1} protocol: still converges (one-step staleness)."""

    def test_stale_converges_near_truth(self):
        F, w, n = _make_problem()
        sched = schedules.round_robin(4, 150)
        final, _ = server.run_protocol(jnp.zeros(n), F, sched, handoff="stale")
        assert float(jnp.linalg.norm(final.theta - w)) < 0.05

    def test_stale_differs_from_sequential(self):
        F, w, n = _make_problem()
        sched = schedules.round_robin(4, 2)
        seq, _ = server.run_protocol(jnp.zeros(n), F, sched)
        sta, _ = server.run_protocol(jnp.zeros(n), F, sched, handoff="stale")
        assert not jnp.allclose(seq.theta, sta.theta)

    def test_unknown_handoff_raises(self):
        st = server.init_server(jnp.zeros(3))
        with pytest.raises(ValueError):
            server.contact(st, jnp.ones(3), handoff="bogus")


class TestAsyncSchedule:
    """Paper §5: S_t ~ S with p(S=i) > 0 ∀i ⇒ convergence preserved."""

    def test_async_converges(self):
        F, w, n = _make_problem()
        sched = schedules.asynchronous(jax.random.key(0), 4, 600)
        final, _ = server.run_protocol(jnp.zeros(n), F, sched)
        assert float(jnp.linalg.norm(final.theta - w)) < 2e-2

    def test_every_node_contacts(self):
        sched = schedules.asynchronous(jax.random.key(1), 8, 400)
        assert float(schedules.coverage(sched, 8)) == 1.0

    def test_zero_prob_rejected(self):
        probs = jnp.asarray([0.5, 0.5, 0.0, 0.0])
        with pytest.raises(ValueError):
            schedules.asynchronous(jax.random.key(0), 4, 10, probs=probs)

    def test_work_proportional(self):
        p = schedules.work_proportional_probs(jnp.asarray([10.0, 20.0, 40.0]))
        np.testing.assert_allclose(jnp.sum(p), 1.0, rtol=1e-6)
        assert p[0] > p[1] > p[2]  # smaller shard → contacts more often

    def test_nonuniform_distribution_respected(self):
        probs = jnp.asarray([0.7, 0.1, 0.1, 0.1])
        sched = schedules.asynchronous(jax.random.key(2), 4, 4000, probs=probs)
        frac0 = float(jnp.mean((sched == 0).astype(jnp.float32)))
        assert abs(frac0 - 0.7) < 0.05


class TestServerState:
    def test_contact_records_and_hands_back(self):
        st = server.init_server(jnp.zeros(2))
        st, rec = server.contact(st, jnp.ones(2))
        assert int(st.t) == 1
        np.testing.assert_array_equal(st.theta, jnp.ones(2))
        np.testing.assert_array_equal(st.theta_prev, jnp.zeros(2))
        np.testing.assert_array_equal(rec, jnp.ones(2))  # sequential

    def test_pull_returns_current(self):
        st = server.init_server(jnp.full((2,), 3.0))
        np.testing.assert_array_equal(server.pull(st), jnp.full((2,), 3.0))

    def test_pytree_thetas(self):
        theta = {"w": jnp.zeros((2, 2)), "b": jnp.zeros(2)}

        def F(k, th):
            return jax.tree.map(lambda x: x + 1.0, th)

        final, _ = server.run_protocol(theta, F, schedules.round_robin(2, 3))
        np.testing.assert_allclose(final.theta["b"], jnp.full((2,), 6.0))
