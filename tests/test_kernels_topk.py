"""Top-k compression Pallas kernel vs exact oracle."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.topk_compress import ops, ref

CASES = [((4096,), 100), ((128, 300), 500), ((10000,), 1), ((8192,), 8191), ((513,), 64)]


@pytest.mark.parametrize("case", CASES)
def test_topk_exact(case):
    shape, k = case
    x = jax.random.normal(jax.random.key(k), shape)
    out = ops.topk_sparsify(x, k)
    exp = ref.topk_sparsify_ref(x, k)
    assert int(jnp.sum(out != 0)) == k
    assert bool(jnp.allclose(out, exp))


def test_values_preserved():
    x = jax.random.normal(jax.random.key(2), (2048,))
    out = ops.topk_sparsify(x, 50)
    nz = out != 0
    assert bool(jnp.all(out[nz] == x[nz]))


def test_kept_dominate_dropped():
    x = jax.random.normal(jax.random.key(3), (2048,))
    out = ops.topk_sparsify(x, 64)
    kept_min = jnp.min(jnp.abs(out[out != 0]))
    dropped_max = jnp.max(jnp.abs(jnp.where(out == 0, x, 0.0)))
    assert float(kept_min) >= float(dropped_max)


def test_k_larger_than_size():
    x = jax.random.normal(jax.random.key(4), (100,))
    out = ops.topk_sparsify(x, 1000)
    assert bool(jnp.allclose(out, x))
