"""Equivalence + behavior tests for the unified ``repro.api.fit`` engine.

The redesign's contract: the five transports reproduce the historical
per-algorithm loops — the sequential-server path matches
``core.server.run_protocol`` BIT-exactly, the allreduce path matches the
historical ``distributed_gd`` arithmetic (golden reference inlined here),
and compression composes with any transport while the ledger reports the
savings.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import schedules, server
from repro.core.allreduce import server_allreduce
from repro.core.staleness import delay_init, delay_push_pop
from repro.ml.linear import lsq_loss


def _make_problem(K=4, Nk=10, n=5, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(K, Nk, n)))
    w = jnp.asarray(rng.normal(size=(n,)))
    y = jnp.einsum("kni,i->kn", X, w)
    lr = 0.05

    def F(k, theta):
        Xk, yk = X[k], y[k]
        g = Xk.T @ (Xk @ theta - yk) / Nk
        return theta - lr * g

    return F, X, y, w, n


class TestServerEquivalence:
    """fit(transport="sequential_server") ≡ run_protocol, bit-exactly."""

    def test_sequential_bit_exact(self):
        F, X, y, w, n = _make_problem()
        sched = schedules.round_robin(4, 5)
        final, traj = server.run_protocol(jnp.zeros(n), F, sched)
        res = api.fit(
            api.FunctionStrategy(F, num_nodes=4),
            transport="sequential_server",
            schedule=sched,
            theta0=jnp.zeros(n),
        )
        np.testing.assert_array_equal(np.asarray(res.theta), np.asarray(final.theta))
        np.testing.assert_array_equal(np.asarray(res.trajectory), np.asarray(traj))

    def test_stale_bit_exact(self):
        F, X, y, w, n = _make_problem()
        sched = schedules.asynchronous(jax.random.key(3), 4, 40)
        final, traj = server.run_protocol(jnp.zeros(n), F, sched, handoff="stale")
        res = api.fit(
            api.FunctionStrategy(F, num_nodes=4),
            transport="stale_server",
            schedule=sched,
            theta0=jnp.zeros(n),
        )
        np.testing.assert_array_equal(np.asarray(res.theta), np.asarray(final.theta))
        np.testing.assert_array_equal(np.asarray(res.trajectory), np.asarray(traj))

    def test_server_ledger_charges_every_contact(self):
        F, X, y, w, n = _make_problem()
        sched = schedules.round_robin(4, 5)
        res = api.fit(
            api.FunctionStrategy(F, num_nodes=4),
            transport="sequential_server",
            schedule=sched,
            theta0=jnp.zeros(n),
        )
        per_contact = 2 * n * 4  # push + handoff of the f32 θ
        assert res.ledger.total_bytes == len(sched) * per_contact
        assert res.ledger.rounds == len(sched)


class TestAllreduceEquivalence:
    """fit(transport="allreduce") ≡ the historical distributed_gd loop."""

    @staticmethod
    def _golden_gd(Xs, ys, *, loss, lr, steps, l2=0.0):
        """The pre-redesign ml.linear.distributed_gd arithmetic, verbatim."""
        K, Nk, n = Xs.shape
        theta = jnp.zeros((n,))
        weights = jnp.full((K,), Nk / (K * Nk))
        grad_local = jax.vmap(jax.grad(loss), in_axes=(None, 0, 0))

        def step(theta, _):
            gs = grad_local(theta, Xs, ys)
            g = server_allreduce(gs * weights[:, None], op="sum") + l2 * theta
            theta_new = theta - lr * g
            cur = jnp.mean(
                jax.vmap(loss, in_axes=(None, 0, 0))(theta_new, Xs, ys)
            )
            return theta_new, cur

        return jax.lax.scan(step, theta, None, length=steps)

    def test_matches_golden_trajectory(self):
        _, X, y, w, n = _make_problem()
        theta_ref, losses_ref = self._golden_gd(X, y, loss=lsq_loss, lr=0.1, steps=60)
        res = api.fit(
            api.GradientDescent(lsq_loss, lr=0.1),
            (X, y),
            transport="allreduce",
            steps=60,
        )
        np.testing.assert_array_equal(np.asarray(res.theta), np.asarray(theta_ref))
        np.testing.assert_array_equal(
            np.asarray(res.trajectory), np.asarray(losses_ref)
        )

    def test_allreduce_ledger_cost_model(self):
        _, X, y, w, n = _make_problem()
        res = api.fit(
            api.GradientDescent(lsq_loss, lr=0.1), (X, y),
            transport="allreduce", steps=10,
        )
        assert res.ledger.total_bytes == 10 * 2 * 4 * n * 4  # K pushes + K pulls
        assert res.ledger.rounds == 10

    def test_converges_to_truth(self):
        _, X, y, w, n = _make_problem()
        res = api.fit(
            api.GradientDescent(lsq_loss, lr=0.1), (X, y),
            transport="allreduce", steps=400,
        )
        assert float(jnp.linalg.norm(res.theta - w)) < 0.05


class TestDelayLine:
    def test_staleness_zero_equals_allreduce(self):
        _, X, y, w, n = _make_problem()
        a = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=30)
        d = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="delay_line", staleness=0, steps=30)
        np.testing.assert_array_equal(np.asarray(a.theta), np.asarray(d.theta))

    def test_matches_manual_delay_line(self):
        """fit(delay_line, D) ≡ hand-rolled core.staleness loop."""
        _, X, y, w, n = _make_problem()
        D, lr, steps = 2, 0.1, 40
        strategy = api.GradientDescent(lsq_loss, lr=lr)
        res = api.fit(strategy, (X, y), transport="delay_line",
                      staleness=D, steps=steps)

        K, Nk = X.shape[0], X.shape[1]
        weights = jnp.full((K,), 1.0 / K)
        grad_local = jax.vmap(jax.grad(lsq_loss), in_axes=(None, 0, 0))
        theta = jnp.zeros(n)
        delay = delay_init(jnp.zeros(n), D)
        for _ in range(steps):
            g = server_allreduce(
                grad_local(theta, X, y) * weights[:, None], op="sum"
            )
            delay, g_stale = delay_push_pop(delay, g)
            theta = theta - lr * g_stale
        np.testing.assert_allclose(
            np.asarray(res.theta), np.asarray(theta), rtol=1e-6, atol=1e-7
        )

    def test_delayed_still_converges(self):
        _, X, y, w, n = _make_problem()
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                      transport="delay_line", staleness=3, steps=500)
        assert float(jnp.linalg.norm(res.theta - w)) < 0.1


class TestAdmmConsensus:
    def test_matches_direct_consensus_admm(self):
        from repro.core.admm import consensus_admm
        from repro.ml.linear import lasso_prox_builder

        _, X, y, w, n = _make_problem()
        res = api.fit(
            api.ProxStrategy(lasso_prox_builder), (X, y),
            transport="admm_consensus", steps=50, rho=1.0, g="l1", g_lam=0.1,
        )
        ref = consensus_admm(
            lasso_prox_builder((X, y)), 4, n, rho=1.0, g="l1", g_lam=0.1, iters=50
        )
        np.testing.assert_array_equal(np.asarray(res.theta), np.asarray(ref.z))
        np.testing.assert_array_equal(
            np.asarray(res.trajectory), np.asarray(ref.history)
        )
        assert res.metrics["admm"].z is not None

    def test_two_allreduces_per_iteration(self):
        from repro.ml.linear import lasso_prox_builder

        _, X, y, w, n = _make_problem()
        res = api.fit(
            api.ProxStrategy(lasso_prox_builder), (X, y),
            transport="admm_consensus", steps=25, g="l1", g_lam=0.1,
        )
        assert res.ledger.rounds == 2 * 25
        assert res.ledger.total_bytes == 25 * 2 * 2 * 4 * n * 4

    def test_compressed_wire_rejected(self):
        from repro.ml.linear import lasso_prox_builder

        _, X, y, w, n = _make_problem()
        with pytest.raises(ValueError, match="dense"):
            api.fit(
                api.ProxStrategy(lasso_prox_builder), (X, y),
                transport="admm_consensus", steps=5, wire="topk:0.5",
            )

    def test_warm_start_rejected_not_ignored(self):
        from repro.ml.linear import lasso_prox_builder

        _, X, y, w, n = _make_problem()
        with pytest.raises(ValueError, match="one-shot"):
            api.fit(
                api.ProxStrategy(lasso_prox_builder), (X, y),
                transport="admm_consensus", steps=5, theta0=jnp.zeros(n),
            )


class TestCompressionThroughTransport:
    """Satellite: top-k + error feedback composed with the stale_server
    transport converges AND reports fewer ledger bytes than dense push."""

    def test_topk_ef_stale_server(self):
        F, X, y, w, n = _make_problem()
        sched = schedules.round_robin(4, 100)
        strategy = api.FunctionStrategy(F, num_nodes=4)
        dense = api.fit(strategy, transport="stale_server",
                        schedule=sched, theta0=jnp.zeros(n))
        comp = api.fit(strategy, transport="stale_server", wire="topk:0.25+ef",
                       schedule=sched, theta0=jnp.zeros(n))
        # converges: close to the truth and to the dense solution
        assert float(jnp.linalg.norm(comp.theta - w)) < 0.1
        assert float(jnp.linalg.norm(comp.theta - dense.theta)) < 0.1
        # cheaper: uplink strictly below the dense push cost
        assert comp.ledger.uplink_bytes < dense.ledger.uplink_bytes
        assert dense.ledger.uplink_bytes == len(sched) * n * 4

    def test_error_feedback_beats_plain_topk(self):
        F, X, y, w, n = _make_problem()
        sched = schedules.round_robin(4, 150)
        strategy = api.FunctionStrategy(F, num_nodes=4)
        plain = api.fit(strategy, transport="stale_server", wire="topk:0.25",
                        schedule=sched, theta0=jnp.zeros(n))
        ef = api.fit(strategy, transport="stale_server", wire="topk:0.25+ef",
                     schedule=sched, theta0=jnp.zeros(n))
        err_plain = float(jnp.linalg.norm(plain.theta - w))
        err_ef = float(jnp.linalg.norm(ef.theta - w))
        assert err_ef <= err_plain + 1e-6

    def test_compressed_allreduce_runs(self):
        _, X, y, w, n = _make_problem()
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", wire="int8", steps=50)
        assert float(res.trajectory[-1]) < float(res.trajectory[0])
        dense_up = 50 * 4 * n * 4
        assert res.ledger.uplink_bytes != dense_up  # int8 metering applied


class TestStreamAndResume:
    def test_chunked_carry_matches_single_run(self):
        """fit → carry → fit reproduces one uninterrupted run (the
        launch/train.py driving pattern)."""
        from repro.api.strategy import OptimizerStrategy
        from repro.optim import adam

        rng = np.random.default_rng(1)
        Xb = jnp.asarray(rng.normal(size=(8, 4, 3)))  # 8 steps of batches
        yb = jnp.asarray(rng.normal(size=(8, 4)))
        theta0 = jnp.zeros((3,))

        def loss_fn(theta, batch):
            Xt, yt = batch
            return 0.5 * jnp.mean((Xt @ theta - yt) ** 2)

        def run(chunks):
            strategy = OptimizerStrategy(loss_fn, adam(0.1))
            theta, carry = theta0, None
            losses = []
            for lo, hi in chunks:
                stream = (Xb[lo:hi], yb[lo:hi])
                res = api.fit(strategy, None, transport="delay_line",
                              staleness=1, wire="topk:0.5+ef",
                              stream=stream, theta0=theta, carry=carry)
                theta, carry = res.theta, res.metrics["carry"]
                losses.extend(np.asarray(res.trajectory).tolist())
            return theta, losses

        t_full, l_full = run([(0, 8)])
        t_chunk, l_chunk = run([(0, 3), (3, 8)])
        np.testing.assert_allclose(np.asarray(t_full), np.asarray(t_chunk),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(l_full, l_chunk, rtol=1e-6, atol=1e-7)


class TestCarryResumeEquivalence:
    """fit(steps=T) ≡ fit(steps=T/2) then fit(carry=..., steps=T/2) — the
    split must be invisible: θ, the concatenated trajectory, AND the
    summed ledger totals all match the uninterrupted run."""

    @pytest.mark.parametrize(
        "transport,kw",
        [("allreduce", {}), ("delay_line", {"staleness": 2})],
    )
    def test_split_matches_full(self, transport, kw):
        from repro.ml.linear import lsq_loss

        _, X, y, w, n = _make_problem()
        T = 40
        full = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport=transport, steps=T, **kw)
        a = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport=transport, steps=T // 2, **kw)
        b = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport=transport, steps=T // 2,
                    carry=a.metrics["carry"], **kw)
        np.testing.assert_array_equal(np.asarray(b.theta), np.asarray(full.theta))
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(a.trajectory), np.asarray(b.trajectory)]),
            np.asarray(full.trajectory),
        )
        assert (a.ledger.uplink_bytes + b.ledger.uplink_bytes
                == full.ledger.uplink_bytes)
        assert (a.ledger.downlink_bytes + b.ledger.downlink_bytes
                == full.ledger.downlink_bytes)
        assert a.ledger.rounds + b.ledger.rounds == full.ledger.rounds

    @pytest.mark.parametrize(
        "transport,kw",
        [("allreduce", {}), ("delay_line", {"staleness": 2})],
    )
    def test_split_matches_full_compressed(self, transport, kw):
        """Same invariance with a stateful (EF) wire: the residuals ride
        the carry."""
        from repro.ml.linear import lsq_loss

        _, X, y, w, n = _make_problem()
        T = 40
        kw = dict(kw, wire="topk:0.5+ef")
        full = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport=transport, steps=T, **kw)
        a = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport=transport, steps=T // 2, **kw)
        b = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport=transport, steps=T // 2,
                    carry=a.metrics["carry"], **kw)
        np.testing.assert_array_equal(np.asarray(b.theta), np.asarray(full.theta))
        assert (a.ledger.total_bytes + b.ledger.total_bytes
                == full.ledger.total_bytes)


class TestServerResume:
    def test_carry_resumes_without_theta0(self):
        """A server-transport run can continue from carry alone — the
        resume token holds the full server state."""
        F, X, y, w, n = _make_problem()
        strategy = api.FunctionStrategy(F, num_nodes=4)
        full = api.fit(strategy, transport="sequential_server",
                       schedule=schedules.round_robin(4, 6),
                       theta0=jnp.zeros(n))
        first = api.fit(strategy, transport="sequential_server",
                        schedule=schedules.round_robin(4, 2),
                        theta0=jnp.zeros(n))
        second = api.fit(strategy, transport="sequential_server",
                         schedule=schedules.round_robin(4, 4),
                         carry=first.metrics["carry"])
        np.testing.assert_array_equal(
            np.asarray(full.theta), np.asarray(second.theta)
        )


class TestLedgerExactness:
    def test_byte_counts_are_int64_exact(self):
        """Per-round byte counts must not pass through f32 (a dense push of
        a >4M-param model would lose low bits)."""
        F, X, y, w, n = _make_problem()
        res = api.fit(api.FunctionStrategy(F, num_nodes=4),
                      transport="sequential_server",
                      schedule=schedules.round_robin(4, 3),
                      theta0=jnp.zeros(n))
        ups = res.metrics["uplink_bytes_per_round"]
        assert ups.dtype == np.int64
        big = 2**24 + 4  # not representable in f32
        assert int(np.asarray(big, dtype=ups.dtype)) == big


class TestShims:
    """Old public entry points stay importable and delegate to repro.api."""

    def test_distributed_gd_shim_warns_and_matches(self):
        _, X, y, w, n = _make_problem()
        from repro.ml import linear

        with pytest.warns(DeprecationWarning):
            old = linear.distributed_gd(X, y, steps=30, lr=0.1)
        new = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=30)
        np.testing.assert_array_equal(np.asarray(old.theta), np.asarray(new.theta))
        assert old.ledger.summary() == new.ledger.summary()

    def test_all_shims_importable(self):
        from repro.ml.kwindows import distributed_kwindows  # noqa: F401
        from repro.ml.linear import (  # noqa: F401
            admm_lasso,
            distributed_gd,
            distributed_lbfgs,
        )
        from repro.ml.svm import cascade_svm, consensus_svm  # noqa: F401

    def test_kwindows_shim_fills_ledger(self):
        from repro.core.allreduce import CommLedger
        from repro.ml import kwindows

        rng = np.random.default_rng(2)
        Xs = jnp.asarray(rng.normal(size=(3, 40, 2)))
        led = CommLedger()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            win = kwindows.distributed_kwindows(
                jax.random.key(0), Xs, num_windows=4, r=1.0, ledger=led
            )
        assert isinstance(win, kwindows.KWindows)
        assert led.total_bytes > 0 and led.rounds == 3


class TestEngineErrors:
    def test_unknown_transport(self):
        with pytest.raises(ValueError, match="unknown transport"):
            api.make_transport("gossip")

    def test_unknown_wire(self):
        with pytest.raises(ValueError, match="unknown wire"):
            api.make_wire("zstd")

    def test_server_needs_schedule(self):
        F, X, y, w, n = _make_problem()
        with pytest.raises(ValueError, match="schedule"):
            api.fit(api.FunctionStrategy(F, num_nodes=4),
                    transport="sequential_server", theta0=jnp.zeros(n))

    def test_update_needs_steps(self):
        _, X, y, w, n = _make_problem()
        with pytest.raises(ValueError, match="steps"):
            api.fit(api.GradientDescent(lsq_loss), (X, y), transport="allreduce")

    def test_unsupported_family_raises(self):
        F, X, y, w, n = _make_problem()
        strategy = api.FunctionStrategy(F, num_nodes=4)
        with pytest.raises(NotImplementedError, match="update transports"):
            api.fit(strategy, (X, y), transport="allreduce", steps=3,
                    theta0=jnp.zeros(n))

    def test_all_transports_listed(self):
        assert set(api.TRANSPORTS) == {
            "sequential_server", "stale_server", "delay_line",
            "allreduce", "admm_consensus",
        }
