"""Distributed linear & logistic regression (paper §3.1)."""

import jax.numpy as jnp
import numpy as np

from repro.data import make_feature_shards
from repro.ml import linear


def _shards(seed=1, K=4, Nk=25, n=6, noise=0.01):
    return make_feature_shards(seed, K, Nk, n, noise=noise)


def test_distributed_gd_converges():
    Xs, ys, w = _shards()
    res = linear.distributed_gd(Xs, ys, steps=400, lr=0.1)
    assert float(jnp.linalg.norm(res.theta - w)) < 0.05
    assert res.losses[-1] < res.losses[0]


def test_gd_comm_ledger_counts():
    Xs, ys, w = _shards()
    res = linear.distributed_gd(Xs, ys, steps=10)
    # one Allreduce per step: K pushes + K pulls of an n-vector (f32)
    per_round = 2 * 4 * 6 * 4
    assert res.ledger.total_bytes == 10 * per_round
    assert res.ledger.rounds == 10


def test_private_second_order_matches_ols():
    Xs, ys, w = _shards(noise=0.05)
    theta, ledger = linear.private_second_order(Xs, ys)
    Xall = Xs.reshape(-1, Xs.shape[-1])
    yall = ys.reshape(-1)
    ols = jnp.linalg.lstsq(Xall, yall)[0]
    np.testing.assert_allclose(theta, ols, atol=1e-4)
    # wire cost independent of N: K·(n² + n) numbers up, n down
    assert ledger.uplink_bytes == 4 * (6 * 6 + 6) * 4
    assert ledger.downlink_bytes == 6 * 4


def test_admm_lasso_matches_ista():
    Xs, ys, w = _shards(noise=0.02)
    res = linear.admm_lasso(Xs, ys, lam=0.4, iters=300)
    Xall = Xs.reshape(-1, Xs.shape[-1])
    yall = ys.reshape(-1)
    ref = linear.ista_lasso(Xall, yall, 0.4, iters=5000)
    np.testing.assert_allclose(res.z, ref, atol=1e-3)


def test_lasso_sparsity_increases_with_lambda():
    Xs, ys, w = _shards(noise=0.02)
    z_small = linear.admm_lasso(Xs, ys, lam=0.01, iters=200).z
    z_big = linear.admm_lasso(Xs, ys, lam=100.0, iters=300).z
    assert int(jnp.sum(jnp.abs(z_big) < 1e-6)) > int(jnp.sum(jnp.abs(z_small) < 1e-6))


def test_distributed_lbfgs_beats_gd_per_iteration():
    Xs, ys, w = _shards(seed=3)
    yc = jnp.sign(ys)
    lb = linear.distributed_lbfgs(Xs, yc, steps=30, l2=1e-3)
    gd = linear.distributed_gd(
        Xs, yc, loss=linear.logistic_loss, steps=30, lr=0.5, l2=1e-3
    )
    assert float(lb.losses[-1]) < float(gd.losses[-1])
    # [5]'s point: exactly one Allreduce per iteration
    assert lb.ledger.rounds == 31  # steps + initial gradient
