"""Client-fleet fault suite: FaultPlan semantics, seeded determinism,
privacy wires (dp / secagg / chains), and placement equivalence.

The contracts this file pins down:

* FaultPlan draws are counter-addressed — resuming mid-plan from a carry
  replays the identical schedule, so split runs are BITWISE equal to the
  uninterrupted run (on the same executor).
* Dropout masks survivors out of a SUM aggregate; the ledger meters
  survivors only, host-exactly (``live(t) × push_bytes``).
* Quorum rolls back whole rounds (θ, strategy state, wire state, delay
  line); survivor uplinks are still charged, downlink only on commit.
* Empty rounds are legal: ``dropout_p=1.0`` runs, charges zero bytes,
  and by-hop attribution materializes zero buckets instead of raising.
* ``dp:<clip>,<sigma>`` clips per-node L2 and adds seeded Gaussian noise
  (statistically checked); ``secagg`` per-node payloads are masked while
  the masked fit is bitwise-identical to the dense fit.
* Mesh placements agree with local to fp tolerance, mesh ≡ multipod
  bitwise on a shared mesh, and round-varying masks compile ONE program
  (8-fake-device subprocess cases).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api.faults import FaultCarry, FaultDraws, FaultPlan, make_fault_plan
from repro.api.wire import make_wire
from repro.core.schedules import round_robin
from repro.ml.linear import lsq_loss


def _make_problem(K=8, Nk=10, n=5, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(K, Nk, n)))
    w = jnp.asarray(rng.normal(size=(n,)))
    y = jnp.einsum("kni,i->kn", X, w)
    return X, y, w, n


def _gd():
    return api.GradientDescent(lsq_loss, lr=0.1)


class TestFaultPlan:
    """The plan object itself: validation, draw determinism, cache keys."""

    def test_validation(self):
        with pytest.raises(ValueError, match="dropout_p"):
            FaultPlan(seed=0, dropout_p=1.5)
        with pytest.raises(ValueError, match="straggler"):
            FaultPlan(seed=0, straggler=-1)
        with pytest.raises(ValueError, match="quorum"):
            FaultPlan(seed=0, quorum=0)
        with pytest.raises(TypeError, match="FaultPlan"):
            make_fault_plan({"dropout_p": 0.5})
        assert make_fault_plan(None) is None
        plan = FaultPlan(seed=3, dropout_p=0.25)
        assert make_fault_plan(plan) is plan

    def test_draws_are_deterministic_and_counter_addressed(self):
        plan = FaultPlan(seed=7, dropout_p=0.3, straggler=3)
        full = plan.draws(0, 20, 4)
        assert isinstance(full, FaultDraws)
        assert full.u.shape == (20, 4) and full.u.dtype == np.float32
        assert full.lag.shape == (20, 4) and full.lag.dtype == np.int32
        assert np.all((0 <= full.lag) & (full.lag <= 3))
        # same call → bitwise identical
        np.testing.assert_array_equal(full.u, plan.draws(0, 20, 4).u)
        # a window resumed at t=8 is the tail of the full window
        tail = plan.draws(8, 12, 4)
        np.testing.assert_array_equal(tail.u, full.u[8:])
        np.testing.assert_array_equal(tail.lag, full.lag[8:])

    def test_streams_and_seeds_independent(self):
        a = FaultPlan(seed=1, straggler=5).draws(0, 10, 4)
        b = FaultPlan(seed=2, straggler=5).draws(0, 10, 4)
        assert not np.array_equal(a.u, b.u)
        assert not np.array_equal(a.lag, b.lag)

    def test_cache_token_excludes_seed(self):
        # plans differing only in seed share one compiled program
        a = FaultPlan(seed=1, dropout_p=0.3, quorum=2)
        b = FaultPlan(seed=99, dropout_p=0.3, quorum=2)
        assert a.cache_token() == b.cache_token()
        assert a.cache_token() != FaultPlan(seed=1, dropout_p=0.4).cache_token()
        # a swept dropout_p is traced per scenario → not baked in the key
        assert a.cache_token(dropout_swept=True) \
            == b.cache_token(dropout_swept=True)
        assert a.cache_token(dropout_swept=True) != a.cache_token()

    def test_describe_round_trips_the_spec(self):
        plan = FaultPlan(seed=5, dropout_p=0.2, straggler=1, quorum=3)
        assert plan.describe() == {
            "seed": 5, "dropout_p": 0.2, "straggler": 1, "quorum": 3,
        }


class TestSeededDeterminism:
    """Bitwise-identical FitResult across repeats and across resume."""

    def test_repeat_is_bitwise(self, fault_plan):
        X, y, w, n = _make_problem()
        kw = dict(transport="allreduce", steps=25, faults=fault_plan)
        a = api.fit(_gd(), (X, y), **kw)
        b = api.fit(_gd(), (X, y), **kw)
        np.testing.assert_array_equal(np.asarray(a.theta), np.asarray(b.theta))
        np.testing.assert_array_equal(
            np.asarray(a.trajectory), np.asarray(b.trajectory)
        )
        assert a.ledger.uplink_bytes == b.ledger.uplink_bytes
        assert a.metrics["faults"] == fault_plan.describe()

    def test_resume_mid_plan_is_bitwise(self, fault_plan):
        X, y, w, n = _make_problem()
        kw = dict(transport="allreduce", faults=fault_plan)
        full = api.fit(_gd(), (X, y), steps=20, **kw)
        first = api.fit(_gd(), (X, y), steps=10, **kw)
        carry = first.metrics["carry"]
        assert isinstance(carry, FaultCarry) and carry.next_round == 10
        second = api.fit(_gd(), (X, y), steps=10, carry=carry, **kw)
        np.testing.assert_array_equal(
            np.asarray(second.theta), np.asarray(full.theta)
        )
        assert first.ledger.uplink_bytes + second.ledger.uplink_bytes \
            == full.ledger.uplink_bytes

    def test_faulted_differs_from_fault_free(self):
        X, y, w, n = _make_problem()
        clean = api.fit(_gd(), (X, y), transport="allreduce", steps=25)
        faulted = api.fit(_gd(), (X, y), transport="allreduce", steps=25,
                          faults=FaultPlan(seed=11, dropout_p=0.5))
        assert not np.array_equal(
            np.asarray(clean.theta), np.asarray(faulted.theta)
        )

    def test_zero_plan_matches_fault_free_bitwise(self):
        # dropout_p=0 with no straggler/quorum: every node always alive —
        # the masked path must reduce to the stock one exactly
        X, y, w, n = _make_problem()
        clean = api.fit(_gd(), (X, y), transport="allreduce", steps=20)
        zero = api.fit(_gd(), (X, y), transport="allreduce", steps=20,
                       faults=FaultPlan(seed=11))
        np.testing.assert_array_equal(
            np.asarray(clean.theta), np.asarray(zero.theta)
        )
        assert clean.ledger.uplink_bytes == zero.ledger.uplink_bytes

    def test_carry_cross_wiring_rejected(self):
        X, y, w, n = _make_problem()
        plan = FaultPlan(seed=11, dropout_p=0.3)
        clean = api.fit(_gd(), (X, y), transport="allreduce", steps=5)
        faulted = api.fit(_gd(), (X, y), transport="allreduce", steps=5,
                          faults=plan)
        with pytest.raises(ValueError, match="faults="):
            api.fit(_gd(), (X, y), transport="allreduce", steps=5,
                    carry=clean.metrics["carry"], faults=plan)
        with pytest.raises(ValueError, match="faults="):
            api.fit(_gd(), (X, y), transport="allreduce", steps=5,
                    carry=faulted.metrics["carry"])


class TestDropoutAccounting:
    """The ledger meters SURVIVORS, host-exactly from the plan's draws."""

    def test_survivor_bytes_exact(self):
        X, y, w, n = _make_problem()
        plan = FaultPlan(seed=11, dropout_p=0.4)
        T, K = 30, X.shape[0]
        res = api.fit(_gd(), (X, y), transport="allreduce", steps=T,
                      faults=plan)
        live = (plan.draws(0, T, K).u >= plan.dropout_p).sum(axis=1)
        per_push = n * 4  # dense float32 θ
        assert res.ledger.uplink_bytes == int(live.sum()) * per_push
        assert res.ledger.downlink_bytes == int(live.sum()) * per_push
        assert res.ledger.rounds == T

    def test_survivor_bytes_with_compression(self):
        X, y, w, n = _make_problem(n=8)
        plan = FaultPlan(seed=11, dropout_p=0.4)
        T, K = 30, X.shape[0]
        res = api.fit(_gd(), (X, y), transport="allreduce", steps=T,
                      wire="topk:0.5+ef", faults=plan)
        live = (plan.draws(0, T, K).u >= plan.dropout_p).sum(axis=1)
        up_each = make_wire("topk:0.5+ef").push_bytes(jnp.zeros((8,)))
        assert res.ledger.uplink_bytes == int(live.sum()) * up_each
        # downlink hands dense θ back to survivors
        assert res.ledger.downlink_bytes == int(live.sum()) * 8 * 4

    def test_quorum_charges_uplink_only_on_aborted_rounds(self):
        X, y, w, n = _make_problem()
        plan = FaultPlan(seed=11, dropout_p=0.5, quorum=5)
        T, K = 40, X.shape[0]
        res = api.fit(_gd(), (X, y), transport="allreduce", steps=T,
                      faults=plan)
        live = (plan.draws(0, T, K).u >= plan.dropout_p).sum(axis=1)
        committed = live >= plan.quorum
        assert 0 < committed.sum() < T  # the seed exercises both branches
        per = n * 4
        assert res.ledger.uplink_bytes == int(live.sum()) * per
        assert res.ledger.downlink_bytes \
            == int(np.where(committed, live, 0).sum()) * per

    def test_all_dead_round_is_legal_and_free(self):
        # dropout_p=1.0: u ∈ [0, 1) never reaches the threshold — every
        # round is empty.  θ must stay put and the wire must charge zero.
        X, y, w, n = _make_problem()
        res = api.fit(_gd(), (X, y), transport="allreduce", steps=10,
                      theta0=jnp.zeros((n,)),
                      faults=FaultPlan(seed=11, dropout_p=1.0, quorum=1))
        np.testing.assert_array_equal(np.asarray(res.theta), np.zeros((n,)))
        assert res.ledger.uplink_bytes == 0
        assert res.ledger.downlink_bytes == 0
        assert res.ledger.rounds == 10

    def test_empty_rounds_attribute_zero_hop_buckets(self):
        # by-hop attribution over a zero-message run keeps the summary
        # shape (zero buckets) instead of raising — empty rounds are legal
        res = api.fit(_gd(), _make_problem()[:2], transport="allreduce",
                      steps=5, executor="multipod",
                      faults=FaultPlan(seed=11, dropout_p=1.0))
        assert res.ledger.total_bytes == 0
        by_hop = res.ledger.summary()["by_hop"]
        assert set(by_hop) == {"intra_pod", "inter_pod"}
        assert all(v["total_bytes"] == 0 for v in by_hop.values())


class TestStraggler:
    """Straggler lags deepen the delay line and stale the aggregate."""

    def test_straggler_changes_trajectory_not_bytes(self):
        X, y, w, n = _make_problem()
        base = api.fit(_gd(), (X, y), transport="allreduce", steps=25,
                       faults=FaultPlan(seed=11))
        lagged = api.fit(_gd(), (X, y), transport="allreduce", steps=25,
                         faults=FaultPlan(seed=11, straggler=3))
        # everyone still participates — bytes identical, dynamics stale
        assert lagged.ledger.uplink_bytes == base.ledger.uplink_bytes
        assert not np.array_equal(
            np.asarray(base.trajectory), np.asarray(lagged.trajectory)
        )

    def test_straggler_zero_lag_draws_match_baseline(self):
        # straggler=0 draws all-zero lags → identical to the no-straggler
        # plan bitwise (the deeper-buffer path only engages when > 0)
        X, y, w, n = _make_problem()
        a = api.fit(_gd(), (X, y), transport="delay_line", steps=20,
                    staleness=1, faults=FaultPlan(seed=11, dropout_p=0.3))
        b = api.fit(_gd(), (X, y), transport="delay_line", steps=20,
                    staleness=1,
                    faults=FaultPlan(seed=11, dropout_p=0.3, straggler=0))
        np.testing.assert_array_equal(np.asarray(a.theta), np.asarray(b.theta))

    def test_straggler_composes_with_staleness(self):
        X, y, w, n = _make_problem()
        res = api.fit(_gd(), (X, y), transport="delay_line", steps=25,
                      staleness=2,
                      faults=FaultPlan(seed=11, straggler=2))
        assert np.all(np.isfinite(np.asarray(res.theta)))
        assert res.metrics["faults"]["straggler"] == 2


class TestServerFaults:
    """§5 server transports: dropout only — a dead contact is a no-op."""

    def test_dropout_contact_noop_and_metered(self):
        X, y, w, n = _make_problem(K=4)
        sched = round_robin(4, 24)
        plan = FaultPlan(seed=11, dropout_p=0.5)
        res = api.fit(_gd(), (X, y), transport="sequential_server",
                      schedule=sched, faults=plan)
        clean = api.fit(_gd(), (X, y), transport="sequential_server",
                        schedule=sched)
        assert not np.array_equal(
            np.asarray(res.theta), np.asarray(clean.theta)
        )
        u = plan.draws(0, len(sched), 4).u
        alive = u[np.arange(len(sched)), np.asarray(sched)] >= plan.dropout_p
        per = n * 4
        assert res.ledger.uplink_bytes == int(alive.sum()) * per
        assert res.ledger.downlink_bytes == int(alive.sum()) * per

    def test_repeat_and_resume_bitwise(self):
        X, y, w, n = _make_problem(K=4)
        plan = FaultPlan(seed=11, dropout_p=0.4)
        full = api.fit(_gd(), (X, y), transport="stale_server",
                       schedule=round_robin(4, 20), faults=plan)
        again = api.fit(_gd(), (X, y), transport="stale_server",
                        schedule=round_robin(4, 20), faults=plan)
        np.testing.assert_array_equal(
            np.asarray(full.theta), np.asarray(again.theta)
        )
        first = api.fit(_gd(), (X, y), transport="stale_server",
                        schedule=round_robin(4, 20)[:10], faults=plan)
        second = api.fit(_gd(), (X, y), transport="stale_server",
                         schedule=round_robin(4, 20)[10:],
                         carry=first.metrics["carry"], faults=plan)
        np.testing.assert_array_equal(
            np.asarray(second.theta), np.asarray(full.theta)
        )

    def test_straggler_and_quorum_rejected(self):
        X, y, w, n = _make_problem(K=4)
        for bad in (FaultPlan(seed=0, straggler=1), FaultPlan(seed=0, quorum=2)):
            with pytest.raises(ValueError, match="ONE node per round"):
                api.fit(_gd(), (X, y), transport="sequential_server",
                        schedule=round_robin(4, 8), faults=bad)


class TestValidation:
    """Fault-mode compatibility gates fail loudly, not silently."""

    def test_mean_aggregate_rejected(self):
        # LBFGS declares aggregate_op="mean" — masking nodes out of a
        # mean silently reweights it, so the gate must refuse
        X, y, w, n = _make_problem()
        with pytest.raises(ValueError, match="SUM aggregate"):
            api.fit(api.LBFGS(lsq_loss), (X, y), transport="allreduce",
                    steps=4, faults=FaultPlan(seed=0))

    def test_value_dependent_wire_rejected(self):
        X, y, w, n = _make_problem()
        with pytest.raises(ValueError, match="thresh"):
            api.fit(_gd(), (X, y), transport="allreduce", steps=4,
                    wire="thresh:0.1", faults=FaultPlan(seed=0))

    def test_quorum_above_fleet_rejected(self):
        X, y, w, n = _make_problem(K=4)
        with pytest.raises(ValueError, match="never be met"):
            api.fit(_gd(), (X, y), transport="allreduce", steps=4,
                    faults=FaultPlan(seed=0, quorum=5))

    def test_admm_rejected(self):
        from repro.ml.linear import lasso_prox_builder

        X, y, w, n = _make_problem(K=4)
        with pytest.raises(ValueError, match="admm"):
            api.fit(api.ProxStrategy(lasso_prox_builder), (X, y),
                    transport="admm_consensus", steps=4, g="l1", g_lam=0.1,
                    faults=FaultPlan(seed=0))

    def test_dropout_sweep_needs_a_plan(self):
        X, y, w, n = _make_problem()
        with pytest.raises(ValueError, match="needs faults="):
            api.fit(_gd(), (X, y), transport="allreduce", steps=4,
                    executor="sweep",
                    sweep={"dropout_p": jnp.asarray([0.0, 0.3])})


class TestDPWire:
    """dp:<clip>,<sigma> — per-node L2 clip + seeded Gaussian noise."""

    def test_spec_parsing(self):
        wi = make_wire("dp:1.5,0.25")
        assert (wi.dp_clip, wi.dp_sigma) == (1.5, 0.25)
        assert not wi.lossless
        with pytest.raises(ValueError, match="dp clip"):
            make_wire("dp:0,0.5")
        with pytest.raises(ValueError, match="chain"):
            make_wire("dp:1.0,0.5+ef")

    def test_clip_enforced_exactly(self):
        # sigma=0 isolates the clip: every privatized row lands at
        # L2 norm == min(‖m‖, clip)
        wi = make_wire("dp:1.0,0.0")
        msgs = jnp.asarray(
            np.random.default_rng(0).normal(size=(4, 64)) * 10.0,
            jnp.float32,
        )
        st = wi.init_state(msgs[0], 4)
        _, hat, nb = wi.encode_updates(st, msgs)
        norms = np.linalg.norm(np.asarray(hat), axis=1)
        np.testing.assert_allclose(norms, 1.0, rtol=1e-5)
        assert int(nb) == msgs.size * 4  # dense payload

    def test_small_updates_pass_unclipped(self):
        wi = make_wire("dp:100.0,0.0")
        msgs = jnp.asarray(
            np.random.default_rng(0).normal(size=(4, 16)), jnp.float32
        )
        _, hat, _ = wi.encode_updates(wi.init_state(msgs[0], 4), msgs)
        np.testing.assert_allclose(
            np.asarray(hat), np.asarray(msgs), rtol=1e-5, atol=1e-6
        )

    def test_noise_scale_statistical(self):
        # zero message → output IS the noise; empirical std over 8×4096
        # draws must sit within a few percent of dp_sigma·dp_clip
        wi = make_wire("dp:2.0,0.5")
        msgs = jnp.zeros((8, 4096), jnp.float32)
        _, hat, _ = wi.encode_updates(wi.init_state(msgs[0], 8), msgs)
        flat = np.asarray(hat).ravel()
        assert abs(flat.mean()) < 0.05
        np.testing.assert_allclose(flat.std(), 0.5 * 2.0, rtol=0.05)

    def test_noise_seeded_and_counter_advanced(self):
        wi = make_wire("dp:1.0,0.5")
        msgs = jnp.zeros((4, 32), jnp.float32)
        st = wi.init_state(msgs[0], 4)
        st1, a, _ = wi.encode_updates(st, msgs)
        _, a2, _ = wi.encode_updates(st, msgs)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(a2))
        # counters advanced → round 2 draws a fresh noise slice
        _, b, _ = wi.encode_updates(st1, msgs)
        assert not np.array_equal(np.asarray(a), np.asarray(b))
        # per-node streams differ (global index folds into the key)
        assert not np.array_equal(np.asarray(a)[0], np.asarray(a)[1])

    def test_fit_end_to_end_and_sweepable(self):
        X, y, w, n = _make_problem()
        res = api.fit(_gd(), (X, y), transport="allreduce", steps=20,
                      wire="dp:1.0,0.01")
        assert np.all(np.isfinite(np.asarray(res.theta)))
        # dp_sigma is a plain attribute → sweepable per scenario
        sw = api.fit(_gd(), (X, y), transport="allreduce", steps=20,
                     wire="dp:1.0,0.01", executor="sweep",
                     sweep={"dp_sigma": jnp.asarray([0.0, 0.01, 0.1])})
        traj = np.asarray(sw.trajectory)
        assert traj.shape[0] == 3
        # σ=0 scenario is the clipped-but-noiseless run; more noise hurts
        clipped = api.fit(_gd(), (X, y), transport="allreduce", steps=20,
                          wire="dp:1.0,0.0")
        np.testing.assert_allclose(
            traj[0, -1], np.asarray(clipped.trajectory)[-1],
            rtol=1e-5, atol=1e-6,
        )

    def test_dp_under_dropout_freezes_dead_counters(self):
        X, y, w, n = _make_problem()
        plan = FaultPlan(seed=11, dropout_p=0.4)
        a = api.fit(_gd(), (X, y), transport="allreduce", steps=15,
                    wire="dp:1.0,0.05", faults=plan)
        b = api.fit(_gd(), (X, y), transport="allreduce", steps=15,
                    wire="dp:1.0,0.05", faults=plan)
        np.testing.assert_array_equal(np.asarray(a.theta), np.asarray(b.theta))


class TestSecAggWire:
    """secagg — pairwise antisymmetric masks, exact in the aggregate."""

    def test_fit_bitwise_equals_dense(self):
        X, y, w, n = _make_problem()
        dense = api.fit(_gd(), (X, y), transport="allreduce", steps=20)
        masked = api.fit(_gd(), (X, y), transport="allreduce", steps=20,
                         wire="secagg")
        np.testing.assert_array_equal(
            np.asarray(masked.theta), np.asarray(dense.theta)
        )
        np.testing.assert_array_equal(
            np.asarray(masked.trajectory), np.asarray(dense.trajectory)
        )
        # masking never compresses: metered bytes equal the dense wire's
        assert masked.ledger.uplink_bytes == dense.ledger.uplink_bytes

    def test_payloads_masked_but_sum_recovers_aggregate(self):
        wi = make_wire("secagg")
        msgs = jnp.asarray(
            np.random.default_rng(0).normal(size=(4, 32)), jnp.float32
        )
        st = wi.init_state(msgs[0], 4)
        pay = np.asarray(wi.uplink_payloads(st, msgs))
        raw = np.asarray(msgs)
        # every individual uplink is masked away from its raw message...
        for k in range(4):
            assert not np.allclose(pay[k], raw[k], atol=1e-3)
        # ...while the pairwise masks cancel in the sum
        np.testing.assert_allclose(
            pay.sum(axis=0), raw.sum(axis=0), rtol=1e-4, atol=1e-4
        )

    def test_server_transport_rejected(self):
        X, y, w, n = _make_problem(K=4)
        with pytest.raises(NotImplementedError, match="aggregate"):
            api.fit(_gd(), (X, y), transport="sequential_server",
                    schedule=round_robin(4, 8), wire="secagg")

    def test_ef_suffix_rejected(self):
        with pytest.raises(ValueError, match="secagg"):
            make_wire("secagg+ef")


class TestChainWire:
    """'a>b' chains: stage composition, byte metering, guard rails."""

    def test_chain_parsing_and_metering(self):
        wi = make_wire("dp:1.0,0.5>topk:0.5+ef")
        assert [type(s).__name__ for s in wi.stages] == ["DPWire", "TopKWire"]
        assert not wi.lossless
        theta = jnp.zeros((12,), jnp.float32)
        # the chain's cost is the LAST re-pricing stage's (topk)
        assert wi.push_bytes(theta) == make_wire("topk:0.5+ef").push_bytes(theta)
        # a preserves_bytes tail (secagg) keeps the previous stage's price
        tail = make_wire("topk:0.5+ef>secagg")
        assert tail.push_bytes(theta) == wi.push_bytes(theta)
        assert tail.preserves_bytes is False

    def test_chain_fit_and_faults(self):
        X, y, w, n = _make_problem()
        plan = FaultPlan(seed=11, dropout_p=0.3)
        res = api.fit(_gd(), (X, y), transport="allreduce", steps=15,
                      wire="dp:1.0,0.1>topk:0.5+ef", faults=plan)
        again = api.fit(_gd(), (X, y), transport="allreduce", steps=15,
                        wire="dp:1.0,0.1>topk:0.5+ef", faults=plan)
        np.testing.assert_array_equal(
            np.asarray(res.theta), np.asarray(again.theta)
        )
        T, K = 15, X.shape[0]
        live = (plan.draws(0, T, K).u >= plan.dropout_p).sum(axis=1)
        up_each = make_wire("dp:1.0,0.1>topk:0.5+ef").push_bytes(
            jnp.zeros((n,))
        )
        assert res.ledger.uplink_bytes == int(live.sum()) * up_each

    def test_no_nesting(self):
        with pytest.raises(ValueError, match="at least two"):
            api.ChainWire([make_wire("dense")])
        with pytest.raises(ValueError, match="nest"):
            api.ChainWire([make_wire("dense"), make_wire("dp:1.0,0.1>secagg")])


class TestDropoutSweep:
    """sweep={'dropout_p': ...}: S dropout levels, ONE executable, shared
    draws (inverse-CDF coupling)."""

    def test_scenarios_match_single_runs(self):
        X, y, w, n = _make_problem()
        plan = FaultPlan(seed=11)
        levels = [0.0, 0.3, 0.6]
        sw = api.fit(_gd(), (X, y), transport="allreduce", steps=20,
                     executor="sweep", faults=plan,
                     sweep={"dropout_p": jnp.asarray(levels)})
        traj = np.asarray(sw.trajectory)
        assert traj.shape[0] == 3
        for s, p in enumerate(levels):
            single = api.fit(_gd(), (X, y), transport="allreduce", steps=20,
                             faults=FaultPlan(seed=11, dropout_p=p))
            np.testing.assert_allclose(
                traj[s], np.asarray(single.trajectory), rtol=1e-4, atol=1e-5
            )
        # per-scenario survivor accounting: (S, T) uplink rows
        per = np.asarray(sw.ledger[0].uplink_bytes if isinstance(sw.ledger, list)
                         else sw.ledger.uplink_bytes)
        assert per is not None

    def test_per_scenario_ledgers_meter_survivors(self):
        X, y, w, n = _make_problem()
        plan = FaultPlan(seed=11)
        levels = np.asarray([0.0, 0.5])
        sw = api.fit(_gd(), (X, y), transport="allreduce", steps=20,
                     executor="sweep", faults=plan,
                     sweep={"dropout_p": jnp.asarray(levels)})
        ledgers = sw.ledger if isinstance(sw.ledger, list) else [sw.ledger]
        assert len(ledgers) == 2
        T, K = 20, X.shape[0]
        u = plan.draws(0, T, K).u
        per = n * 4
        for led, p in zip(ledgers, levels):
            live = (u >= p).sum(axis=1)
            assert led.uplink_bytes == int(live.sum()) * per


class TestMeshFaultEquivalence:
    """Placement equivalence on a REAL 8-fake-device placement: local ≈
    mesh (fp-order tolerance), mesh ≡ multipod bitwise on one shared
    mesh, survivor attribution, and the single-program guarantee."""

    SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
import jax.numpy as jnp
import numpy as np
from repro import api
from repro.api.executor import clear_program_cache, program_cache_stats
from repro.api.faults import FaultPlan
from repro.core.schedules import round_robin
from repro.launch.mesh import make_multipod_mesh
from repro.ml.linear import lsq_loss

rng = np.random.default_rng(0)
K, Nk, n = 8, 10, 5
X = jnp.asarray(rng.normal(size=(K, Nk, n)))
w = jnp.asarray(rng.normal(size=(n,)))
y = jnp.einsum("kni,i->kn", X, w)
gd = lambda: api.GradientDescent(lsq_loss, lr=0.1)
plan = FaultPlan(seed=11, dropout_p=0.4, straggler=1, quorum=2)
out = {"num_devices": jax.device_count()}

# local vs mesh: same masked math, different reduction order → allclose
loc = api.fit(gd(), (X, y), transport="allreduce", steps=25, faults=plan)
mesh = api.fit(gd(), (X, y), transport="allreduce", steps=25, faults=plan,
               executor="mesh")
out["local_mesh_allclose"] = bool(np.allclose(
    np.asarray(loc.theta), np.asarray(mesh.theta), rtol=1e-5, atol=1e-6))
out["bytes_equal"] = bool(
    loc.ledger.uplink_bytes == mesh.ledger.uplink_bytes)

# mesh vs multipod ON THE SAME MESH: bitwise (the repo's §5 guarantee)
shared = make_multipod_mesh()
flat = api.fit(gd(), (X, y), transport="allreduce", steps=25, faults=plan,
               executor=api.MeshExecutor(shared))
hier = api.fit(gd(), (X, y), transport="allreduce", steps=25, faults=plan,
               executor=api.MultiPodExecutor(shared))
out["mesh_multipod_bitwise"] = bool(
    np.array_equal(np.asarray(flat.theta), np.asarray(hier.theta))
    and np.array_equal(np.asarray(flat.trajectory),
                       np.asarray(hier.trajectory)))
by_hop = hier.ledger.summary()["by_hop"]
out["survivor_hops_sum"] = bool(
    sum(v["total_bytes"] for v in by_hop.values())
    == flat.ledger.total_bytes)

# server dropout: local ≡ mesh bitwise (one contact per round — no
# reduction-order freedom)
splan = FaultPlan(seed=11, dropout_p=0.4)
sched = round_robin(K, 24)
sl = api.fit(gd(), (X, y), transport="sequential_server", schedule=sched,
             faults=splan)
sm = api.fit(gd(), (X, y), transport="sequential_server", schedule=sched,
             faults=splan, executor="mesh")
out["server_bitwise"] = bool(
    np.array_equal(np.asarray(sl.theta), np.asarray(sm.theta)))
out["server_bytes_equal"] = bool(
    sl.ledger.uplink_bytes == sm.ledger.uplink_bytes)

# ONE compiled program under round-varying masks: plans differing only
# in seed (different masks every round) share the cached executable
clear_program_cache()
api.fit(gd(), (X, y), transport="allreduce", steps=25,
        faults=FaultPlan(seed=1, dropout_p=0.4, straggler=1, quorum=2),
        executor="mesh")
api.fit(gd(), (X, y), transport="allreduce", steps=25,
        faults=FaultPlan(seed=2, dropout_p=0.4, straggler=1, quorum=2),
        executor="mesh")
out["program_cache"] = program_cache_stats()

# secagg on mesh: masked fit bitwise-identical to the dense fit
sd = api.fit(gd(), (X, y), transport="allreduce", steps=20, executor="mesh")
sa = api.fit(gd(), (X, y), transport="allreduce", steps=20, executor="mesh",
             wire="secagg")
out["secagg_mesh_bitwise"] = bool(
    np.array_equal(np.asarray(sd.theta), np.asarray(sa.theta)))
print(json.dumps(out))
"""

    def test_fault_equivalence_on_8_devices(self, fake_devices):
        out = fake_devices(self.SCRIPT)
        assert out["num_devices"] == 8
        assert out["local_mesh_allclose"]
        assert out["bytes_equal"]
        assert out["mesh_multipod_bitwise"]
        assert out["survivor_hops_sum"]
        assert out["server_bitwise"]
        assert out["server_bytes_equal"]
        assert out["program_cache"]["size"] == 1
        assert out["program_cache"]["misses"] == 1
        assert out["program_cache"]["hits"] >= 1
        assert out["secagg_mesh_bitwise"]
