"""Fused wire kernels — bit-equality with the jitted jnp reference.

The wire encode path always runs JITTED (inside the transport's scan),
and jitted XLA canonicalizes ``c * mask`` at dropped entries to +0.0
where eager evaluation keeps IEEE −0.0 — so every reference here is
computed UNDER ``jax.jit``, which is the only comparison that reflects
what a fit actually computes.  Covered:

* fused top-k encode (select + mask + EF residual + survivor count) vs
  the reference formulas, across leaf shapes including the <256 kernel
  boundary and multi-round EF residual carry;
* fused int8 absmax + quantize→dequantize vs the reference;
* wire-level: a fit with ``use_kernel=True`` is bitwise identical to
  ``use_kernel=False`` (the knob changes pass structure, never results),
  and ``FitResult.metrics["wire_kernel_hits"]`` reports which path ran;
* an 8-fake-device subprocess check of the same equalities under a real
  multi-shard mesh placement.
"""

from __future__ import annotations

import os
import subprocess
import sys
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.api.wire import Int8Wire, TopKWire
from repro.kernels.int8_quant import ops as q8_ops
from repro.kernels.topk_compress import ops as tk_ops
from repro.ml.linear import lsq_loss


def bits_equal(a, b) -> bool:
    a = np.atleast_1d(np.asarray(a))
    b = np.atleast_1d(np.asarray(b))
    if a.shape != b.shape or a.dtype != b.dtype:
        return False
    return bool((a.view(np.uint32) == b.view(np.uint32)).all())


@partial(jax.jit, static_argnames=("k", "with_residual"))
def _topk_ref(c, *, k, with_residual):
    """The wire's reference formulas, jitted — what the fallback path of
    ``TopKWire._encode_leaf`` computes inside the transport scan."""
    thresh = jax.lax.top_k(jnp.abs(c.reshape(-1)), k)[0][-1]
    keep = (jnp.abs(c) >= thresh).astype(c.dtype)
    o = c * keep
    res = c - o if with_residual else None
    count = jnp.sum(keep != 0).astype(jnp.int32)
    return o, res, count


@jax.jit
def _int8_ref(c):
    scale = jnp.maximum(jnp.max(jnp.abs(c)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
    return q.astype(c.dtype) * scale, scale


# shapes cross the (8, 1024) tile boundary, stay under it, and hit the
# <256 gate's neighborhood from both sides
SHAPES = [(4096,), (128, 300), (513,), (300,), (8192,), (256,), (257,)]


@pytest.mark.parametrize("shape", SHAPES)
def test_topk_encode_bitwise_no_ef(shape):
    x = jax.random.normal(jax.random.key(1), shape)
    k = max(1, x.size // 10)
    out, res, count = tk_ops.topk_encode(x, k=k)
    exp_o, _, exp_c = _topk_ref(x, k=k, with_residual=False)
    assert res is None
    assert bits_equal(out, exp_o)
    assert int(count) == int(exp_c)


@pytest.mark.parametrize("shape", SHAPES)
def test_topk_encode_bitwise_with_ef(shape):
    x = jax.random.normal(jax.random.key(2), shape)
    r = 0.25 * jax.random.normal(jax.random.key(3), shape)
    k = max(1, x.size // 10)
    out, res, count = tk_ops.topk_encode(x, r, k=k)
    exp_o, exp_r, exp_c = _topk_ref(x + r, k=k, with_residual=True)
    assert bits_equal(out, exp_o)
    assert bits_equal(res, exp_r)
    assert int(count) == int(exp_c)


def test_topk_encode_k_edges():
    x = jax.random.normal(jax.random.key(4), (256,))
    for k in (1, 255, 256):
        out, _, count = tk_ops.topk_encode(x, k=k)
        exp_o, _, exp_c = _topk_ref(x, k=k, with_residual=False)
        assert bits_equal(out, exp_o)
        assert int(count) == int(exp_c) == k


def test_topk_ef_residual_carries_over_rounds():
    """EF carry: round t's residual feeds round t+1 — kernel chain equals
    the jitted reference chain bitwise at every round."""
    x = jax.random.normal(jax.random.key(5), (2048,))
    k = 64
    r_k = jnp.zeros_like(x)
    r_ref = jnp.zeros_like(x)
    for t in range(4):
        m = jnp.sin(x * (t + 1))  # deterministic fresh "update"
        out_k, r_k, _ = tk_ops.topk_encode(m, r_k, k=k)
        out_ref, r_ref, _ = _topk_ref(m + r_ref, k=k, with_residual=True)
        assert bits_equal(out_k, out_ref), f"round {t} output diverged"
        assert bits_equal(r_k, r_ref), f"round {t} residual diverged"


@pytest.mark.parametrize("shape", SHAPES)
def test_int8_roundtrip_bitwise(shape):
    x = jax.random.normal(jax.random.key(6), shape)
    got, scale = q8_ops.int8_roundtrip(x)
    exp, exp_scale = _int8_ref(x)
    assert bits_equal(got, exp)
    assert bits_equal(scale, exp_scale)


def _fit_problem():
    # 300-dim: the theta leaf is kernel-eligible; K=4 nodes
    Xs = jax.random.normal(jax.random.key(7), (4, 32, 300))
    w = jax.random.normal(jax.random.key(8), (300,))
    ys = jnp.einsum("kni,i->kn", Xs, w)
    return (Xs, ys)


@pytest.mark.parametrize("make_wire", [
    lambda uk: TopKWire(0.1, error_feedback=True, use_kernel=uk),
    lambda uk: TopKWire(0.1, use_kernel=uk),
    lambda uk: Int8Wire(error_feedback=True, use_kernel=uk),
    lambda uk: Int8Wire(use_kernel=uk),
])
def test_fit_kernel_on_off_bitwise(make_wire):
    """The use_kernel knob changes pass structure, never results."""
    data = _fit_problem()
    st = api.GradientDescent(lsq_loss, lr=0.05)
    r_on = api.fit(st, data, transport="allreduce", steps=6,
                   wire=make_wire(True))
    r_off = api.fit(st, data, transport="allreduce", steps=6,
                    wire=make_wire(False))
    assert bits_equal(r_on.theta, r_off.theta)
    assert bits_equal(np.asarray(r_on.trajectory),
                      np.asarray(r_off.trajectory))
    assert r_on.ledger.total_bytes == r_off.ledger.total_bytes


def test_wire_kernel_hits_reported():
    data = _fit_problem()
    st = api.GradientDescent(lsq_loss, lr=0.05)
    res = api.fit(st, data, transport="allreduce", steps=3,
                  wire="topk:0.1+ef")
    hits = res.metrics["wire_kernel_hits"]
    assert hits["kernel_leaves"] == 1  # the (300,) theta leaf
    assert hits["fallback_leaves"] == 0
    assert hits["min_size"] == 256
    assert hits["wire"] == "topk:0.1+ef"
    # dense wire has no kernel path — no report
    res_d = api.fit(st, data, transport="allreduce", steps=3)
    assert "wire_kernel_hits" not in res_d.metrics


def test_small_leaf_takes_reference_path_and_still_matches():
    """<256 leaves fall back (satellite fix: previously a silent
    size-only gate) — and the fallback is the reference, so results
    still match a forced-off run bitwise."""
    Xs = jax.random.normal(jax.random.key(9), (4, 16, 100))
    w = jax.random.normal(jax.random.key(10), (100,))
    ys = jnp.einsum("kni,i->kn", Xs, w)
    st = api.GradientDescent(lsq_loss, lr=0.05)
    wire_on = TopKWire(0.2, error_feedback=True, use_kernel=True)
    r_on = api.fit(st, (Xs, ys), transport="allreduce", steps=4,
                   wire=wire_on)
    hits = r_on.metrics["wire_kernel_hits"]
    assert hits["kernel_leaves"] == 0 and hits["fallback_leaves"] == 1
    r_off = api.fit(st, (Xs, ys), transport="allreduce", steps=4,
                    wire=TopKWire(0.2, error_feedback=True,
                                  use_kernel=False))
    assert bits_equal(r_on.theta, r_off.theta)


_SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)
os.environ["JAX_PLATFORMS"] = "cpu"
import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.api.wire import Int8Wire, TopKWire
from repro.ml.linear import lsq_loss

assert jax.device_count() == 8, jax.device_count()

Xs = jax.random.normal(jax.random.key(7), (8, 32, 300))
w = jax.random.normal(jax.random.key(8), (300,))
ys = jnp.einsum("kni,i->kn", Xs, w)
st = api.GradientDescent(lsq_loss, lr=0.05)


def bits_equal(a, b):
    a, b = np.atleast_1d(np.asarray(a)), np.atleast_1d(np.asarray(b))
    return bool((a.view(np.uint32) == b.view(np.uint32)).all())


for make in (
    lambda uk: TopKWire(0.1, error_feedback=True, use_kernel=uk),
    lambda uk: Int8Wire(error_feedback=True, use_kernel=uk),
):
    r_on = api.fit(st, (Xs, ys), transport="allreduce", steps=5,
                   wire=make(True), executor="mesh")
    r_off = api.fit(st, (Xs, ys), transport="allreduce", steps=5,
                    wire=make(False), executor="mesh")
    r_loc = api.fit(st, (Xs, ys), transport="allreduce", steps=5,
                    wire=make(False))
    assert bits_equal(r_on.theta, r_off.theta), "kernel knob changed mesh fit"
    # cross-device psum order differs from the local stacked sum, so
    # mesh vs local is allclose (same convention as test_executors.py)
    assert np.allclose(np.asarray(r_on.theta), np.asarray(r_loc.theta),
                       rtol=1e-6, atol=1e-6), "mesh fit far from local fit"
print("OK")
"""


def test_wire_kernels_on_8_fake_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout
