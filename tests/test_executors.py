"""Executor-layer tests: the same Strategy/Transport/Wire program must
produce the same fit under every placement.

* ``local`` — bit-exact with the pre-executor engine (covered by
  ``test_api_fit.py`` running entirely on the default executor; here we
  only check the explicit spec resolves to the same run).
* ``mesh``  — shard_map node placement matches the stacked scan within fp
  tolerance (reduction order differs), with IDENTICAL ledgers; exercised
  on however many devices the process has (the CI mesh job forces 8 fake
  CPU devices via XLA_FLAGS) plus an explicit 8-device subprocess check.
* ``sweep`` — a vmapped S-scenario batch matches S independent ``fit``
  calls, with per-scenario ledgers bit-for-bit equal on byte totals.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import schedules
from repro.ml.linear import lsq_loss


def _make_problem(K=8, Nk=10, n=5, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(K, Nk, n)))
    w = jnp.asarray(rng.normal(size=(n,)))
    y = jnp.einsum("kni,i->kn", X, w)
    return X, y, w, n


class TestMeshEquivalence:
    """mesh executor ≡ local executor on whatever devices this process has
    (1 in a plain run; 8 under the CI mesh job's XLA_FLAGS)."""

    @pytest.mark.parametrize(
        "transport,kw",
        [("allreduce", {}), ("delay_line", {"staleness": 2})],
    )
    def test_matches_local(self, transport, kw):
        X, y, w, n = _make_problem()
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport=transport, steps=40, **kw)
        mesh = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport=transport, steps=40, executor="mesh", **kw)
        np.testing.assert_allclose(np.asarray(mesh.theta), np.asarray(loc.theta),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(mesh.trajectory),
                                   np.asarray(loc.trajectory),
                                   rtol=1e-5, atol=1e-6)
        assert mesh.ledger.summary() == loc.ledger.summary()
        assert mesh.metrics["executor"] == "mesh"

    def test_lbfgs_mean_aggregation(self):
        """aggregate_op="mean" completes with pmean across shards."""
        X, y, w, n = _make_problem()
        loc = api.fit(api.LBFGS(lsq_loss), (X, y), transport="allreduce", steps=15)
        mesh = api.fit(api.LBFGS(lsq_loss), (X, y), transport="allreduce",
                       steps=15, executor="mesh")
        np.testing.assert_allclose(np.asarray(mesh.theta), np.asarray(loc.theta),
                                   rtol=1e-4, atol=1e-5)
        assert mesh.ledger.summary() == loc.ledger.summary()

    def test_compressed_wire_encodes_per_shard(self):
        """top-k + EF composes with the mesh placement: the per-node
        encode runs inside the shard_map body, byte accounting unchanged."""
        X, y, w, n = _make_problem()
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", wire="topk:0.5+ef", steps=25)
        mesh = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="allreduce", wire="topk:0.5+ef", steps=25,
                       executor="mesh")
        assert mesh.ledger.summary() == loc.ledger.summary()
        assert float(mesh.trajectory[-1]) < float(mesh.trajectory[0])
        # compression actually metered: below the dense allreduce cost
        dense_up = 25 * X.shape[0] * n * 4
        assert mesh.ledger.uplink_bytes < dense_up

    def test_resume_carry_crosses_executors(self):
        """A mesh run's carry resumes on the local executor (the wire/EF
        state is reassembled to its global layout at the shard_map exit)."""
        X, y, w, n = _make_problem()
        full = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="allreduce", steps=30)
        first = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                        transport="allreduce", steps=15, executor="mesh")
        second = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                         transport="allreduce", steps=15,
                         carry=first.metrics["carry"])
        np.testing.assert_allclose(np.asarray(second.theta),
                                   np.asarray(full.theta),
                                   rtol=1e-5, atol=1e-6)


class TestMeshValidation:
    def test_server_transport_rejected(self):
        X, y, w, n = _make_problem(K=4)
        with pytest.raises(ValueError, match="local"):
            api.fit(api.FunctionStrategy(lambda k, t: t, num_nodes=4),
                    transport="sequential_server",
                    schedule=schedules.round_robin(4, 2),
                    theta0=jnp.zeros(n), executor="mesh")

    def test_admm_rejected(self):
        from repro.ml.linear import lasso_prox_builder

        X, y, w, n = _make_problem(K=4)
        with pytest.raises(ValueError, match="local"):
            api.fit(api.ProxStrategy(lasso_prox_builder), (X, y),
                    transport="admm_consensus", steps=5, g="l1", g_lam=0.1,
                    executor="mesh")

    def test_semantic_aggregate_rejected(self):
        """Strategies that override aggregate() (cascade SVM's mask union)
        cannot be placed on a mesh — only op-based reductions psum."""
        from repro.ml.svm import CascadeStrategy

        rng = np.random.default_rng(3)
        Xs = jnp.asarray(rng.normal(size=(4, 6, 2)))
        ys = jnp.asarray(np.sign(rng.normal(size=(4, 6))))
        with pytest.raises(NotImplementedError, match="aggregate"):
            api.fit(CascadeStrategy(C=1.0, iters=10), (Xs, ys),
                    transport="allreduce", steps=2, executor="mesh")

    def test_uneven_placement_rejected(self):
        if jax.device_count() == 1:
            pytest.skip("needs >1 device to make K indivisible")
        K = jax.device_count() + 1
        X, y, w, n = _make_problem(K=K)
        with pytest.raises(ValueError, match="evenly"):
            api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=3, executor="mesh")

    def test_mesh_context_reuse(self):
        """An active sharding.rules.MeshContext supplies the mesh."""
        from repro.launch.mesh import make_node_mesh
        from repro.sharding.rules import MeshContext, set_mesh_context

        X, y, w, n = _make_problem()
        set_mesh_context(MeshContext(mesh=make_node_mesh(), logical={}))
        try:
            res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                          transport="allreduce", steps=10, executor="mesh")
        finally:
            set_mesh_context(None)
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=10)
        np.testing.assert_allclose(np.asarray(res.theta), np.asarray(loc.theta),
                                   rtol=1e-5, atol=1e-6)


class TestMeshEightDevices:
    """The acceptance check proper: 8 fake CPU devices in a subprocess
    (XLA device count is fixed at jax init, so in-process tests can't
    force it)."""

    SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
import jax.numpy as jnp
import numpy as np
from repro import api
from repro.ml.linear import lsq_loss

rng = np.random.default_rng(0)
X = jnp.asarray(rng.normal(size=(8, 10, 5)))
w = jnp.asarray(rng.normal(size=(5,)))
y = jnp.einsum("kni,i->kn", X, w)
out = {"num_devices": jax.device_count()}
for transport, kw in [("allreduce", {}), ("delay_line", {"staleness": 2})]:
    loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                  transport=transport, steps=40, **kw)
    mesh = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                   transport=transport, steps=40, executor="mesh", **kw)
    out[transport] = {
        "theta_close": bool(np.allclose(loc.theta, mesh.theta,
                                        rtol=1e-5, atol=1e-6)),
        "traj_close": bool(np.allclose(loc.trajectory, mesh.trajectory,
                                       rtol=1e-5, atol=1e-6)),
        "ledger_equal": loc.ledger.summary() == mesh.ledger.summary(),
    }
print(json.dumps(out))
"""

    def test_mesh_matches_local_on_8_devices(self):
        # repro may be a namespace package (no __file__) — anchor on api
        src = os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.abspath(api.__file__)))
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["JAX_PLATFORMS"] = "cpu"
        proc = subprocess.run(
            [sys.executable, "-c", self.SCRIPT],
            capture_output=True, text=True, env=env, timeout=600,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        assert out["num_devices"] == 8
        for transport in ("allreduce", "delay_line"):
            assert out[transport] == {
                "theta_close": True, "traj_close": True, "ledger_equal": True
            }, out


class TestSweepEquivalence:
    """sweep over S scenarios ≡ S independent fits; ledgers bit-for-bit."""

    LRS = (0.02, 0.05, 0.1, 0.2)

    def test_lr_sweep_matches_independent_fits(self):
        X, y, w, n = _make_problem()
        sw = api.SweepExecutor({"lr": jnp.asarray(self.LRS)})
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=30, executor=sw)
        assert np.asarray(res.theta).shape == (4, n)
        assert np.asarray(res.trajectory).shape == (4, 30)
        assert isinstance(res.ledger, list) and len(res.ledger) == 4
        for i, lr in enumerate(self.LRS):
            solo = api.fit(api.GradientDescent(lsq_loss, lr=lr), (X, y),
                           transport="allreduce", steps=30)
            np.testing.assert_allclose(np.asarray(res.theta[i]),
                                       np.asarray(solo.theta),
                                       rtol=1e-6, atol=1e-7)
            np.testing.assert_allclose(np.asarray(res.trajectory[i]),
                                       np.asarray(solo.trajectory),
                                       rtol=1e-6, atol=1e-7)
            # acceptance: byte totals bit-for-bit
            assert res.ledger[i].uplink_bytes == solo.ledger.uplink_bytes
            assert res.ledger[i].downlink_bytes == solo.ledger.downlink_bytes
            assert res.ledger[i].rounds == solo.ledger.rounds

    def test_staleness_sweep_matches_independent_fits(self):
        """S staleness levels share one depth-max(D) delay line read at a
        batched index — one compiled executable."""
        X, y, w, n = _make_problem()
        Ds = (0, 1, 2, 3)
        sw = api.SweepExecutor({"staleness": jnp.asarray(Ds)})
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                      transport="delay_line", steps=40, executor=sw)
        for i, D in enumerate(Ds):
            solo = api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                           transport="delay_line", staleness=D, steps=40)
            np.testing.assert_allclose(np.asarray(res.theta[i]),
                                       np.asarray(solo.theta),
                                       rtol=1e-6, atol=1e-7)
            assert res.ledger[i].total_bytes == solo.ledger.total_bytes

    def test_theta0_sweep(self):
        X, y, w, n = _make_problem()
        theta0s = jnp.asarray(np.random.default_rng(1).normal(size=(3, n)))
        sw = api.SweepExecutor({"theta0": theta0s})
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=20, executor=sw)
        for i in range(3):
            solo = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                           transport="allreduce", steps=20,
                           theta0=theta0s[i])
            np.testing.assert_allclose(np.asarray(res.theta[i]),
                                       np.asarray(solo.theta),
                                       rtol=1e-6, atol=1e-7)

    def test_pytree_theta0_sweep(self):
        """theta0 may be a model PYTREE with batched leaves (the
        launch/train.py param dicts), not just a flat vector."""
        from repro.api.strategy import OptimizerStrategy
        from repro.optim import adam

        rng = np.random.default_rng(2)
        Xb = jnp.asarray(rng.normal(size=(6, 4, 3)))
        yb = jnp.asarray(rng.normal(size=(6, 4)))

        def loss_fn(theta, batch):
            Xt, yt = batch
            return 0.5 * jnp.mean(((Xt @ theta["w"]) + theta["b"] - yt) ** 2)

        theta0s = {
            "w": jnp.asarray(rng.normal(size=(2, 3))),
            "b": jnp.asarray(rng.normal(size=(2,))),
        }
        sw = api.SweepExecutor({"theta0": theta0s})
        assert sw.num_scenarios == 2
        res = api.fit(OptimizerStrategy(loss_fn, adam(0.1)), None,
                      transport="delay_line", staleness=0,
                      stream=(Xb, yb), executor=sw)
        for i in range(2):
            solo = api.fit(OptimizerStrategy(loss_fn, adam(0.1)), None,
                           transport="delay_line", staleness=0,
                           stream=(Xb, yb),
                           theta0=jax.tree.map(lambda x: x[i], theta0s))
            np.testing.assert_allclose(np.asarray(res.theta["w"][i]),
                                       np.asarray(solo.theta["w"]),
                                       rtol=1e-6, atol=1e-7)

    def test_sweep_carry_resume(self):
        """A swept run resumes from its batched carry."""
        X, y, w, n = _make_problem()
        sw = api.SweepExecutor({"lr": jnp.asarray(self.LRS)})
        full = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="allreduce", steps=30, executor=sw)
        a = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=15, executor=sw)
        b = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=15, executor=sw,
                    carry=a.metrics["carry"])
        np.testing.assert_allclose(np.asarray(b.theta), np.asarray(full.theta),
                                   rtol=1e-6, atol=1e-7)

    def test_compressed_wire_sweeps(self):
        """EF residual state batches per scenario alongside θ."""
        X, y, w, n = _make_problem()
        sw = api.SweepExecutor({"lr": jnp.asarray([0.05, 0.1])})
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", wire="topk:0.5+ef", steps=20,
                      executor=sw)
        solo = api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                       transport="allreduce", wire="topk:0.5+ef", steps=20)
        np.testing.assert_allclose(np.asarray(res.theta[0]),
                                   np.asarray(solo.theta),
                                   rtol=1e-6, atol=1e-7)
        assert res.ledger[0].total_bytes == solo.ledger.total_bytes


class TestExecutorErrors:
    def test_unknown_executor(self):
        with pytest.raises(ValueError, match="unknown executor"):
            api.make_executor("cluster")

    def test_bare_sweep_string_rejected(self):
        with pytest.raises(ValueError, match="SweepExecutor"):
            api.make_executor("sweep")

    def test_sweep_needs_params(self):
        with pytest.raises(ValueError, match="at least one"):
            api.SweepExecutor({})

    def test_sweep_scenario_count_mismatch(self):
        with pytest.raises(ValueError, match="disagree"):
            api.SweepExecutor({"lr": jnp.zeros(3), "l2": jnp.zeros(4)})

    def test_sweep_unknown_attribute(self):
        X, y, w, n = _make_problem(K=4)
        sw = api.SweepExecutor({"momentum": jnp.asarray([0.1, 0.2])})
        with pytest.raises(ValueError, match="momentum"):
            api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=3, executor=sw)

    def test_server_transport_rejects_sweep(self):
        X, y, w, n = _make_problem(K=4)
        sw = api.SweepExecutor({"lr": jnp.asarray([0.1, 0.2])})
        with pytest.raises(ValueError, match="local"):
            api.fit(api.FunctionStrategy(lambda k, t: t, num_nodes=4),
                    transport="sequential_server",
                    schedule=schedules.round_robin(4, 2),
                    theta0=jnp.zeros(n), executor=sw)

    def test_all_executors_listed(self):
        assert set(api.EXECUTORS) == {"local", "mesh", "sweep", "serve"}

    def test_explicit_local_is_default(self):
        X, y, w, n = _make_problem(K=4)
        a = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=10)
        b = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=10, executor="local")
        np.testing.assert_array_equal(np.asarray(a.theta), np.asarray(b.theta))
        assert a.ledger.summary() == b.ledger.summary()


class TestDynamicDelayRead:
    """core.staleness.delay_push_read ≡ delay_push_pop at delay == depth."""

    def test_matches_push_pop_at_full_depth(self):
        from repro.core.staleness import delay_init, delay_push_pop, delay_push_read

        rng = np.random.default_rng(0)
        D = 3
        a = delay_init(jnp.zeros(4), D)
        b = delay_init(jnp.zeros(4), D)
        for t in range(8):
            g = jnp.asarray(rng.normal(size=4))
            a, pa = delay_push_pop(a, g)
            b, pb = delay_push_read(b, g, jnp.asarray(D))
            np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
            np.testing.assert_array_equal(np.asarray(a.buffer), np.asarray(b.buffer))

    def test_zero_delay_reads_fresh(self):
        from repro.core.staleness import delay_init, delay_push_read

        s = delay_init(jnp.zeros(3), 2)
        g = jnp.asarray([1.0, 2.0, 3.0])
        _, read = delay_push_read(s, g, jnp.asarray(0))
        np.testing.assert_array_equal(np.asarray(read), np.asarray(g))
