"""Executor-layer tests: the same Strategy/Transport/Wire program must
produce the same fit under every placement.

* ``local`` — bit-exact with the pre-executor engine (covered by
  ``test_api_fit.py`` running entirely on the default executor; here we
  only check the explicit spec resolves to the same run).
* ``mesh``  — shard_map node placement matches the stacked scan within fp
  tolerance (reduction order differs), with IDENTICAL ledgers; exercised
  on however many devices the process has (the CI mesh job forces 8 fake
  CPU devices via XLA_FLAGS) plus an explicit 8-device subprocess check.
* ``multipod`` — the hierarchical ``("pod", "data")`` placement is
  BIT-EXACT with the flat mesh executor on the same mesh (both stage the
  reduction through the same mesh-derived topology; only the ledger
  accounting differs), and the per-hop ledger decomposition sums to the
  flat totals.
* ``sweep`` — a vmapped S-scenario batch matches S independent ``fit``
  calls, with per-scenario ledgers bit-for-bit equal on byte totals.
* ``mesh+sweep`` / ``multipod+sweep`` — the composed executor (scenario
  vmap INSIDE the shard_map body) matches S independent fits on the
  same inner executor: theta and per-scenario ledger totals bit-exact,
  trajectory to fp tolerance (the vmapped loss-metric reduction orders
  differently).
* mesh-placed SERVER transports — ``sequential_server``/``stale_server``
  under ``executor="mesh"`` walk the same sequential schedule with each
  contact's ``local_step`` masked onto the owning shard; bit-exact with
  the local walk (the ``from_owner`` psum adds only zeros).
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import schedules
from repro.ml.linear import lsq_loss


def _make_problem(K=8, Nk=10, n=5, seed=0):
    rng = np.random.default_rng(seed)
    X = jnp.asarray(rng.normal(size=(K, Nk, n)))
    w = jnp.asarray(rng.normal(size=(n,)))
    y = jnp.einsum("kni,i->kn", X, w)
    return X, y, w, n


class TestMeshEquivalence:
    """mesh executor ≡ local executor on whatever devices this process has
    (1 in a plain run; 8 under the CI mesh job's XLA_FLAGS)."""

    @pytest.mark.parametrize(
        "transport,kw",
        [("allreduce", {}), ("delay_line", {"staleness": 2})],
    )
    def test_matches_local(self, transport, kw):
        X, y, w, n = _make_problem()
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport=transport, steps=40, **kw)
        mesh = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport=transport, steps=40, executor="mesh", **kw)
        np.testing.assert_allclose(np.asarray(mesh.theta), np.asarray(loc.theta),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(mesh.trajectory),
                                   np.asarray(loc.trajectory),
                                   rtol=1e-5, atol=1e-6)
        assert mesh.ledger.summary() == loc.ledger.summary()
        assert mesh.metrics["executor"] == "mesh"

    def test_lbfgs_mean_aggregation(self):
        """aggregate_op="mean" completes with pmean across shards."""
        X, y, w, n = _make_problem()
        loc = api.fit(api.LBFGS(lsq_loss), (X, y), transport="allreduce", steps=15)
        mesh = api.fit(api.LBFGS(lsq_loss), (X, y), transport="allreduce",
                       steps=15, executor="mesh")
        np.testing.assert_allclose(np.asarray(mesh.theta), np.asarray(loc.theta),
                                   rtol=1e-4, atol=1e-5)
        assert mesh.ledger.summary() == loc.ledger.summary()

    def test_compressed_wire_encodes_per_shard(self):
        """top-k + EF composes with the mesh placement: the per-node
        encode runs inside the shard_map body, byte accounting unchanged."""
        X, y, w, n = _make_problem()
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", wire="topk:0.5+ef", steps=25)
        mesh = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="allreduce", wire="topk:0.5+ef", steps=25,
                       executor="mesh")
        assert mesh.ledger.summary() == loc.ledger.summary()
        assert float(mesh.trajectory[-1]) < float(mesh.trajectory[0])
        # compression actually metered: below the dense allreduce cost
        dense_up = 25 * X.shape[0] * n * 4
        assert mesh.ledger.uplink_bytes < dense_up

    def test_resume_carry_crosses_executors(self):
        """A mesh run's carry resumes on the local executor (the wire/EF
        state is reassembled to its global layout at the shard_map exit)."""
        X, y, w, n = _make_problem()
        full = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="allreduce", steps=30)
        first = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                        transport="allreduce", steps=15, executor="mesh")
        second = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                         transport="allreduce", steps=15,
                         carry=first.metrics["carry"])
        np.testing.assert_allclose(np.asarray(second.theta),
                                   np.asarray(full.theta),
                                   rtol=1e-5, atol=1e-6)


class TestMeshValidation:
    def test_server_transport_needs_shardable_data(self):
        """Closure-based strategies (no data to shard) cannot mesh-place
        a server transport — the masked-compute placement needs a data
        shard per node."""
        X, y, w, n = _make_problem(K=4)
        with pytest.raises(ValueError, match="local"):
            api.fit(api.FunctionStrategy(lambda k, t: t, num_nodes=4),
                    transport="sequential_server",
                    schedule=schedules.round_robin(4, 2),
                    theta0=jnp.zeros(n), executor="mesh")

    def test_admm_rejected(self):
        from repro.ml.linear import lasso_prox_builder

        X, y, w, n = _make_problem(K=4)
        with pytest.raises(ValueError, match="local"):
            api.fit(api.ProxStrategy(lasso_prox_builder), (X, y),
                    transport="admm_consensus", steps=5, g="l1", g_lam=0.1,
                    executor="mesh")

    def test_python_aggregate_override_rejected(self):
        """Strategies that override aggregate() with arbitrary Python
        cannot be placed on a mesh — only op-based reductions psum
        (set aggregate_op, e.g. the cascade SVM's "any" union)."""

        class Weird(api.GradientDescent):
            def aggregate(self, msgs):
                return jnp.median(msgs, axis=0)

        X, y, w, n = _make_problem()
        with pytest.raises(NotImplementedError, match="aggregate"):
            api.fit(Weird(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=2, executor="mesh")

    def test_uneven_placement_rejected(self):
        if jax.device_count() == 1:
            pytest.skip("needs >1 device to make K indivisible")
        K = jax.device_count() + 1
        X, y, w, n = _make_problem(K=K)
        with pytest.raises(ValueError, match="evenly"):
            api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=3, executor="mesh")

    def test_mesh_context_reuse(self):
        """An active sharding.rules.MeshContext supplies the mesh."""
        from repro.launch.mesh import make_node_mesh
        from repro.sharding.rules import MeshContext, set_mesh_context

        X, y, w, n = _make_problem()
        set_mesh_context(MeshContext(mesh=make_node_mesh(), logical={}))
        try:
            res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                          transport="allreduce", steps=10, executor="mesh")
        finally:
            set_mesh_context(None)
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=10)
        np.testing.assert_allclose(np.asarray(res.theta), np.asarray(loc.theta),
                                   rtol=1e-5, atol=1e-6)


class TestMeshServerTransports:
    """The §5 sequential schedule placed on the mesh: each contact's
    local_step runs masked on the shard owning the contacted node, the
    push is replicated with one psum — BIT-exact with the local walk
    (summing the non-owners' zeros is exact in fp)."""

    @pytest.mark.parametrize("transport", ["sequential_server", "stale_server"])
    @pytest.mark.parametrize("wire", ["dense", "topk:0.5+ef"])
    def test_matches_local(self, transport, wire):
        X, y, w, n = _make_problem()
        sched = schedules.round_robin(8, 5)
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport=transport, schedule=sched, wire=wire)
        mesh = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport=transport, schedule=sched, wire=wire,
                       executor="mesh")
        np.testing.assert_array_equal(np.asarray(loc.theta),
                                      np.asarray(mesh.theta))
        np.testing.assert_array_equal(np.asarray(loc.trajectory),
                                      np.asarray(mesh.trajectory))
        assert mesh.ledger.summary() == loc.ledger.summary()
        assert mesh.metrics["executor"] == "mesh"

    def test_random_schedule_matches_local(self):
        X, y, w, n = _make_problem()
        sched = schedules.asynchronous(jax.random.PRNGKey(0), 8, 40)
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="sequential_server", schedule=sched)
        mesh = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="sequential_server", schedule=sched,
                       executor="mesh")
        np.testing.assert_array_equal(np.asarray(loc.theta),
                                      np.asarray(mesh.theta))

    def test_multipod_decomposes_server_bytes(self):
        """The multipod placement accepts server transports too, with
        the contact traffic attributed across tiers (summing exactly to
        the flat totals)."""
        X, y, w, n = _make_problem()
        sched = schedules.round_robin(8, 5)
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="sequential_server", schedule=sched)
        mp = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                     transport="sequential_server", schedule=sched,
                     executor="multipod")
        np.testing.assert_array_equal(np.asarray(loc.theta),
                                      np.asarray(mp.theta))
        s = mp.ledger.summary()
        assert set(s["by_hop"]) == {"intra_pod", "inter_pod"}
        assert sum(v["total_bytes"] for v in s["by_hop"].values()) \
            == loc.ledger.total_bytes

    def test_kwindows_server_on_mesh(self):
        """A server strategy that mixes shard-local data indexing with
        global slot/key indexing (node_global_index) places bit-exactly."""
        from repro.ml.kwindows import KWindowsStrategy

        rng = np.random.default_rng(0)
        pts = np.concatenate([rng.normal(loc=c, scale=0.3, size=(80, 2))
                              for c in [(0, 0), (3, 3), (-3, 2)]])
        rng.shuffle(pts)
        Xs = jnp.asarray(pts.reshape(8, 30, 2))
        sched = schedules.round_robin(8, 1)

        def strat():
            return KWindowsStrategy(jax.random.PRNGKey(0), num_windows=3, r=1.0)

        loc = api.fit(strat(), Xs, transport="sequential_server",
                      schedule=sched)
        mesh = api.fit(strat(), Xs, transport="sequential_server",
                       schedule=sched, executor="mesh")
        for f in loc.theta._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(loc.theta, f)),
                np.asarray(getattr(mesh.theta, f)))
        assert mesh.ledger.summary() == loc.ledger.summary()

    def test_resume_carry_crosses_executors(self):
        """A mesh server run's carry resumes on the local executor (the
        wire state reassembles to its global layout at the shard_map
        exit) and vice versa."""
        X, y, w, n = _make_problem()
        sched = schedules.round_robin(8, 6)
        full = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="sequential_server", schedule=sched,
                       wire="topk:0.5+ef")
        half = schedules.round_robin(8, 3)
        a = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="sequential_server", schedule=half,
                    wire="topk:0.5+ef", executor="mesh")
        b = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="sequential_server", schedule=half,
                    wire="topk:0.5+ef", carry=a.metrics["carry"])
        np.testing.assert_array_equal(np.asarray(b.theta),
                                      np.asarray(full.theta))

    def test_replicate_data_strategy_rejected(self):
        """Replicate-data strategies have nothing to place — every shard
        reads the whole dataset — so the mesh server path refuses them."""
        class Rep(api.GradientDescent):
            replicate_data = True

        X, y, w, n = _make_problem()
        with pytest.raises(ValueError, match="replicate_data"):
            api.fit(Rep(lsq_loss, lr=0.1), (X, y),
                    transport="sequential_server",
                    schedule=schedules.round_robin(8, 2), executor="mesh")


class TestMeshEightDevices:
    """The acceptance check proper: 8 fake CPU devices in a subprocess
    (XLA device count is fixed at jax init, so in-process tests can't
    force it).  Covers the update transports, the mesh-placed SERVER
    transports (bitwise vs local), and the composed ``mesh+sweep``
    executor (S=4 scenarios bit-exact vs 4 independent mesh fits on
    theta and per-scenario ledger totals; trajectory to fp tolerance —
    the vmapped metric mean reduces in a different order)."""

    SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
import jax.numpy as jnp
import numpy as np
from repro import api
from repro.core import schedules
from repro.ml.linear import lsq_loss

def bitwise(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return bool(a.shape == b.shape and
                (a.view(np.uint32) == b.view(np.uint32)).all())

rng = np.random.default_rng(0)
X = jnp.asarray(rng.normal(size=(8, 10, 5)))
w = jnp.asarray(rng.normal(size=(5,)))
y = jnp.einsum("kni,i->kn", X, w)
out = {"num_devices": jax.device_count()}
for transport, kw in [("allreduce", {}), ("delay_line", {"staleness": 2})]:
    loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                  transport=transport, steps=40, **kw)
    mesh = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                   transport=transport, steps=40, executor="mesh", **kw)
    out[transport] = {
        "theta_close": bool(np.allclose(loc.theta, mesh.theta,
                                        rtol=1e-5, atol=1e-6)),
        "traj_close": bool(np.allclose(loc.trajectory, mesh.trajectory,
                                       rtol=1e-5, atol=1e-6)),
        "ledger_equal": loc.ledger.summary() == mesh.ledger.summary(),
    }

# mesh-placed server transports: bitwise vs the local sequential walk
sched = schedules.round_robin(8, 5)
for transport in ("sequential_server", "stale_server"):
    for wire in ("dense", "topk:0.5+ef"):
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport=transport, schedule=sched, wire=wire)
        mesh = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport=transport, schedule=sched, wire=wire,
                       executor="mesh")
        out[f"{transport}/{wire}"] = {
            "theta_bitwise": bitwise(loc.theta, mesh.theta),
            "traj_bitwise": bitwise(loc.trajectory, mesh.trajectory),
            "ledger_equal": loc.ledger.summary() == mesh.ledger.summary(),
        }

# ACCEPTANCE — mesh+sweep: S=4 scenarios vs 4 independent mesh fits
LRS = (0.02, 0.05, 0.1, 0.2)
res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
              transport="allreduce", steps=40, executor="mesh+sweep",
              sweep={"lr": jnp.asarray(LRS)})
acc = {"theta_bitwise": True, "traj_close": True, "ledger_equal": True,
       "executor_name": res.metrics["executor"]}
for i, lr in enumerate(LRS):
    solo = api.fit(api.GradientDescent(lsq_loss, lr=lr), (X, y),
                   transport="allreduce", steps=40, executor="mesh")
    acc["theta_bitwise"] &= bitwise(res.theta[i], solo.theta)
    acc["traj_close"] &= bool(np.allclose(res.trajectory[i], solo.trajectory,
                                          rtol=1e-5, atol=1e-7))
    acc["ledger_equal"] &= (
        res.ledger[i].uplink_bytes == solo.ledger.uplink_bytes
        and res.ledger[i].downlink_bytes == solo.ledger.downlink_bytes
        and res.ledger[i].rounds == solo.ledger.rounds)
out["mesh+sweep"] = acc

# multipod inner: per-hop split preserved per scenario
res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
              transport="delay_line", staleness=1, steps=30,
              executor="multipod+sweep", sweep={"lr": jnp.asarray(LRS)})
split_ok = True
for led in res.ledger:
    s = led.summary()
    split_ok &= set(s["by_hop"]) == {"intra_pod", "inter_pod"}
    split_ok &= all(v["total_bytes"] > 0 for v in s["by_hop"].values())
    split_ok &= sum(v["total_bytes"] for v in s["by_hop"].values()) \
        == led.total_bytes
out["multipod+sweep"] = {"split_per_scenario": bool(split_ok)}

# reduce-scatter staging + comm/compute overlap: both knobs bit-exact on
# a real 8-shard mesh (the staged additions happen in the same order)
rs_on = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                transport="allreduce", steps=30,
                executor=api.MeshExecutor(reduce_scatter=True))
rs_off = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                 transport="allreduce", steps=30,
                 executor=api.MeshExecutor(reduce_scatter=False))
out["reduce_scatter"] = {
    "theta_bitwise": bitwise(rs_on.theta, rs_off.theta),
    "ledger_equal": rs_on.ledger.summary() == rs_off.ledger.summary(),
}
ov_on = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                transport="delay_line", staleness=2, steps=30,
                executor=api.MeshExecutor(overlap=True))
ov_off = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                 transport="delay_line", staleness=2, steps=30,
                 executor=api.MeshExecutor(overlap=False))
resumed = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                  transport="delay_line", staleness=2, steps=15,
                  executor=api.MeshExecutor(overlap=True))
resumed = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                  transport="delay_line", staleness=2, steps=15,
                  executor=api.MeshExecutor(overlap=False),
                  carry=resumed.metrics["carry"])
out["overlap"] = {
    "theta_bitwise": bitwise(ov_on.theta, ov_off.theta),
    "traj_bitwise": bitwise(ov_on.trajectory, ov_off.trajectory),
    "ledger_equal": ov_on.ledger.summary() == ov_off.ledger.summary(),
    "resume_bitwise": bitwise(resumed.theta, ov_off.theta),
}
print(json.dumps(out))
"""

    def test_mesh_matches_local_on_8_devices(self, fake_devices):
        out = fake_devices(self.SCRIPT)
        assert out["num_devices"] == 8
        for transport in ("allreduce", "delay_line"):
            assert out[transport] == {
                "theta_close": True, "traj_close": True, "ledger_equal": True
            }, out
        for transport in ("sequential_server", "stale_server"):
            for wire in ("dense", "topk:0.5+ef"):
                assert out[f"{transport}/{wire}"] == {
                    "theta_bitwise": True, "traj_bitwise": True,
                    "ledger_equal": True,
                }, out
        assert out["mesh+sweep"] == {
            "theta_bitwise": True, "traj_close": True, "ledger_equal": True,
            "executor_name": "mesh+sweep",
        }, out
        assert out["multipod+sweep"] == {"split_per_scenario": True}, out
        assert out["reduce_scatter"] == {
            "theta_bitwise": True, "ledger_equal": True,
        }, out
        assert out["overlap"] == {
            "theta_bitwise": True, "traj_bitwise": True,
            "ledger_equal": True, "resume_bitwise": True,
        }, out


class TestMultiPodEquivalence:
    """multipod (hierarchical + per-hop pricing) ≡ mesh (flat) on the SAME
    mesh: both executors derive the same staged reduction topology from
    the mesh, so theta/trajectory are BIT-EXACT; only the ledger
    attribution differs.  Runs on however many devices the process has
    (the multipod mesh degrades to (1, 1) on one device — the hop split
    stays nonzero because the server tier always exists)."""

    @pytest.mark.parametrize(
        "transport,kw,wire",
        [
            ("allreduce", {}, "dense"),
            ("allreduce", {}, "topk:0.5+ef"),
            ("delay_line", {"staleness": 2}, "dense"),
            ("delay_line", {"staleness": 2}, "topk:0.5+ef"),
        ],
    )
    def test_bit_exact_with_flat_mesh(self, transport, kw, wire):
        from repro.launch.mesh import make_multipod_mesh

        X, y, w, n = _make_problem()
        mesh = make_multipod_mesh()
        strat = lambda: api.GradientDescent(lsq_loss, lr=0.1)  # noqa: E731
        flat = api.fit(strat(), (X, y), transport=transport, wire=wire,
                       steps=30, executor=api.MeshExecutor(mesh), **kw)
        hier = api.fit(strat(), (X, y), transport=transport, wire=wire,
                       steps=30, executor=api.MultiPodExecutor(mesh), **kw)
        np.testing.assert_array_equal(np.asarray(flat.theta),
                                      np.asarray(hier.theta))
        np.testing.assert_array_equal(np.asarray(flat.trajectory),
                                      np.asarray(hier.trajectory))
        # same flat totals; the hierarchical run decomposes them by tier
        assert hier.ledger.total_bytes == flat.ledger.total_bytes
        assert hier.ledger.uplink_bytes == flat.ledger.uplink_bytes
        by_hop = hier.ledger.summary()["by_hop"]
        assert set(by_hop) == {"intra_pod", "inter_pod"}
        assert all(v["total_bytes"] > 0 for v in by_hop.values())
        assert sum(v["total_bytes"] for v in by_hop.values()) \
            == flat.ledger.total_bytes
        assert flat.ledger.summary()["by_hop"] == {}
        assert hier.metrics["executor"] == "multipod"

    def test_matches_local_and_ledger_totals(self):
        X, y, w, n = _make_problem()
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=40)
        mp = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                     transport="allreduce", steps=40, executor="multipod")
        np.testing.assert_allclose(np.asarray(mp.theta), np.asarray(loc.theta),
                                   rtol=1e-5, atol=1e-6)
        assert mp.ledger.total_bytes == loc.ledger.total_bytes

    def test_priced_cost_weights_inter_pod(self):
        """The expensive tier is priced above the cheap one, so the priced
        cost exceeds the flat byte count whenever inter-pod traffic
        exists (and custom prices flow through)."""
        X, y, w, n = _make_problem()
        mp = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                     transport="allreduce", steps=10,
                     executor=api.MultiPodExecutor(
                         intra_price=1.0, inter_price=5.0))
        s = mp.ledger.summary()
        inter = s["by_hop"]["inter_pod"]
        assert inter["price_per_byte"] == 5.0
        assert s["priced_cost"] == pytest.approx(
            s["total_bytes"] + 4.0 * inter["total_bytes"]
        )

    def test_pod_axis_required(self):
        from repro.launch.mesh import make_node_mesh

        X, y, w, n = _make_problem()
        with pytest.raises(ValueError, match="pod"):
            api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=2,
                    executor=api.MultiPodExecutor(make_node_mesh()))

    def test_resume_carry_crosses_to_local(self):
        X, y, w, n = _make_problem()
        full = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="allreduce", steps=30)
        first = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                        transport="allreduce", steps=15, executor="multipod")
        second = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                         transport="allreduce", steps=15,
                         carry=first.metrics["carry"])
        np.testing.assert_allclose(np.asarray(second.theta),
                                   np.asarray(full.theta),
                                   rtol=1e-5, atol=1e-6)


class TestMultiPodEightDevices:
    """The hierarchical≡flat acceptance suite on a REAL multi-shard
    placement: 8 fake CPU devices in a subprocess, a 2×4 ``("pod",
    "data")`` mesh for the transport×wire equivalence matrix and the
    2×2×2 ``("pod", "data", "model")`` production shape for the
    acceptance check proper (bit-exact theta, nonzero per-hop split
    summing to the flat total).  The CI ``multipod-2x4`` job runs this
    file under the same XLA_FLAGS."""

    SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
import jax.numpy as jnp
import numpy as np
from repro import api
from repro.ml.linear import lsq_loss
from repro.ml.svm import CascadeStrategy

def bitwise(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return bool(a.shape == b.shape and
                (a.view(np.uint32) == b.view(np.uint32)).all())

rng = np.random.default_rng(0)
X = jnp.asarray(rng.normal(size=(8, 10, 5)))
w = jnp.asarray(rng.normal(size=(5,)))
y = jnp.einsum("kni,i->kn", X, w)
out = {"num_devices": jax.device_count()}

mesh24 = jax.make_mesh((2, 4), ("pod", "data"))
for transport, kw in [("allreduce", {}), ("delay_line", {"staleness": 2})]:
    for wire in ("dense", "topk:0.5+ef"):
        flat = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport=transport, wire=wire, steps=40,
                       executor=api.MeshExecutor(mesh24), **kw)
        hier = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport=transport, wire=wire, steps=40,
                       executor=api.MultiPodExecutor(mesh24), **kw)
        by_hop = hier.ledger.summary()["by_hop"]
        out[f"{transport}/{wire}"] = {
            "theta_bitwise": bitwise(flat.theta, hier.theta),
            "traj_bitwise": bitwise(flat.trajectory, hier.trajectory),
            "totals_equal": flat.ledger.total_bytes == hier.ledger.total_bytes,
            "split_nonzero": all(v["total_bytes"] > 0 for v in by_hop.values())
                             and set(by_hop) == {"intra_pod", "inter_pod"},
            "split_sums_to_flat": sum(v["total_bytes"] for v in by_hop.values())
                                  == flat.ledger.total_bytes,
        }

# acceptance: the (2, 2, 2) ("pod", "data", "model") production shape
mesh222 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
flat = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
               transport="allreduce", steps=40,
               executor=api.MeshExecutor(mesh222))
hier = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
               transport="allreduce", steps=40,
               executor=api.MultiPodExecutor(mesh222))
by_hop = hier.ledger.summary()["by_hop"]
out["mesh_2x2x2"] = {
    "theta_bitwise": bitwise(flat.theta, hier.theta),
    "traj_bitwise": bitwise(flat.trajectory, hier.trajectory),
    "split_nonzero": all(v["total_bytes"] > 0 for v in by_hop.values())
                     and len(by_hop) == 2,
    "split_sums_to_flat": sum(v["total_bytes"] for v in by_hop.values())
                          == flat.ledger.total_bytes,
}

# cascade SVM: the "any" union on a real multi-shard mesh (replicated data)
rng = np.random.default_rng(3)
Xs = jnp.asarray(rng.normal(size=(8, 6, 2)))
ys = jnp.asarray(np.sign(rng.normal(size=(8, 6))))
cl = api.fit(CascadeStrategy(C=1.0, iters=60), (Xs, ys),
             transport="allreduce", steps=3)
cm = api.fit(CascadeStrategy(C=1.0, iters=60), (Xs, ys),
             transport="allreduce", steps=3,
             executor=api.MeshExecutor(mesh24))
out["cascade"] = {
    "mask_equal": bool((np.asarray(cl.theta.sv_mask)
                        == np.asarray(cm.theta.sv_mask)).all()),
    "ledger_equal": cl.ledger.summary() == cm.ledger.summary(),
}
print(json.dumps(out))
"""

    def test_hierarchical_matches_flat_on_8_devices(self, fake_devices):
        out = fake_devices(self.SCRIPT)
        assert out["num_devices"] == 8
        for transport in ("allreduce", "delay_line"):
            for wire in ("dense", "topk:0.5+ef"):
                assert out[f"{transport}/{wire}"] == {
                    "theta_bitwise": True, "traj_bitwise": True,
                    "totals_equal": True, "split_nonzero": True,
                    "split_sums_to_flat": True,
                }, out
        assert out["mesh_2x2x2"] == {
            "theta_bitwise": True, "traj_bitwise": True,
            "split_nonzero": True, "split_sums_to_flat": True,
        }, out
        assert out["cascade"] == {"mask_equal": True, "ledger_equal": True}, out


class TestCascadeAnyReduction:
    """The cascade SVM's SV-mask union is an ``any``-reduction
    (psum-of-bools) — it now places on the mesh executors (with
    replicated data) instead of rejecting them."""

    def _problem(self, K=4):
        rng = np.random.default_rng(3)
        Xs = jnp.asarray(rng.normal(size=(K, 6, 2)))
        ys = jnp.asarray(np.sign(rng.normal(size=(K, 6))))
        return Xs, ys

    def test_local_mesh_equivalence(self):
        from repro.ml.svm import CascadeStrategy

        K = 4 if jax.device_count() == 1 else jax.device_count()
        Xs, ys = self._problem(K)
        loc = api.fit(CascadeStrategy(C=1.0, iters=60), (Xs, ys),
                      transport="allreduce", steps=3)
        mesh = api.fit(CascadeStrategy(C=1.0, iters=60), (Xs, ys),
                       transport="allreduce", steps=3, executor="mesh")
        np.testing.assert_array_equal(np.asarray(loc.theta.sv_mask),
                                      np.asarray(mesh.theta.sv_mask))
        np.testing.assert_allclose(np.asarray(loc.theta.alpha),
                                   np.asarray(mesh.theta.alpha),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(loc.trajectory),
                                      np.asarray(mesh.trajectory))
        # semantic (SVs-only) byte accounting completes across shards
        assert mesh.ledger.summary() == loc.ledger.summary()

    def test_multipod_decomposes_semantic_bytes(self):
        from repro.ml.svm import CascadeStrategy

        Xs, ys = self._problem(K=4 if jax.device_count() == 1 else
                               jax.device_count())
        loc = api.fit(CascadeStrategy(C=1.0, iters=60), (Xs, ys),
                      transport="allreduce", steps=3)
        mp = api.fit(CascadeStrategy(C=1.0, iters=60), (Xs, ys),
                     transport="allreduce", steps=3, executor="multipod")
        np.testing.assert_array_equal(np.asarray(loc.theta.sv_mask),
                                      np.asarray(mp.theta.sv_mask))
        s = mp.ledger.summary()
        assert sum(v["total_bytes"] for v in s["by_hop"].values()) \
            == loc.ledger.total_bytes

    def test_any_op_primitives(self):
        from repro.core.allreduce import server_allreduce

        m = jnp.asarray([[True, False, False], [False, False, True]])
        np.testing.assert_array_equal(
            np.asarray(server_allreduce(m, op="any")),
            np.array([True, False, True]),
        )


class TestThresholdWire:
    """The threshold sparsifier: value-dependent ratio, shape-static
    program — the knob that makes compression ratio sweepable."""

    def test_spec_parsing(self):
        w = api.make_wire("thresh:0.25")
        assert isinstance(w, api.ThresholdWire)
        assert w.tau == 0.25 and not w.error_feedback and not w.lossless
        wef = api.make_wire("thresh:0.25+ef")
        assert wef.error_feedback

    def test_push_cost_is_dynamic(self):
        w = api.make_wire("thresh:0.1")
        assert w.push_bytes(jnp.zeros(8)) is None

    def test_threshold_zero_meters_dense_count(self):
        X, y, w, n = _make_problem()
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", wire="thresh:0.0", steps=10)
        dense_up = 10 * X.shape[0] * n * (4 + 4)  # index + f32 per entry
        assert res.ledger.uplink_bytes == dense_up

    def test_higher_tau_fewer_bytes(self):
        X, y, w, n = _make_problem()
        lo = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                     transport="allreduce", wire="thresh:0.01", steps=20)
        hi = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                     transport="allreduce", wire="thresh:0.3", steps=20)
        assert hi.ledger.uplink_bytes < lo.ledger.uplink_bytes
        assert float(hi.trajectory[-1]) < float(hi.trajectory[0])

    def test_tau_sweeps_compression_ratio(self):
        """One executable, S thresholds: per-scenario results and byte
        totals match S independent fits — the ratio is now a swept axis
        (per-scenario top-k fractions would each need a static k)."""
        X, y, w, n = _make_problem()
        taus = (0.0, 0.05, 0.2)
        sw = api.SweepExecutor({"tau": jnp.asarray(taus)})
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", wire="thresh:0.1", steps=25,
                      executor=sw)
        totals = []
        for i, tau in enumerate(taus):
            solo = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                           transport="allreduce",
                           wire=api.ThresholdWire(tau), steps=25)
            np.testing.assert_allclose(np.asarray(res.theta[i]),
                                       np.asarray(solo.theta),
                                       rtol=1e-6, atol=1e-7)
            assert res.ledger[i].total_bytes == solo.ledger.total_bytes
            totals.append(res.ledger[i].total_bytes)
        assert totals[0] > totals[1] > totals[2]  # ratio actually swept

    def test_tau_sweep_with_error_feedback(self):
        X, y, w, n = _make_problem()
        sw = api.SweepExecutor({"tau": jnp.asarray([0.02, 0.2])})
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", wire="thresh:0.1+ef", steps=20,
                      executor=sw)
        solo = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="allreduce",
                       wire=api.ThresholdWire(0.2, error_feedback=True),
                       steps=20)
        np.testing.assert_allclose(np.asarray(res.theta[1]),
                                   np.asarray(solo.theta),
                                   rtol=1e-6, atol=1e-7)
        assert res.ledger[1].total_bytes == solo.ledger.total_bytes

    def test_mesh_placement_matches_local(self):
        X, y, w, n = _make_problem()
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", wire="thresh:0.05+ef", steps=20)
        mesh = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="allreduce", wire="thresh:0.05+ef", steps=20,
                       executor="mesh")
        np.testing.assert_allclose(np.asarray(mesh.theta), np.asarray(loc.theta),
                                   rtol=1e-5, atol=1e-6)
        assert mesh.ledger.summary() == loc.ledger.summary()

    def test_admm_rejects_lossy_threshold(self):
        from repro.ml.linear import lasso_prox_builder

        X, y, w, n = _make_problem(K=4)
        with pytest.raises(ValueError, match="lossless"):
            api.fit(api.ProxStrategy(lasso_prox_builder), (X, y),
                    transport="admm_consensus", steps=5, g="l1", g_lam=0.1,
                    wire="thresh:0.1")


class TestTopologyLedger:
    """core.topology decomposition + CommLedger per-hop accounting."""

    def test_hop_messages_telescope(self):
        from repro.core.topology import Topology

        topo = Topology.from_mesh(("pod", "data"))
        msgs = topo.hop_messages(8, {"pod": 2, "data": 4})
        assert [(n, m) for n, m, _ in msgs] == [
            ("intra_pod", 6), ("inter_pod", 2)
        ]
        assert sum(m for _, m, _ in msgs) == 8

    def test_flat_topology_single_tier(self):
        from repro.core.topology import Topology

        topo = Topology.from_mesh(("data",))
        assert topo.tiers == ("flat",)
        assert topo.hop_messages(8, {"data": 4}) == [("flat", 8, 1.0)]

    def test_duplicate_axis_rejected(self):
        from repro.core.topology import Hop, Topology

        with pytest.raises(ValueError, match="more than one hop"):
            Topology((Hop(("data",), "a"), Hop(("data",), "b")))

    def test_record_hop(self):
        from repro.core.allreduce import CommLedger

        led = CommLedger()
        led.record_hop(jnp.zeros(4), "intra_pod", fanin=6)
        led.record_hop(jnp.zeros(4), "inter_pod", fanin=2,
                       price_per_byte=10.0)
        s = led.summary()
        assert led.total_bytes == (6 + 2) * 16 * 2
        assert s["by_hop"]["intra_pod"]["uplink_bytes"] == 96
        assert s["by_hop"]["inter_pod"]["uplink_bytes"] == 32
        assert s["priced_cost"] == 96 * 2 + 32 * 2 * 10.0

    def test_attribute_hops_preserves_totals(self):
        from repro.core.allreduce import CommLedger

        led = CommLedger(uplink_bytes=1001, downlink_bytes=777)
        led.attribute_hops([("intra_pod", 6, 1.0), ("inter_pod", 2, 10.0)])
        s = led.summary()
        assert sum(v["uplink_bytes"] for v in s["by_hop"].values()) == 1001
        assert sum(v["downlink_bytes"] for v in s["by_hop"].values()) == 777

    def test_merge_folds_hops(self):
        from repro.core.allreduce import CommLedger

        a, b = CommLedger(), CommLedger()
        a.record_hop(jnp.zeros(2), "inter_pod", fanin=1)
        b.record_hop(jnp.zeros(2), "inter_pod", fanin=3)
        a.merge(b)
        assert a.hops["inter_pod"]["uplink_bytes"] == 8 + 24

    def test_merge_mixed_prices_stays_exact(self):
        """Merging ledgers priced under different link prices keeps the
        exact cost (per-contribution accumulation, not first-price-wins)."""
        from repro.core.allreduce import CommLedger

        a, b = CommLedger(), CommLedger()
        a.record_hop(jnp.zeros(25), "inter_pod", fanin=1, price_per_byte=10.0)
        b.record_hop(jnp.zeros(25), "inter_pod", fanin=1, price_per_byte=100.0)
        a.merge(b)
        # 200 bytes @ x10 + 200 bytes @ x100
        assert a.priced_cost() == 200 * 10.0 + 200 * 100.0
        # summary reports the byte-weighted effective price
        assert a.summary()["by_hop"]["inter_pod"]["price_per_byte"] == 55.0

    def test_merge_empty_ledger_is_identity(self):
        """Folding a fresh ledger in (either direction) changes nothing —
        the executor merge path hits this every time a shard was idle."""
        from repro.core.allreduce import CommLedger

        a = CommLedger()
        a.record_hop(jnp.zeros(4), "inter_pod", fanin=2, price_per_byte=3.0)
        before = a.summary()
        a.merge(CommLedger())
        assert a.summary() == before

        empty = CommLedger()
        empty.merge(a)
        assert empty.summary() == before

    def test_zero_byte_hop_keeps_decomposition_consistent(self):
        """A hop that moved nothing (empty tree / fanin 0) must neither
        poison priced_cost nor divide-by-zero in the summary."""
        from repro.core.allreduce import CommLedger

        led = CommLedger()
        led.record_hop(jnp.zeros(4), "intra_pod", fanin=0,
                       price_per_byte=10.0)
        assert led.total_bytes == 0
        assert led.priced_cost() == 0.0
        s = led.summary()
        assert s["by_hop"]["intra_pod"]["total_bytes"] == 0
        # effective price of zero bytes reports the flat default, not NaN
        assert s["by_hop"]["intra_pod"]["price_per_byte"] == 1.0

    def test_merge_disjoint_hop_sets_unions(self):
        """Ledgers recorded on different tiers (e.g. one pod's intra-pod
        stage, another's inter-pod stage) merge to the union with each
        bucket intact."""
        from repro.core.allreduce import CommLedger

        a, b = CommLedger(), CommLedger()
        a.record_hop(jnp.zeros(4), "intra_pod", fanin=6)
        b.record_hop(jnp.zeros(4), "inter_pod", fanin=2,
                     price_per_byte=10.0)
        a.merge(b)
        assert set(a.hops) == {"intra_pod", "inter_pod"}
        assert a.hops["intra_pod"]["uplink_bytes"] == 96
        assert a.hops["inter_pod"]["uplink_bytes"] == 32
        assert a.priced_cost() == 96 * 2 + 32 * 2 * 10.0
        # and the flat totals still cover exactly the attributed bytes
        assert a.total_bytes == sum(
            h["uplink_bytes"] + h["downlink_bytes"] for h in a.hops.values()
        )

    def test_attribute_hops_on_empty_ledger(self):
        """Attributing zero recorded bytes is legal (tiers all get 0);
        a non-positive message count is the caller bug that raises."""
        from repro.core.allreduce import CommLedger

        led = CommLedger()
        led.attribute_hops([("intra_pod", 6, 1.0), ("inter_pod", 2, 10.0)])
        assert led.total_bytes == 0
        assert all(
            h["uplink_bytes"] == h["downlink_bytes"] == 0
            for h in led.hops.values()
        )
        with pytest.raises(ValueError, match="positive message count"):
            CommLedger(uplink_bytes=8).attribute_hops([("flat", 0, 1.0)])

    def test_hierarchical_allreduce_flat_hop_is_mesh_allreduce(self):
        """A single flat hop over all node axes is exactly the joint
        collective (the bit-exact degradation the refactor promises)."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.core.allreduce import hierarchical_allreduce, mesh_allreduce
        from repro.core.topology import Topology
        from repro.launch.mesh import make_node_mesh

        mesh = make_node_mesh()
        topo = Topology.flat(("data",))
        x = jnp.arange(jax.device_count() * 3, dtype=jnp.float32)

        def staged(v):
            return hierarchical_allreduce(v, topo.hops)

        def joint(v):
            return mesh_allreduce(v, "data")

        fa = shard_map(staged, mesh=mesh, in_specs=P("data"), out_specs=P())
        fb = shard_map(joint, mesh=mesh, in_specs=P("data"), out_specs=P())
        np.testing.assert_array_equal(np.asarray(fa(x)), np.asarray(fb(x)))


class TestSweepEquivalence:
    """sweep over S scenarios ≡ S independent fits; ledgers bit-for-bit."""

    LRS = (0.02, 0.05, 0.1, 0.2)

    def test_lr_sweep_matches_independent_fits(self):
        X, y, w, n = _make_problem()
        sw = api.SweepExecutor({"lr": jnp.asarray(self.LRS)})
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=30, executor=sw)
        assert np.asarray(res.theta).shape == (4, n)
        assert np.asarray(res.trajectory).shape == (4, 30)
        assert isinstance(res.ledger, list) and len(res.ledger) == 4
        for i, lr in enumerate(self.LRS):
            solo = api.fit(api.GradientDescent(lsq_loss, lr=lr), (X, y),
                           transport="allreduce", steps=30)
            np.testing.assert_allclose(np.asarray(res.theta[i]),
                                       np.asarray(solo.theta),
                                       rtol=1e-6, atol=1e-7)
            np.testing.assert_allclose(np.asarray(res.trajectory[i]),
                                       np.asarray(solo.trajectory),
                                       rtol=1e-6, atol=1e-7)
            # acceptance: byte totals bit-for-bit
            assert res.ledger[i].uplink_bytes == solo.ledger.uplink_bytes
            assert res.ledger[i].downlink_bytes == solo.ledger.downlink_bytes
            assert res.ledger[i].rounds == solo.ledger.rounds

    def test_staleness_sweep_matches_independent_fits(self):
        """S staleness levels share one depth-max(D) delay line read at a
        batched index — one compiled executable."""
        X, y, w, n = _make_problem()
        Ds = (0, 1, 2, 3)
        sw = api.SweepExecutor({"staleness": jnp.asarray(Ds)})
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                      transport="delay_line", steps=40, executor=sw)
        for i, D in enumerate(Ds):
            solo = api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                           transport="delay_line", staleness=D, steps=40)
            np.testing.assert_allclose(np.asarray(res.theta[i]),
                                       np.asarray(solo.theta),
                                       rtol=1e-6, atol=1e-7)
            assert res.ledger[i].total_bytes == solo.ledger.total_bytes

    def test_theta0_sweep(self):
        X, y, w, n = _make_problem()
        theta0s = jnp.asarray(np.random.default_rng(1).normal(size=(3, n)))
        sw = api.SweepExecutor({"theta0": theta0s})
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=20, executor=sw)
        for i in range(3):
            solo = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                           transport="allreduce", steps=20,
                           theta0=theta0s[i])
            np.testing.assert_allclose(np.asarray(res.theta[i]),
                                       np.asarray(solo.theta),
                                       rtol=1e-6, atol=1e-7)

    def test_pytree_theta0_sweep(self):
        """theta0 may be a model PYTREE with batched leaves (the
        launch/train.py param dicts), not just a flat vector."""
        from repro.api.strategy import OptimizerStrategy
        from repro.optim import adam

        rng = np.random.default_rng(2)
        Xb = jnp.asarray(rng.normal(size=(6, 4, 3)))
        yb = jnp.asarray(rng.normal(size=(6, 4)))

        def loss_fn(theta, batch):
            Xt, yt = batch
            return 0.5 * jnp.mean(((Xt @ theta["w"]) + theta["b"] - yt) ** 2)

        theta0s = {
            "w": jnp.asarray(rng.normal(size=(2, 3))),
            "b": jnp.asarray(rng.normal(size=(2,))),
        }
        sw = api.SweepExecutor({"theta0": theta0s})
        assert sw.num_scenarios == 2
        res = api.fit(OptimizerStrategy(loss_fn, adam(0.1)), None,
                      transport="delay_line", staleness=0,
                      stream=(Xb, yb), executor=sw)
        for i in range(2):
            solo = api.fit(OptimizerStrategy(loss_fn, adam(0.1)), None,
                           transport="delay_line", staleness=0,
                           stream=(Xb, yb),
                           theta0=jax.tree.map(lambda x: x[i], theta0s))
            np.testing.assert_allclose(np.asarray(res.theta["w"][i]),
                                       np.asarray(solo.theta["w"]),
                                       rtol=1e-6, atol=1e-7)

    def test_sweep_carry_resume(self):
        """A swept run resumes from its batched carry."""
        X, y, w, n = _make_problem()
        sw = api.SweepExecutor({"lr": jnp.asarray(self.LRS)})
        full = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="allreduce", steps=30, executor=sw)
        a = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=15, executor=sw)
        b = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=15, executor=sw,
                    carry=a.metrics["carry"])
        np.testing.assert_allclose(np.asarray(b.theta), np.asarray(full.theta),
                                   rtol=1e-6, atol=1e-7)

    def test_compressed_wire_sweeps(self):
        """EF residual state batches per scenario alongside θ."""
        X, y, w, n = _make_problem()
        sw = api.SweepExecutor({"lr": jnp.asarray([0.05, 0.1])})
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", wire="topk:0.5+ef", steps=20,
                      executor=sw)
        solo = api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                       transport="allreduce", wire="topk:0.5+ef", steps=20)
        np.testing.assert_allclose(np.asarray(res.theta[0]),
                                   np.asarray(solo.theta),
                                   rtol=1e-6, atol=1e-7)
        assert res.ledger[0].total_bytes == solo.ledger.total_bytes


class TestMeshSweepComposition:
    """mesh+sweep (scenario vmap INSIDE the shard_map body) ≡ S
    independent fits on the inner mesh executor: theta and per-scenario
    ledger byte totals BIT-exact, trajectory to fp tolerance (the
    vmapped loss-metric mean reduces in a different order)."""

    LRS = (0.02, 0.05, 0.1, 0.2)

    def test_lr_sweep_matches_independent_mesh_fits(self):
        X, y, w, n = _make_problem()
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=30,
                      executor="mesh+sweep",
                      sweep={"lr": jnp.asarray(self.LRS)})
        assert res.metrics["executor"] == "mesh+sweep"
        assert np.asarray(res.theta).shape == (4, n)
        assert isinstance(res.ledger, list) and len(res.ledger) == 4
        for i, lr in enumerate(self.LRS):
            solo = api.fit(api.GradientDescent(lsq_loss, lr=lr), (X, y),
                           transport="allreduce", steps=30, executor="mesh")
            np.testing.assert_array_equal(np.asarray(res.theta[i]),
                                          np.asarray(solo.theta))
            np.testing.assert_allclose(np.asarray(res.trajectory[i]),
                                       np.asarray(solo.trajectory),
                                       rtol=1e-5, atol=1e-7)
            assert res.ledger[i].uplink_bytes == solo.ledger.uplink_bytes
            assert res.ledger[i].downlink_bytes == solo.ledger.downlink_bytes
            assert res.ledger[i].rounds == solo.ledger.rounds

    def test_staleness_sweep_composes_with_mesh(self):
        """The shared depth-max(D) delay line reads at a per-scenario
        index inside the shard_map body — D levels × mesh placement in
        one executable."""
        X, y, w, n = _make_problem()
        Ds = (0, 1, 3)
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                      transport="delay_line", steps=25,
                      executor=api.SweepExecutor({"staleness": jnp.asarray(Ds)},
                                                 inner=api.MeshExecutor()))
        for i, D in enumerate(Ds):
            solo = api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                           transport="delay_line", staleness=D, steps=25,
                           executor="mesh")
            np.testing.assert_array_equal(np.asarray(res.theta[i]),
                                          np.asarray(solo.theta))
            assert res.ledger[i].total_bytes == solo.ledger.total_bytes

    def test_tau_sweep_composes_with_mesh(self):
        """Swept WIRE attributes (the threshold sparsifier's τ) ride the
        composed executable; the traced per-scenario byte counts psum
        across shards and still match independent mesh fits exactly."""
        X, y, w, n = _make_problem()
        taus = (0.0, 0.05, 0.2)
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", wire="thresh:0.1", steps=25,
                      executor="mesh+sweep", sweep={"tau": jnp.asarray(taus)})
        totals = []
        for i, tau in enumerate(taus):
            solo = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                           transport="allreduce",
                           wire=api.ThresholdWire(tau), steps=25,
                           executor="mesh")
            np.testing.assert_array_equal(np.asarray(res.theta[i]),
                                          np.asarray(solo.theta))
            assert res.ledger[i].total_bytes == solo.ledger.total_bytes
            totals.append(res.ledger[i].total_bytes)
        assert totals[0] > totals[1] > totals[2]  # ratio actually swept

    def test_multipod_inner_keeps_per_hop_split(self):
        """Under a multipod inner every scenario's ledger decomposes per
        hop, each split summing exactly to that scenario's flat total."""
        X, y, w, n = _make_problem()
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=20,
                      executor="multipod+sweep",
                      sweep={"lr": jnp.asarray(self.LRS)})
        assert res.metrics["executor"] == "multipod+sweep"
        for i in range(len(self.LRS)):
            s = res.ledger[i].summary()
            assert set(s["by_hop"]) == {"intra_pod", "inter_pod"}
            assert all(v["total_bytes"] > 0 for v in s["by_hop"].values())
            assert sum(v["total_bytes"] for v in s["by_hop"].values()) \
                == res.ledger[i].total_bytes

    def test_composed_resume(self):
        """A composed run's batched carry resumes a later composed fit —
        EF wire state included — matching one uninterrupted run."""
        X, y, w, n = _make_problem()
        kw = dict(executor="mesh+sweep",
                  sweep={"staleness": jnp.asarray([0, 2])},
                  transport="delay_line", wire="topk:0.5+ef")
        full = api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                       steps=30, **kw)
        a = api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                    steps=15, **kw)
        b = api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                    steps=15, carry=a.metrics["carry"], **kw)
        np.testing.assert_array_equal(np.asarray(b.theta),
                                      np.asarray(full.theta))

    def test_theta0_sweep_composes(self):
        X, y, w, n = _make_problem()
        theta0s = jnp.asarray(np.random.default_rng(1).normal(size=(3, n)))
        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=20,
                      executor=api.SweepExecutor({"theta0": theta0s},
                                                 inner=api.MeshExecutor()))
        for i in range(3):
            solo = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                           transport="allreduce", steps=20,
                           theta0=theta0s[i], executor="mesh")
            np.testing.assert_array_equal(np.asarray(res.theta[i]),
                                          np.asarray(solo.theta))

    def test_spec_strings_and_sweep_kwarg(self):
        sw = {"lr": jnp.asarray([0.1, 0.2])}
        ex = api.make_executor("mesh+sweep", sw)
        assert isinstance(ex, api.SweepExecutor)
        assert isinstance(ex.inner, api.MeshExecutor)
        assert ex.name == "mesh+sweep"
        ex = api.make_executor("multipod+sweep", sw)
        assert isinstance(ex.inner, api.MultiPodExecutor)
        assert api.make_executor("sweep", sw).inner is None
        # local inner collapses to the plain vmapped sweep
        assert api.SweepExecutor(sw, inner="local").inner is None
        assert set(api.COMPOSED_EXECUTORS) == {"mesh+sweep", "multipod+sweep"}

    def test_composition_errors(self):
        sw = {"lr": jnp.asarray([0.1, 0.2])}
        with pytest.raises(ValueError, match="scenario parameters"):
            api.make_executor("mesh+sweep")
        with pytest.raises(ValueError, match="sweep"):
            api.make_executor("mesh", sw)  # params without a sweep spec
        with pytest.raises(ValueError, match="sweep"):
            api.make_executor(api.MeshExecutor(), sw)  # instance + sweep=
        with pytest.raises(ValueError, match="nest"):
            api.SweepExecutor(sw, inner=api.ServingExecutor())
        with pytest.raises(ValueError, match="unknown executor"):
            api.make_executor("serve+sweep", sw)


class TestExecutorErrors:
    def test_unknown_executor(self):
        with pytest.raises(ValueError, match="unknown executor"):
            api.make_executor("cluster")

    def test_bare_sweep_string_rejected(self):
        with pytest.raises(ValueError, match="SweepExecutor"):
            api.make_executor("sweep")

    def test_sweep_needs_params(self):
        with pytest.raises(ValueError, match="at least one"):
            api.SweepExecutor({})

    def test_sweep_scenario_count_mismatch(self):
        with pytest.raises(ValueError, match="disagree"):
            api.SweepExecutor({"lr": jnp.zeros(3), "l2": jnp.zeros(4)})

    def test_sweep_unknown_attribute(self):
        X, y, w, n = _make_problem(K=4)
        sw = api.SweepExecutor({"momentum": jnp.asarray([0.1, 0.2])})
        with pytest.raises(ValueError, match="momentum"):
            api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=3, executor=sw)

    def test_server_transport_rejects_sweep(self):
        X, y, w, n = _make_problem(K=4)
        sw = api.SweepExecutor({"lr": jnp.asarray([0.1, 0.2])})
        with pytest.raises(ValueError, match="local"):
            api.fit(api.FunctionStrategy(lambda k, t: t, num_nodes=4),
                    transport="sequential_server",
                    schedule=schedules.round_robin(4, 2),
                    theta0=jnp.zeros(n), executor=sw)

    def test_all_executors_listed(self):
        assert set(api.EXECUTORS) == {
            "local", "mesh", "multipod", "sweep", "serve"
        }

    def test_explicit_local_is_default(self):
        X, y, w, n = _make_problem(K=4)
        a = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=10)
        b = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                    transport="allreduce", steps=10, executor="local")
        np.testing.assert_array_equal(np.asarray(a.theta), np.asarray(b.theta))
        assert a.ledger.summary() == b.ledger.summary()


class TestDynamicDelayRead:
    """core.staleness.delay_push_read ≡ delay_push_pop at delay == depth."""

    def test_matches_push_pop_at_full_depth(self):
        from repro.core.staleness import delay_init, delay_push_pop, delay_push_read

        rng = np.random.default_rng(0)
        D = 3
        a = delay_init(jnp.zeros(4), D)
        b = delay_init(jnp.zeros(4), D)
        for t in range(8):
            g = jnp.asarray(rng.normal(size=4))
            a, pa = delay_push_pop(a, g)
            b, pb = delay_push_read(b, g, jnp.asarray(D))
            np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
            np.testing.assert_array_equal(np.asarray(a.buffer), np.asarray(b.buffer))

    def test_zero_delay_reads_fresh(self):
        from repro.core.staleness import delay_init, delay_push_read

        s = delay_init(jnp.zeros(3), 2)
        g = jnp.asarray([1.0, 2.0, 3.0])
        _, read = delay_push_read(s, g, jnp.asarray(0))
        np.testing.assert_array_equal(np.asarray(read), np.asarray(g))


class TestReduceScatterStaging:
    """MeshExecutor(reduce_scatter=True) restages the innermost hop as
    psum_scatter → all_gather — BIT-exact with the flat staged psum
    (same additions, same order, different wire schedule)."""

    @pytest.mark.parametrize(
        "transport,kw", [("allreduce", {}), ("delay_line", {"staleness": 2})]
    )
    def test_rs_on_off_bitwise(self, transport, kw):
        X, y, w, n = _make_problem()
        on = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                     transport=transport, steps=30,
                     executor=api.MeshExecutor(reduce_scatter=True), **kw)
        off = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport=transport, steps=30,
                      executor=api.MeshExecutor(reduce_scatter=False), **kw)
        np.testing.assert_array_equal(np.asarray(on.theta),
                                      np.asarray(off.theta))
        np.testing.assert_array_equal(np.asarray(on.trajectory),
                                      np.asarray(off.trajectory))
        assert on.ledger.summary() == off.ledger.summary()

    def test_auto_resolution(self):
        assert api.MeshExecutor(reduce_scatter=True)._rs_active() is True
        assert api.MeshExecutor(reduce_scatter=False)._rs_active() is False
        auto = api.MeshExecutor()._rs_active()
        assert auto is (jax.default_backend() == "tpu")


class TestCommComputeOverlap:
    """MeshExecutor(overlap=True) dispatches the outermost hop against
    the NEXT round's local compute on delay-tolerant transports.  The
    schedule change re-slots which delay-buffer entry completes when —
    but the values entering each round are identical, so theta,
    trajectory, ledger AND the resume carry are bit-exact with
    overlap=False."""

    @pytest.mark.parametrize("staleness", [1, 2])
    def test_overlap_on_off_bitwise(self, staleness):
        X, y, w, n = _make_problem()
        on = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                     transport="delay_line", staleness=staleness, steps=30,
                     executor=api.MeshExecutor(overlap=True))
        off = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="delay_line", staleness=staleness, steps=30,
                      executor=api.MeshExecutor(overlap=False))
        loc = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="delay_line", staleness=staleness, steps=30)
        for a, b in [(on, off), (on, loc)]:
            np.testing.assert_array_equal(np.asarray(a.theta),
                                          np.asarray(b.theta))
            np.testing.assert_array_equal(np.asarray(a.trajectory),
                                          np.asarray(b.trajectory))
            assert a.ledger.summary() == b.ledger.summary()

    @pytest.mark.parametrize("staleness", [1, 2])
    def test_resume_carry_interchangeable(self, staleness):
        """A carry saved from an overlapped run resumes bit-exactly on a
        non-overlapped executor (and vice versa): exit_loop converts the
        in-flight pending partial back to plain delay-line layout."""
        X, y, w, n = _make_problem()
        gd = lambda: api.GradientDescent(lsq_loss, lr=0.1)
        full = api.fit(gd(), (X, y), transport="delay_line",
                       staleness=staleness, steps=30)
        for ex_a, ex_b in [
            (api.MeshExecutor(overlap=True), api.MeshExecutor(overlap=False)),
            (api.MeshExecutor(overlap=False), api.MeshExecutor(overlap=True)),
            (api.MeshExecutor(overlap=True), "local"),
        ]:
            first = api.fit(gd(), (X, y), transport="delay_line",
                            staleness=staleness, steps=15, executor=ex_a)
            second = api.fit(gd(), (X, y), transport="delay_line",
                             staleness=staleness, steps=15, executor=ex_b,
                             carry=first.metrics["carry"])
            np.testing.assert_array_equal(np.asarray(second.theta),
                                          np.asarray(full.theta))

    def test_overlap_declined_for_mean_aggregate(self):
        """LBFGS aggregates with op="mean" — the overlap split's deferred
        outer hop cannot carry the final divide, so the transport declines
        overlap and runs the synchronous schedule (still correct)."""
        X, y, w, n = _make_problem()
        on = api.fit(api.LBFGS(lsq_loss), (X, y), transport="delay_line",
                     staleness=1, steps=15,
                     executor=api.MeshExecutor(overlap=True))
        loc = api.fit(api.LBFGS(lsq_loss), (X, y), transport="delay_line",
                      staleness=1, steps=15)
        np.testing.assert_allclose(np.asarray(on.theta), np.asarray(loc.theta),
                                   rtol=1e-5, atol=1e-6)


class TestCalibratedPrices:
    """MultiPodExecutor(calibrate=True) replaces the x1/x10 default hop
    prices with measured per-byte costs (core.topology.calibrate_prices):
    placement and math are untouched — only the priced ledger changes."""

    def test_calibrate_smoke(self):
        X, y, w, n = _make_problem()
        cal = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=10,
                      executor=api.MultiPodExecutor(calibrate=True))
        ref = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                      transport="allreduce", steps=10, executor="multipod")
        np.testing.assert_array_equal(np.asarray(cal.theta),
                                      np.asarray(ref.theta))
        s_cal, s_ref = cal.ledger.summary(), ref.ledger.summary()
        assert set(s_cal["by_hop"]) == set(s_ref["by_hop"])
        for hop, v in s_cal["by_hop"].items():
            assert v["total_bytes"] == s_ref["by_hop"][hop]["total_bytes"]
            assert v["price_per_byte"] > 0.0

    def test_explicit_price_beats_calibration(self):
        ex = api.MultiPodExecutor(calibrate=True, inter_price=42.0)
        r = ex.resolve()
        inter = [h for h in r.topology.hops if h.name == "inter_pod"]
        if inter:  # single-device meshes may degrade to one tier
            assert inter[0].price_per_byte == 42.0

    def test_calibrate_prices_memoized(self):
        from repro.core.topology import calibrate_prices
        mesh = api.MeshExecutor().resolve().mesh
        a = calibrate_prices(mesh)
        b = calibrate_prices(mesh)  # second call is the memo (copied out)
        assert a == b
        assert a["calibrated"] is True
        assert a["intra_pod"] > 0.0 and a["inter_pod"] > 0.0


class TestProgramCache:
    """Executors memoize their jitted placed program by config
    fingerprint (Strategy.cache_token + wire + transport shape) so
    repeated fits skip retrace/relower — the core of the mesh speedup."""

    def setup_method(self):
        from repro.api import executor as _exec
        _exec.clear_program_cache()

    def _fit(self, **kw):
        X, y, w, n = _make_problem()
        st = kw.pop("strategy", None) or api.GradientDescent(lsq_loss, lr=0.1)
        return st, api.fit(st, (X, y), transport="allreduce", steps=10, **kw)

    def test_repeat_fit_hits(self):
        from repro.api import executor as _exec
        st = api.GradientDescent(lsq_loss, lr=0.1)
        X, y, w, n = _make_problem()
        api.fit(st, (X, y), transport="allreduce", steps=10, executor="mesh")
        miss0 = _exec.program_cache_stats()["misses"]
        res = api.fit(st, (X, y), transport="allreduce", steps=10,
                      executor="mesh")
        stats = _exec.program_cache_stats()
        assert stats["hits"] >= 1
        assert stats["misses"] == miss0  # no new program built
        loc = api.fit(st, (X, y), transport="allreduce", steps=10)
        np.testing.assert_array_equal(np.asarray(res.theta),
                                      np.asarray(loc.theta))

    def test_different_config_misses(self):
        from repro.api import executor as _exec
        st = api.GradientDescent(lsq_loss, lr=0.1)
        X, y, w, n = _make_problem()
        api.fit(st, (X, y), transport="allreduce", steps=10, executor="mesh")
        m0 = _exec.program_cache_stats()["misses"]
        # different wire → different program
        api.fit(st, (X, y), transport="allreduce", steps=10, executor="mesh",
                wire="topk:0.5+ef")
        # different lr → different cache_token
        api.fit(api.GradientDescent(lsq_loss, lr=0.2), (X, y),
                transport="allreduce", steps=10, executor="mesh")
        assert _exec.program_cache_stats()["misses"] > m0

    def test_data_is_an_argument_not_baked(self):
        """Same config + different data must REUSE the program and
        produce the new data's answer (data is a jit argument)."""
        from repro.api import executor as _exec
        st = api.GradientDescent(lsq_loss, lr=0.1)
        X, y, w, n = _make_problem(seed=0)
        X2, y2, w2, _ = _make_problem(seed=1)
        api.fit(st, (X, y), transport="allreduce", steps=10, executor="mesh")
        m0 = _exec.program_cache_stats()["misses"]
        res = api.fit(st, (X2, y2), transport="allreduce", steps=10,
                      executor="mesh")
        assert _exec.program_cache_stats()["misses"] == m0
        loc = api.fit(st, (X2, y2), transport="allreduce", steps=10)
        np.testing.assert_array_equal(np.asarray(res.theta),
                                      np.asarray(loc.theta))

    def test_env_optout_bypasses(self, monkeypatch):
        from repro.api import executor as _exec
        monkeypatch.setenv("REPRO_PROGRAM_CACHE", "0")
        st = api.GradientDescent(lsq_loss, lr=0.1)
        X, y, w, n = _make_problem()
        api.fit(st, (X, y), transport="allreduce", steps=10, executor="mesh")
        api.fit(st, (X, y), transport="allreduce", steps=10, executor="mesh")
        assert _exec.program_cache_stats() == {
            "size": 0, "hits": 0, "misses": 0
        }

    def test_clear_resets(self):
        from repro.api import executor as _exec
        st = api.GradientDescent(lsq_loss, lr=0.1)
        X, y, w, n = _make_problem()
        api.fit(st, (X, y), transport="allreduce", steps=10, executor="mesh")
        assert _exec.program_cache_stats()["size"] >= 1
        _exec.clear_program_cache()
        assert _exec.program_cache_stats() == {
            "size": 0, "hits": 0, "misses": 0
        }
