"""Distributed SVMs (paper §3.2)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ml import svm


@pytest.fixture(scope="module")
def blobs():
    rng = np.random.default_rng(5)
    K, Nk, n = 4, 30, 2
    Xp = rng.normal(size=(K * Nk // 2, n)) + np.array([2.0, 2.0])
    Xm = rng.normal(size=(K * Nk // 2, n)) - np.array([2.0, 2.0])
    X = np.concatenate([Xp, Xm])
    y = np.concatenate([np.ones(len(Xp)), -np.ones(len(Xm))])
    perm = rng.permutation(len(X))
    X, y = X[perm], y[perm]
    return (
        jnp.asarray(X.reshape(K, Nk, n)),
        jnp.asarray(y.reshape(K, Nk)),
        jnp.asarray(X),
        jnp.asarray(y),
    )


def test_dual_svm_separates(blobs):
    _, _, X, y = blobs
    model = svm.dual_svm(X, y, C=1.0)
    acc = float(jnp.mean(jnp.sign(svm.decision_function(model, X)) == y))
    assert acc > 0.97


def test_dual_svm_sparse_alphas(blobs):
    _, _, X, y = blobs
    model = svm.dual_svm(X, y, C=1.0)
    assert int(jnp.sum(model.sv_mask)) < 0.3 * X.shape[0]


def test_decision_uses_only_svs(blobs):
    _, _, X, y = blobs
    model = svm.dual_svm(X, y, C=1.0)
    # zero out all non-SV alphas: decision must be unchanged
    alpha_masked = model.alpha * model.sv_mask
    model2 = svm.SVMModel(alpha_masked, model.X, model.y, model.sv_mask)
    np.testing.assert_allclose(
        svm.decision_function(model, X),
        svm.decision_function(model2, X),
        rtol=1e-4, atol=1e-5,
    )


def test_cascade_svm_accuracy_and_stability(blobs):
    Xs, ys, X, y = blobs
    res = svm.cascade_svm(Xs, ys, C=1.0, max_rounds=6)
    acc = float(jnp.mean(jnp.sign(svm.decision_function(res.model, X)) == y))
    assert acc > 0.97
    assert res.sv_counts[-1] == res.sv_counts[-2]  # SV set stabilized
    assert res.rounds <= 6


def test_cascade_cheaper_than_raw_data(blobs):
    Xs, ys, X, y = blobs
    res = svm.cascade_svm(Xs, ys, C=1.0, max_rounds=6)
    raw = X.size * 4 + y.size * 4
    assert res.ledger.total_bytes < raw  # only SVs crossed the network


def test_consensus_svm(blobs):
    Xs, ys, X, y = blobs
    res = svm.consensus_svm(Xs, ys, iters=60)
    acc = float(jnp.mean(jnp.sign(X @ res.z) == y))
    assert acc > 0.97


def test_weighted_dual_consensus(blobs):
    Xs, ys, X, y = blobs
    _, decide = svm.weighted_dual_consensus(Xs, ys)
    acc = float(jnp.mean(jnp.sign(decide(X)) == y))
    assert acc > 0.95


def test_rbf_kernel_nonlinear():
    rng = np.random.default_rng(7)
    # circle-in-circle: not linearly separable
    r1 = rng.normal(size=(60, 2)) * 0.3
    theta = rng.uniform(0, 2 * np.pi, size=60)
    r2 = np.stack([3 * np.cos(theta), 3 * np.sin(theta)], 1) + 0.1 * rng.normal(size=(60, 2))
    X = jnp.asarray(np.concatenate([r1, r2]))
    y = jnp.asarray(np.concatenate([np.ones(60), -np.ones(60)]))
    model = svm.dual_svm(X, y, C=5.0, kernel=lambda a, b: svm.rbf_kernel(a, b, 0.5), iters=800)
    dec = svm.decision_function(model, X, kernel=lambda a, b: svm.rbf_kernel(a, b, 0.5))
    assert float(jnp.mean(jnp.sign(dec) == y)) > 0.95
