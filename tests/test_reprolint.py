"""repro-lint: every rule catches its staged defect, idiomatic repo code
stays clean, suppressions need a justification, and the compat-matrix
pass fails when docs and code disagree (verified on a mutated fixture
copy of the real matrix).

Pure stdlib — none of these tests import jax, mirroring the CI ``lint``
job which runs without an accelerator runtime.
"""

import json
import os
import shutil
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from tools.reprolint import run_lint  # noqa: E402
from tools.reprolint.passes import ALL_RULES  # noqa: E402


def lint_src(tmp_path, source, name="mod.py", **kw):
    f = tmp_path / name
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(source))
    return run_lint([tmp_path], **kw)


def rules_of(findings):
    return [f.rule for f in findings]


# -- tracer-hygiene -----------------------------------------------------------


class TestTracerHygiene:
    def test_flags_branch_cast_and_host_sync_in_jit(self, tmp_path):
        fs = lint_src(tmp_path, """
            import jax

            @jax.jit
            def f(x):
                if x > 0:
                    x = x + 1
                while x < 3:
                    x = x * 2
                y = float(x)
                return x.item() + y
        """)
        msgs = [f.message for f in fs if f.rule == "tracer-hygiene"]
        assert len(msgs) == 4
        assert any("if x > 0" in m for m in msgs)
        assert any("while x < 3" in m for m in msgs)
        assert any("float()" in m for m in msgs)
        assert any(".item()" in m for m in msgs)

    def test_scan_body_and_lambda_positions_are_traced(self, tmp_path):
        fs = lint_src(tmp_path, """
            from jax import lax

            def outer(xs):
                def body(c, x):
                    if x > 0:
                        c = c + x
                    return c, c
                return lax.scan(body, 0.0, xs)
        """)
        assert rules_of(fs) == ["tracer-hygiene"]
        assert "scan body" in fs[0].message

    def test_static_args_shapes_and_none_checks_stay_clean(self, tmp_path):
        fs = lint_src(tmp_path, """
            from functools import partial
            import jax

            @partial(jax.jit, static_argnums=(1,), static_argnames=("mode",))
            def f(x, n, mode="fast", scale=None):
                if n > 3:                 # static_argnums -> concrete
                    x = x * n
                if mode == "fast":        # static_argnames -> concrete
                    x = x + 1
                if scale is not None:     # None-check is trace-static
                    x = x * scale
                if x.shape[0] > 8:        # shapes are trace-static
                    x = x[:8]
                for _ in range(x.ndim):   # ndim is trace-static
                    x = x.sum()
                return x
        """)
        assert fs == []

    def test_defaulted_params_are_closure_idiom_not_tracers(self, tmp_path):
        # def body(c, x, seg=seg): scan never passes `seg`; it holds the
        # concrete default (the sanctioned closure-avoidance idiom)
        fs = lint_src(tmp_path, """
            from jax import lax

            def outer(xs, segs):
                for seg in segs:
                    def body(c, x, seg=seg):
                        for u in seg.unit:
                            c = c + u
                        return c, c
                    c, _ = lax.scan(body, 0.0, xs)
                return c
        """)
        assert fs == []

    def test_untraced_functions_are_free(self, tmp_path):
        fs = lint_src(tmp_path, """
            def host(x):
                if x > 0:
                    return float(x)
                return bool(x)
        """)
        assert fs == []


# -- collective-discipline ----------------------------------------------------


class TestCollectiveDiscipline:
    def test_raw_collective_outside_executor_layer(self, tmp_path):
        fs = lint_src(tmp_path, """
            from jax import lax

            def aggregate(x):
                return lax.psum(x, "data")
        """, name="src/repro/strategies/bad.py")
        assert rules_of(fs) == ["collective-discipline"]
        assert "jax.lax.psum" in fs[0].message

    def test_executor_layer_files_are_allowed(self, tmp_path):
        fs = lint_src(tmp_path, """
            from jax import lax

            def aggregate(x):
                return lax.psum(x, "data")
        """, name="src/repro/api/executor.py")
        assert fs == []

    def test_undeclared_axis_literal_flagged_even_where_allowed(
        self, tmp_path
    ):
        (tmp_path / "mesh.py").write_text(textwrap.dedent("""
            import jax

            def make(devs):
                return jax.make_mesh((len(devs),), ("data",))
        """))
        fs = lint_src(tmp_path, """
            from jax import lax

            def aggregate(x):
                return lax.psum(x, "datum")
        """, name="src/repro/api/executor.py")
        assert rules_of(fs) == ["collective-discipline"]
        assert "'datum'" in fs[0].message and "'data'" in fs[0].message

    def test_repo_wrappers_sharing_collective_names_are_not_raw(
        self, tmp_path
    ):
        fs = lint_src(tmp_path, """
            from repro.core.allreduce import psum_like as psum

            def aggregate(x):
                return psum(x, "data")
        """, name="src/repro/strategies/ok.py")
        assert fs == []


# -- compat-matrix ------------------------------------------------------------


def _fixture_repo(tmp_path):
    """Copy the REAL api modules + executors doc into a fixture tree."""
    api = tmp_path / "src" / "repro" / "api"
    api.mkdir(parents=True)
    docs = tmp_path / "docs"
    docs.mkdir()
    for mod in ("transport.py", "executor.py"):
        shutil.copy(
            os.path.join(REPO, "src", "repro", "api", mod), api / mod
        )
    shutil.copy(
        os.path.join(REPO, "docs", "EXECUTORS.md"), docs / "EXECUTORS.md"
    )
    return tmp_path


class TestCompatMatrix:
    def test_real_matrix_agrees_with_code(self, tmp_path):
        repo = _fixture_repo(tmp_path)
        fs = run_lint(
            [repo / "src"], rules=["compat-matrix"], repo=repo,
            executors_doc=repo / "docs" / "EXECUTORS.md",
        )
        assert fs == []

    def test_mutated_matrix_cell_is_drift(self, tmp_path):
        repo = _fixture_repo(tmp_path)
        doc = repo / "docs" / "EXECUTORS.md"
        text = doc.read_text()
        # flip sequential_server × sweep from documented-✗ to documented-✓
        lines = text.splitlines()
        for i, line in enumerate(lines):
            if line.strip().startswith("| `sequential_server`"):
                cells = line.split("|")
                # flip the FIRST ✗ cell (the sweep column) to a ✓
                for j, c in enumerate(cells):
                    if "✗" in c:
                        cells[j] = c.replace("✗", "✓")
                        break
                lines[i] = "|".join(cells)
                break
        else:
            pytest.fail("sequential_server row not found in EXECUTORS.md")
        doc.write_text("\n".join(lines))
        fs = run_lint(
            [repo / "src"], rules=["compat-matrix"], repo=repo,
            executors_doc=doc,
        )
        assert len(fs) == 1
        assert fs[0].rule == "compat-matrix"
        assert "matrix drift" in fs[0].message
        assert "'sequential_server'" in fs[0].message

    def test_dropped_executor_column_is_reported(self, tmp_path):
        repo = _fixture_repo(tmp_path)
        ex = repo / "src" / "repro" / "api" / "executor.py"
        ex.write_text(ex.read_text().replace(
            'COMPOSED_EXECUTORS = ("mesh+sweep", "multipod+sweep")',
            'COMPOSED_EXECUTORS = ("mesh+sweep", "multipod+sweep", '
            '"serve+sweep")',
        ))
        fs = run_lint(
            [repo / "src"], rules=["compat-matrix"], repo=repo,
            executors_doc=repo / "docs" / "EXECUTORS.md",
        )
        assert any(
            "serve+sweep" in f.message and "missing from" in f.message
            for f in fs
        )

    def test_skipped_outside_a_repo(self, tmp_path):
        fs = lint_src(tmp_path, "x = 1\n", rules=["compat-matrix"])
        assert fs == []


# -- pallas-kernel ------------------------------------------------------------


class TestPallasKernel:
    BAD = """
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def launch(x, big):
            def kern(x_ref, o_ref):
                print("trace-time only")
                o_ref[...] = x_ref[...] + big
            return pl.pallas_call(
                kern,
                grid=(4, 4),
                in_specs=[pl.BlockSpec((8, 100), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((7, 128), lambda i, j: (i, j, 0)),
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                scratch_shapes=[((8, 128), jnp.float32)],
            )(x)
    """

    def test_staged_kernel_defects_all_fire(self, tmp_path):
        fs = lint_src(tmp_path, self.BAD)
        msgs = [f.message for f in fs if f.rule == "pallas-kernel"]
        assert any("print()" in m for m in msgs)
        assert any("closes over 'big'" in m for m in msgs)
        assert any("last dimension 100" in m for m in msgs)
        assert any("second-to-last dimension 7" in m for m in msgs)
        assert any("1 required parameter(s)" in m and "2 dimension(s)" in m
                   for m in msgs)
        assert any("returns 3 coordinate(s)" in m for m in msgs)
        assert any("memory space" in m for m in msgs)
        # kern(x_ref, o_ref) but the call supplies 1 in + 1 out + 1 scratch
        assert any("takes 2 ref parameter(s)" in m and "supplies 3" in m
                   for m in msgs)

    def test_kernel_arity_mismatch_fires(self, tmp_path):
        fs = lint_src(tmp_path, """
            import jax
            import jax.numpy as jnp
            from jax.experimental import pallas as pl

            def _k(x_ref, o_ref, res_ref):
                o_ref[...] = x_ref[...]
                res_ref[...] = x_ref[...]

            def launch(x):
                return pl.pallas_call(
                    _k,
                    grid=(4,),
                    in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                    out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                )(x)
        """)
        msgs = [f.message for f in fs if f.rule == "pallas-kernel"]
        assert any(
            "takes 3 ref parameter(s)" in m and "supplies 2" in m
            for m in msgs
        )

    def test_kernel_arity_unresolvable_specs_stay_silent(self, tmp_path):
        # out_shape built conditionally — count unknown, check must not guess
        fs = lint_src(tmp_path, """
            import jax
            import jax.numpy as jnp
            from jax.experimental import pallas as pl

            def _k(x_ref, o_ref):
                o_ref[...] = x_ref[...]

            def launch(x, with_res):
                if with_res:
                    out_shape = [jax.ShapeDtypeStruct(x.shape, x.dtype)] * 2
                else:
                    out_shape = [jax.ShapeDtypeStruct(x.shape, x.dtype)]
                return pl.pallas_call(
                    _k,
                    grid=(4,),
                    in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
                    out_specs=pl.BlockSpec((8, 128), lambda i: (i, 0)),
                    out_shape=out_shape,
                )(x)
        """)
        assert [f for f in fs if "ref parameter" in f.message] == []

    def test_real_kernels_are_clean(self):
        fs = run_lint(
            [os.path.join(REPO, "src", "repro", "kernels")],
            rules=["pallas-kernel"],
        )
        assert fs == []

    def test_partial_bound_kernel_and_defaulted_index_map_ok(self, tmp_path):
        fs = lint_src(tmp_path, """
            import functools
            import jax
            from jax.experimental import pallas as pl
            from jax.experimental.pallas import tpu as pltpu
            import jax.numpy as jnp

            BQ = 128
            G = 4

            def _kern(q_ref, o_ref, acc_ref, *, scale):
                o_ref[...] = q_ref[...] * scale

            def launch(q):
                kernel = functools.partial(_kern, scale=2.0)
                grid = (8, 4)
                return pl.pallas_call(
                    kernel,
                    grid=grid,
                    in_specs=[
                        pl.BlockSpec((8, BQ), lambda i, j, G=G: (i, j // G)),
                    ],
                    out_specs=pl.BlockSpec((8, BQ), lambda i, j: (i, j)),
                    out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
                    scratch_shapes=[pltpu.VMEM((8, BQ), jnp.float32)],
                )(q)
        """)
        assert fs == []


# -- ledger-completeness ------------------------------------------------------


class TestLedgerCompleteness:
    def test_dropped_byte_counts_and_dead_ledger(self, tmp_path):
        fs = lint_src(tmp_path, """
            from repro.core.allreduce import CommLedger

            def round_trip(wire, wstate, msgs, theta):
                wire.encode_updates(wstate, msgs)
                wstate, payload, _ = wire.encode_push(wstate, 0, theta, theta)
                wire.measure(theta)
                led = CommLedger()
                return payload
        """)
        msgs = [f.message for f in fs if f.rule == "ledger-completeness"]
        assert len(msgs) == 4
        assert any(".encode_updates(...) result discarded" in m for m in msgs)
        assert any("bound to '_' and never read" in m for m in msgs)
        assert any("byte measurement" in m for m in msgs)
        assert any("CommLedger bound to 'led'" in m for m in msgs)

    def test_accounted_flow_is_clean(self, tmp_path):
        fs = lint_src(tmp_path, """
            from repro.core.allreduce import CommLedger

            def round_trip(wire, wstate, msgs, theta, _exec):
                wstate, msgs_hat, up = wire.encode_updates(wstate, msgs)
                up = _exec.sum_bytes(up)
                led = CommLedger()
                led.record_push(theta)
                down = wire.measure(theta)
                return msgs_hat, up, down, led
        """)
        assert fs == []


# -- retrace-smell ------------------------------------------------------------


class TestRetraceSmell:
    def test_static_argnum_drift_and_mutable_default(self, tmp_path):
        fs = lint_src(tmp_path, """
            import jax

            fast = jax.jit(lambda a, b: a + b, static_argnums=(5,))
            named = jax.jit(lambda a, b: a - b, static_argnames="nope")

            @jax.jit
            def f(x, opts={}):
                for row in x:
                    opts = row
                return opts
        """)
        msgs = [f.message for f in fs if f.rule == "retrace-smell"]
        assert any("static_argnums=5" in m for m in msgs)
        assert any("'nope'" in m for m in msgs)
        assert any("mutable (non-hashable) default" in m for m in msgs)
        assert any("Python iteration over `x`" in m for m in msgs)

    def test_valid_static_args_clean(self, tmp_path):
        fs = lint_src(tmp_path, """
            import jax

            fast = jax.jit(lambda a, b: a + b, static_argnums=(1,))

            @jax.jit
            def f(x, mode=None):
                return x
        """)
        assert fs == []


# -- span-discipline ----------------------------------------------------------


class TestSpanDiscipline:
    def test_raw_primitives_and_dropped_span(self, tmp_path):
        fs = lint_src(tmp_path, """
            def f(tracer, work):
                rec = tracer.span_begin("round")
                work()
                tracer.span_end(rec)
                tracer.span("dropped", tag=1)
        """, name="src/repro/api/mod.py")
        msgs = [f.message for f in fs if f.rule == "span-discipline"]
        assert len(msgs) == 3
        assert any("raw span_begin(...)" in m for m in msgs)
        assert any("raw span_end(...)" in m for m in msgs)
        assert any("bare statement" in m for m in msgs)

    def test_context_managed_and_regex_span_clean(self, tmp_path):
        fs = lint_src(tmp_path, """
            from contextlib import nullcontext

            def f(tracer, tr, work, m):
                with tracer.span("round", nodes=8):
                    work()
                with tr.span("maybe") if tr is not None else nullcontext():
                    work()
                return m.span()
        """, name="src/repro/api/mod.py")
        assert fs == []

    def test_outside_src_repro_exempt(self, tmp_path):
        fs = lint_src(tmp_path, """
            def f(tracer):
                tracer.span_begin("bench")
        """, name="benchmarks/mod.py")
        assert [f for f in fs if f.rule == "span-discipline"] == []


# -- suppressions -------------------------------------------------------------


class TestSuppressions:
    BAD_IF = """
        import jax

        @jax.jit
        def f(x):
            if x > 0:{comment}
                x = x + 1
            return x
    """

    def test_justified_suppression_silences(self, tmp_path):
        fs = lint_src(tmp_path, self.BAD_IF.format(
            comment="  # reprolint: disable=tracer-hygiene -- proven concrete"
        ))
        assert fs == []

    def test_bare_suppression_stays_red(self, tmp_path):
        fs = lint_src(tmp_path, self.BAD_IF.format(
            comment="  # reprolint: disable=tracer-hygiene"
        ))
        assert rules_of(fs) == ["bare-suppression"]
        assert "justification" in fs[0].message

    def test_preceding_comment_line_suppresses(self, tmp_path):
        fs = lint_src(tmp_path, """
            import jax

            @jax.jit
            def f(x):
                # reprolint: disable=tracer-hygiene -- concrete by contract
                if x > 0:
                    x = x + 1
                return x
        """)
        assert fs == []

    def test_wrong_rule_does_not_suppress(self, tmp_path):
        fs = lint_src(tmp_path, self.BAD_IF.format(
            comment="  # reprolint: disable=retrace-smell -- wrong rule"
        ))
        assert rules_of(fs) == ["tracer-hygiene"]


# -- driver / CLI -------------------------------------------------------------


class TestDriver:
    def test_parse_error_is_a_finding(self, tmp_path):
        fs = lint_src(tmp_path, "def broken(:\n")
        assert rules_of(fs) == ["parse-error"]

    def test_unknown_rule_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="unknown rules"):
            lint_src(tmp_path, "x = 1\n", rules=["no-such-rule"])

    def test_all_rules_registered(self):
        assert set(ALL_RULES) == {
            "tracer-hygiene", "collective-discipline", "compat-matrix",
            "pallas-kernel", "ledger-completeness", "retrace-smell",
            "span-discipline",
        }

    def test_repo_tree_is_clean(self):
        """The shipped tree lints clean — the CI gate this PR turns on."""
        fs = run_lint([os.path.join(REPO, "src")], repo=REPO)
        assert fs == []

    def test_finding_render_format(self, tmp_path):
        fs = lint_src(tmp_path, "from jax import lax\n\n"
                                "def f(x):\n"
                                "    return lax.psum(x, 'data')\n")
        assert len(fs) == 1
        rendered = fs[0].render()
        assert rendered.startswith(fs[0].path)
        assert ":4:" in rendered and "[collective-discipline]" in rendered


class TestCli:
    def _run(self, *argv):
        return subprocess.run(
            [sys.executable, "-m", "tools.reprolint", *argv],
            capture_output=True, text=True, cwd=REPO, timeout=120,
        )

    def test_clean_tree_exits_zero(self):
        p = self._run("src", "--rules", "collective-discipline")
        assert p.returncode == 0, p.stdout + p.stderr
        assert "0 findings" in p.stdout

    def test_findings_exit_one_and_json_parses(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text(
            "from jax import lax\n\ndef f(x):\n"
            "    return lax.psum(x, 'data')\n"
        )
        p = self._run(str(bad), "--format=json")
        assert p.returncode == 1
        out = json.loads(p.stdout)
        assert out["count"] == 1
        assert out["findings"][0]["rule"] == "collective-discipline"

    def test_no_paths_is_usage_error(self):
        p = self._run()
        assert p.returncode == 2

    def test_list_rules(self):
        p = self._run("--list-rules")
        assert p.returncode == 0
        for rule in ALL_RULES:
            assert rule in p.stdout
