"""Continuous-batching decode plane: paged cache, scheduler, equivalence.

The contracts this file pins down:

* ``PageAllocator`` — all-or-nothing allocation, loud double-free, LIFO
  reuse, page 0 never handed out.
* paged cache ops — write/append/view round-trip exactly; null-page
  redirection keeps inactive slots invisible.
* decode-attention hot path — the Pallas kernel (interpret on CPU,
  single KV block) is BITWISE equal to the jitted XLA reference, through
  ``attn_apply`` and standalone.
* continuous ≡ one-at-a-time ≡ dense-baseline decode (greedy ids — the
  slot scheduler may not change a single served token).
* retrace freedom — the ONE compiled step's jit cache stays at size 1
  under arbitrary join/leave/evict churn (block table and lengths are
  data, not shapes).
* failure semantics — eviction and decode errors fail tickets
  immediately; a poisoned batcher group cannot hang other groups.
* 8-fake-device subprocess acceptance run.
"""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.executor import clear_program_cache, program_cache_stats
from repro.models import cache as cache_lib
from repro.models import transformer as tf
from repro.models.attention import attn_apply, decode_kernel_plan
from repro.models.cache import NULL_PAGE, PageAllocator
from repro.models.config import ModelConfig
from repro.serve import ContinuousLMEngine, DecodeScheduler, EvictedError
from repro.telemetry.report import RunReport
from repro.telemetry.trace import Tracer


def _tiny_cfg(**kw):
    base = dict(
        name="tiny", vocab_size=97, d_model=32, num_layers=2,
        num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
        compute_dtype="float32", param_dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = _tiny_cfg()
    params = tf.init_params(jax.random.key(0), cfg)
    return cfg, params


# ----------------------------------------------------------------------------
# PageAllocator invariants
# ----------------------------------------------------------------------------


class TestPageAllocator:
    def test_never_hands_out_null_page_and_reuses_freed(self):
        a = PageAllocator(8)
        seen = set()
        first = a.alloc(7)
        assert first is not None and NULL_PAGE not in first
        seen.update(first)
        assert a.free_pages == 0
        a.free(first)
        second = a.alloc(7)
        assert set(second) == seen  # full reuse of the same physical pool

    def test_all_or_nothing(self):
        a = PageAllocator(5)  # 4 allocatable
        assert a.alloc(5) is None
        assert a.free_pages == 4  # a refused alloc takes nothing
        got = a.alloc(4)
        assert len(got) == 4
        assert a.alloc(1) is None

    def test_double_free_and_foreign_free_raise(self):
        a = PageAllocator(4)
        pages = a.alloc(2)
        a.free(pages)
        with pytest.raises(ValueError, match="double free|not allocated"):
            a.free(pages)
        with pytest.raises(ValueError, match="not allocated"):
            a.free([NULL_PAGE])

    def test_lifo_reuse(self):
        a = PageAllocator(8)
        x = a.alloc(3)
        a.free(x)
        y = a.alloc(3)
        assert y == list(reversed(x))  # most recently freed comes back first

    def test_negative_and_tiny_arena_rejected(self):
        with pytest.raises(ValueError):
            PageAllocator(1)
        a = PageAllocator(4)
        with pytest.raises(ValueError):
            a.alloc(-1)


# ----------------------------------------------------------------------------
# Paged cache ops
# ----------------------------------------------------------------------------


class TestPagedCacheOps:
    def test_write_view_append_roundtrip(self):
        P, Hkv, D = 4, 2, 3
        cache = cache_lib.paged_kv_cache_init(7, P, Hkv, D, jnp.float32)
        block = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
        rng = np.random.default_rng(0)
        k_seq = jnp.asarray(rng.normal(size=(8, Hkv, D)), jnp.float32)
        v_seq = jnp.asarray(rng.normal(size=(8, Hkv, D)), jnp.float32)
        # write 5 valid rows (3 rows of bucket padding) into slot 0
        cache = cache_lib.paged_write(cache, block[0], k_seq, v_seq, 5)
        k, v = cache_lib.paged_view(cache, block)
        np.testing.assert_array_equal(k[0, :5], k_seq[:5])
        np.testing.assert_array_equal(v[0, :5], v_seq[:5])
        # slot 1 untouched
        np.testing.assert_array_equal(k[1], np.zeros((12, Hkv, D)))

        # append one token per slot at its fill position
        k_tok = jnp.asarray(rng.normal(size=(2, Hkv, D)), jnp.float32)
        v_tok = jnp.asarray(rng.normal(size=(2, Hkv, D)), jnp.float32)
        cache = cache_lib.paged_append(
            cache, block, jnp.asarray([5, 0], jnp.int32), k_tok, v_tok
        )
        k, v = cache_lib.paged_view(cache, block)
        np.testing.assert_array_equal(k[0, 5], k_tok[0])
        np.testing.assert_array_equal(k[1, 0], k_tok[1])
        np.testing.assert_array_equal(k[0, :5], k_seq[:5])  # intact

    def test_null_page_swallows_inactive_writes(self):
        P, Hkv, D = 2, 1, 2
        cache = cache_lib.paged_kv_cache_init(4, P, Hkv, D, jnp.float32)
        live = jnp.asarray([[1, 2]], jnp.int32)
        dead = jnp.full((1, 2), NULL_PAGE, jnp.int32)
        tok = jnp.ones((1, Hkv, D), jnp.float32)
        cache = cache_lib.paged_append(
            cache, dead, jnp.zeros((1,), jnp.int32), tok, tok
        )
        k, _ = cache_lib.paged_view(cache, live)
        np.testing.assert_array_equal(k, np.zeros_like(np.asarray(k)))

    def test_padding_rows_redirect_to_null_page(self):
        P, Hkv, D = 2, 1, 2
        cache = cache_lib.paged_kv_cache_init(4, P, Hkv, D, jnp.float32)
        block_row = jnp.asarray([1, 2], jnp.int32)
        seq = jnp.ones((4, Hkv, D), jnp.float32) * 7.0
        cache = cache_lib.paged_write(cache, block_row, seq, seq, 2)
        k, _ = cache_lib.paged_view(cache, block_row[None])
        np.testing.assert_array_equal(np.asarray(k[0, :2]), seq[:2])
        # rows >= n_valid landed in page 0, not pages 1/2
        np.testing.assert_array_equal(
            np.asarray(k[0, 2:]), np.zeros((2, Hkv, D))
        )


# ----------------------------------------------------------------------------
# Decode-attention hot path: kernel bit-equality
# ----------------------------------------------------------------------------


class TestDecodeKernelBitExact:
    def test_pallas_vs_xla_reference_bitwise(self):
        from repro.kernels.decode_attention import ops as da_ops

        rng = np.random.default_rng(1)
        for B, S, Hq, Hkv, D, vl in [
            (3, 64, 8, 2, 32, 17), (2, 32, 4, 4, 16, 32), (1, 16, 4, 1, 8, 1)
        ]:
            q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
            k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
            v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
            valid = jnp.full((B,), vl, jnp.int32)
            got = da_ops.decode_attention(q, k, v, valid, bk=512)
            ref = da_ops.decode_attention_xla(q, k, v, valid)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_attn_apply_pallas_equals_xla(self, tiny_lm):
        cfg, params = tiny_lm
        p = params["seg0"]
        p0 = jax.tree.map(lambda x: x[0], p)["l0"]["mixer"]
        rng = np.random.default_rng(2)
        B, S = 2, 16
        x = jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)), jnp.float32)
        cache = cache_lib.kv_cache_init(
            B, S, cfg.num_kv_heads, cfg.head_dim, jnp.float32
        )
        cache = cache._replace(index=jnp.asarray(5, jnp.int32))
        pos = jnp.full((B, 1), 5, jnp.int32)
        outs = {}
        for impl in ("pallas", "xla"):
            y, nc = attn_apply(
                p0, cfg, x, positions=pos, cache=cache, decode_attn=impl
            )
            outs[impl] = np.asarray(y)
        np.testing.assert_array_equal(outs["pallas"], outs["xla"])

    def test_kernel_plan_reports_fallback(self):
        plan = decode_kernel_plan(_tiny_cfg(), use_kernel="auto")
        assert plan["path"] in ("pallas", "xla")
        if jax.default_backend() != "tpu":
            assert plan["path"] == "xla"
            assert "bit-equal" in plan["reason"]
        forced = decode_kernel_plan(_tiny_cfg(), use_kernel=True)
        assert forced["path"] == "pallas"
        sw = decode_kernel_plan(_tiny_cfg(sliding_window=8))
        assert sw["path"] == "off"


# ----------------------------------------------------------------------------
# Scheduler
# ----------------------------------------------------------------------------


class TestDecodeScheduler:
    def test_admit_release_cycle(self):
        s = DecodeScheduler(n_slots=2, n_pages=9, page_size=4, max_seq=16)
        from repro.serve.continuous import _Request

        def req(rid, plen, gen):
            return _Request(
                rid=rid, prompt=np.zeros(plen, np.int32), max_new=gen,
                ticket=None, t_submit=0.0, seed=0,
            )

        r1, r2, r3 = req(1, 8, 8), req(2, 4, 4), req(3, 4, 4)
        assert s.admit(r1) is not None  # 4 pages
        assert s.admit(r2) is not None  # 2 pages
        assert s.n_active == 2
        assert s.admit(r3) is None  # no free slot
        s.release(r1.slot)
        assert (s.block[0] == NULL_PAGE).all() and s.length[0] == 0
        assert s.admit(r3) is not None
        assert s.alloc.used_pages == 4

    def test_oversubscribed_arena_queues_by_pages(self):
        # 2 slots but pages for only one 16-token request at a time
        s = DecodeScheduler(n_slots=2, n_pages=5, page_size=4, max_seq=16)
        from repro.serve.continuous import _Request

        a = _Request(rid=1, prompt=np.zeros(8, np.int32), max_new=8,
                     ticket=None, t_submit=0.0, seed=0)
        b = _Request(rid=2, prompt=np.zeros(8, np.int32), max_new=8,
                     ticket=None, t_submit=0.0, seed=0)
        assert s.admit(a) is not None
        assert s.admit(b) is None  # free slot exists, pages don't
        s.release(a.slot)
        assert s.admit(b) is not None

    def test_never_servable_rejected_at_submit(self, tiny_lm):
        cfg, params = tiny_lm
        eng = ContinuousLMEngine(cfg, params, n_slots=2, page_size=4,
                                 max_seq=16)
        with pytest.raises(ValueError, match="max_seq"):
            eng.submit(np.zeros(12, np.int32), max_new=8)


# ----------------------------------------------------------------------------
# Equivalence: continuous ≡ one-at-a-time ≡ dense baseline
# ----------------------------------------------------------------------------


class TestContinuousEquivalence:
    PROMPTS = [(3, 6), (5, 3), (1, 5), (7, 2), (2, 4), (4, 6)]

    def _requests(self, cfg):
        rng = np.random.default_rng(0)
        return [
            (rng.integers(0, cfg.vocab_size, size=l).astype(np.int32), g)
            for l, g in self.PROMPTS
        ]

    def test_continuous_equals_one_at_a_time(self, tiny_lm):
        cfg, params = tiny_lm
        reqs = self._requests(cfg)
        eng = ContinuousLMEngine(cfg, params, n_slots=3, page_size=4,
                                 max_seq=24)
        tickets = [eng.submit(p, max_new=g) for p, g in reqs]
        eng.run_until_idle()
        batched = [t.result().tolist() for t in tickets]

        solo = []
        for p, g in reqs:
            e1 = ContinuousLMEngine(cfg, params, n_slots=1, page_size=4,
                                    max_seq=24)
            solo.append(e1.submit(p, max_new=g).result().tolist())
        assert batched == solo

    def test_continuous_equals_dense_baseline(self, tiny_lm):
        from repro.launch.serve import prefill_and_decode

        cfg, params = tiny_lm
        reqs = self._requests(cfg)
        eng = ContinuousLMEngine(cfg, params, n_slots=3, page_size=4,
                                 max_seq=24)
        tickets = [eng.submit(p, max_new=g) for p, g in reqs]
        eng.run_until_idle()
        for (p, g), t in zip(reqs, tickets):
            dense = prefill_and_decode(
                cfg, params, jnp.asarray(p)[None], gen=g,
                cache_len=len(p) + g + 1,
            )
            assert t.result().tolist() == np.asarray(dense)[0].tolist()

    def test_forced_pallas_kernel_on_hot_path(self, tiny_lm):
        """use_kernel=True routes the compiled step through the Pallas
        kernel (interpret on CPU) and counts hits — and the served ids
        are identical to the XLA-reference path (bit-equal contract)."""
        cfg, params = tiny_lm
        reqs = self._requests(cfg)[:3]
        outs = {}
        for use_kernel in (True, False):
            eng = ContinuousLMEngine(
                cfg, params, n_slots=2, page_size=4, max_seq=24,
                use_kernel=use_kernel,
            )
            tickets = [eng.submit(p, max_new=g) for p, g in reqs]
            eng.run_until_idle()
            outs[use_kernel] = [t.result().tolist() for t in tickets]
            impl = "pallas" if use_kernel else "xla"
            assert eng.kernel_plan["path"] == impl
            assert eng.kernel_hits[impl] > 0
            other = "xla" if use_kernel else "pallas"
            assert eng.kernel_hits[other] == 0
        assert outs[True] == outs[False]

    def test_temperature_sampling_is_occupancy_invariant(self, tiny_lm):
        cfg, params = tiny_lm
        rng = np.random.default_rng(3)
        p0 = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
        others = [
            rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
            for l in (2, 6)
        ]
        alone = ContinuousLMEngine(cfg, params, n_slots=3, page_size=4,
                                   max_seq=16, temperature=0.7, seed=11)
        a = alone.submit(p0, max_new=5).result().tolist()
        crowd = ContinuousLMEngine(cfg, params, n_slots=3, page_size=4,
                                   max_seq=16, temperature=0.7, seed=11)
        tickets = [crowd.submit(p0, max_new=5)]
        tickets += [crowd.submit(p, max_new=4) for p in others]
        crowd.run_until_idle()
        assert tickets[0].result().tolist() == a

    def test_under_provisioned_arena_still_serves_everything(self, tiny_lm):
        cfg, params = tiny_lm
        reqs = self._requests(cfg)
        # pages for ~1.5 requests at a time; 3 slots fight over them
        eng = ContinuousLMEngine(cfg, params, n_slots=3, page_size=4,
                                 max_seq=24, n_pages=8)
        full = ContinuousLMEngine(cfg, params, n_slots=3, page_size=4,
                                  max_seq=24)
        t1 = [eng.submit(p, max_new=g) for p, g in reqs]
        t2 = [full.submit(p, max_new=g) for p, g in reqs]
        eng.run_until_idle()
        full.run_until_idle()
        assert [t.result().tolist() for t in t1] == \
               [t.result().tolist() for t in t2]
        assert eng.sched.alloc.used_pages == 0  # everything returned


# ----------------------------------------------------------------------------
# Retrace freedom
# ----------------------------------------------------------------------------


class TestRetraceFreedom:
    def test_compiled_step_never_retraces_under_churn(self, tiny_lm):
        cfg, params = tiny_lm
        clear_program_cache()
        eng = ContinuousLMEngine(cfg, params, n_slots=2, page_size=4,
                                 max_seq=16)
        rng = np.random.default_rng(4)
        tickets = []
        # churn: staggered joins/leaves of mixed lengths + one eviction
        for i, (l, g) in enumerate([(3, 4), (1, 2), (5, 3), (2, 5), (4, 1)]):
            p = rng.integers(0, cfg.vocab_size, size=l).astype(np.int32)
            tickets.append(eng.submit(p, max_new=g))
            eng.step()
            assert eng.compiled_step_cache_size == 1, f"retrace at join {i}"
        eng.evict(tickets[-1])
        eng.run_until_idle()
        assert eng.compiled_step_cache_size == 1
        assert program_cache_stats()["misses"] >= 1  # step program is cached

    def test_program_cache_shares_step_across_engines(self, tiny_lm):
        cfg, params = tiny_lm
        clear_program_cache()
        ContinuousLMEngine(cfg, params, n_slots=2, page_size=4, max_seq=16)
        before = program_cache_stats()
        ContinuousLMEngine(cfg, params, n_slots=2, page_size=4, max_seq=16)
        after = program_cache_stats()
        assert after["hits"] >= before["hits"] + 3  # step+prefill+insert warm
        assert after["misses"] == before["misses"]


# ----------------------------------------------------------------------------
# Failure semantics
# ----------------------------------------------------------------------------


class TestFailureSemantics:
    def test_eviction_fails_ticket_immediately(self, tiny_lm):
        cfg, params = tiny_lm
        eng = ContinuousLMEngine(cfg, params, n_slots=2, page_size=4,
                                 max_seq=16)
        keep = eng.submit(np.asarray([1, 2, 3], np.int32), max_new=4)
        drop = eng.submit(np.asarray([4, 5], np.int32), max_new=4)
        eng.step()  # both in flight
        eng.evict(drop, reason="test reclaim")
        with pytest.raises(EvictedError, match="test reclaim"):
            drop.result(timeout=0.1)  # fails NOW, not at timeout
        assert len(keep.result()) == 4  # survivor unaffected
        assert eng.stats()["evictions"] == 1

    def test_queued_request_eviction(self, tiny_lm):
        cfg, params = tiny_lm
        eng = ContinuousLMEngine(cfg, params, n_slots=1, page_size=4,
                                 max_seq=16)
        first = eng.submit(np.asarray([1, 2], np.int32), max_new=3)
        queued = eng.submit(np.asarray([3], np.int32), max_new=3)
        eng.step()  # first holds the only slot; second is backlogged
        eng.evict(queued)
        with pytest.raises(EvictedError):
            queued.result(timeout=0.1)
        assert len(first.result()) == 3

    def test_decode_error_fails_all_inflight_tickets(self, tiny_lm):
        cfg, params = tiny_lm
        eng = ContinuousLMEngine(cfg, params, n_slots=2, page_size=4,
                                 max_seq=16)
        t1 = eng.submit(np.asarray([1, 2], np.int32), max_new=4)
        t2 = eng.submit(np.asarray([3], np.int32), max_new=4)
        eng.step()
        boom = RuntimeError("device fell over")
        eng._step = lambda *a, **k: (_ for _ in ()).throw(boom)
        with pytest.raises(RuntimeError, match="device fell over"):
            eng.step()
        for t in (t1, t2):
            with pytest.raises(RuntimeError, match="device fell over"):
                t.result(timeout=0.1)
        assert eng.sched.n_active == 0
        assert eng.sched.alloc.used_pages == 0  # pages reclaimed

    def test_batcher_poll_isolates_poisoned_group(self):
        from repro.serve import MicroBatcher

        calls = {"n": 0}

        def predict(X):
            if X.shape[1] == 2:  # the poisoned shape group
                raise ValueError("bad group")
            return X * 2

        b = MicroBatcher(predict, max_batch=4, timeout_s=0.0)
        bad = b.submit(np.ones(2, np.float32))
        good = b.submit(np.ones(3, np.float32))
        served = b.poll()  # must not raise, must serve the good group
        assert served >= 1
        np.testing.assert_array_equal(
            good.result(timeout=1), 2 * np.ones(3, np.float32)
        )
        with pytest.raises(ValueError, match="bad group"):
            bad.result(timeout=0.1)


# ----------------------------------------------------------------------------
# Metrics / report
# ----------------------------------------------------------------------------


class TestContinuousObservability:
    def test_metrics_and_report(self, tiny_lm):
        cfg, params = tiny_lm
        tr = Tracer()
        eng = ContinuousLMEngine(cfg, params, n_slots=2, page_size=4,
                                 max_seq=16, tracer=tr)
        rng = np.random.default_rng(5)
        tickets = [
            eng.submit(rng.integers(0, cfg.vocab_size, size=3).astype(np.int32),
                       max_new=g)
            for g in (4, 2, 3)
        ]
        eng.run_until_idle()
        for t in tickets:
            t.result()
        s = eng.stats()
        assert s["requests"] == 3
        # the first token of each request comes from prefill logits;
        # ``tokens`` counts what the compiled decode step produced
        assert s["tokens"] == (4 - 1) + (2 - 1) + (3 - 1)
        assert s["tokens_per_s"] > 0
        assert 0 < s["slot_utilization"] <= 1
        assert s["decode_steps"] > 0
        assert s["p50_token_ms"] >= 0
        assert s["request_bytes"] == 3 * 3 * 4  # 3 prompts × 3 int32
        assert s["response_bytes"] == (4 + 2 + 3) * 4

        spans = tr.summary()
        assert "serve/decode_step" in spans and "serve/prefill" in spans
        assert tr.counters["serve/joins"] == 3
        assert tr.counters["serve/decode_tokens"] == s["tokens"]
        assert 0 < tr.gauges["serve/slot_occupancy"] <= 1

        md = RunReport.from_serve(eng).to_markdown()
        assert "decode kernel hits" in md
        assert "tok/s" in md and "slot util" in md
        rep = RunReport.from_serve(eng).as_dict()
        assert rep["decode_kernel_hits"]["xla"] + \
               rep["decode_kernel_hits"]["pallas"] == s["tokens"]

    def test_ledger_coalesces_inference_events(self, tiny_lm):
        cfg, params = tiny_lm
        eng = ContinuousLMEngine(cfg, params, n_slots=2, page_size=4,
                                 max_seq=16, tag="serve/t")
        for _ in range(3):
            eng.submit(np.asarray([1, 2], np.int32), max_new=2).result()
        events = [e for e in eng.ledger.events if e[0] == "inference"]
        assert len(events) == 1  # one running event per tag, not per request
        assert events[0][1] == "serve/t"


# ----------------------------------------------------------------------------
# 8-fake-device acceptance
# ----------------------------------------------------------------------------


class TestContinuousEightDevices:
    """Continuous engine under 8 fake CPU devices: serves a mixed-length
    trace, never retraces, and matches the dense baseline (device count
    is fixed at jax init, so this runs in a subprocess)."""

    SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
import numpy as np
import jax.numpy as jnp
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.serve import ContinuousLMEngine
from repro.launch.serve import prefill_and_decode

cfg = ModelConfig(name="tiny", vocab_size=97, d_model=32, num_layers=2,
                  num_heads=4, num_kv_heads=2, head_dim=8, d_ff=64,
                  compute_dtype="float32", param_dtype="float32")
params = tf.init_params(jax.random.key(0), cfg)
rng = np.random.default_rng(0)
reqs = [(rng.integers(0, 97, size=l).astype(np.int32), g)
        for l, g in [(3, 5), (6, 2), (1, 4), (4, 3), (2, 6)]]
eng = ContinuousLMEngine(cfg, params, n_slots=4, page_size=4, max_seq=16)
tickets = [eng.submit(p, max_new=g) for p, g in reqs]
eng.run_until_idle()
match = all(
    t.result().tolist() == np.asarray(prefill_and_decode(
        cfg, params, jnp.asarray(p)[None], gen=g, cache_len=len(p) + g + 1
    ))[0].tolist()
    for (p, g), t in zip(reqs, tickets)
)
s = eng.stats()
print(json.dumps({
    "num_devices": jax.device_count(),
    "matches_dense": bool(match),
    "step_cache": eng.compiled_step_cache_size,
    "tokens": s["tokens"],
    "kernel_hits": eng.kernel_hits,
}))
"""

    def test_continuous_serve_on_8_devices(self, fake_devices):
        out = fake_devices(self.SCRIPT)
        assert out["num_devices"] == 8
        assert out["matches_dense"], out
        assert out["step_cache"] == 1
        # decode-step tokens: one per request comes from prefill instead
        assert out["tokens"] == (5 + 2 + 4 + 3 + 6) - 5
        assert sum(out["kernel_hits"].values()) == out["tokens"]
