"""Decode-attention Pallas kernel vs oracle."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.decode_attention import ops, ref

CASES = [
    # (B, S, Hq, Hkv, D, valid)
    (2, 256, 8, 2, 32, 100),
    (1, 512, 4, 4, 64, 512),
    (3, 128, 4, 1, 16, 1),
    (2, 300, 8, 4, 32, 257),  # S not a multiple of bk → padding
]


@pytest.mark.parametrize("case", CASES)
def test_decode_matches_ref(case):
    B, S, Hq, Hkv, D, vl = case
    ks = jax.random.split(jax.random.key(sum(case)), 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = ops.decode_attention(q, k, v, jnp.asarray(vl), bk=64)
    exp = ref.decode_attention_ref(q, k, v, vl)
    assert float(jnp.max(jnp.abs(out - exp))) < 2e-5


def test_per_batch_valid_lengths():
    B, S, Hq, Hkv, D = 3, 128, 4, 2, 32
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    vl = jnp.asarray([5, 64, 128])
    out = ops.decode_attention(q, k, v, vl, bk=32)
    exp = ref.decode_attention_ref(q, k, v, vl)
    assert float(jnp.max(jnp.abs(out - exp))) < 2e-5


@pytest.mark.parametrize("case", CASES)
def test_xla_reference_bitexact_single_block(case):
    """``decode_attention_xla`` (the ``use_kernel`` fallback) mirrors the
    kernel's single-pass math, not softmax@v: on one KV block (bk ≥ S)
    the two are BITWISE equal, so flipping the knob never changes a
    served token."""
    B, S, Hq, Hkv, D, vl = case
    ks = jax.random.split(jax.random.key(sum(case)), 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = ops.decode_attention(q, k, v, jnp.asarray(vl), bk=1024)
    exp = ops.decode_attention_xla(q, k, v, jnp.asarray(vl))
    assert jnp.array_equal(out, exp)


def test_xla_reference_close_to_oracle():
    B, S, Hq, Hkv, D = 2, 192, 8, 2, 32
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    vl = jnp.asarray([100, 192])
    out = ops.decode_attention_xla(q, k, v, vl)
    exp = ref.decode_attention_ref(q, k, v, vl)
    assert float(jnp.max(jnp.abs(out - exp))) < 2e-5


def test_bf16_cache():
    B, S, Hq, Hkv, D = 2, 256, 8, 2, 32
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, Hq, D)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hkv, D)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, Hkv, D)).astype(jnp.bfloat16)
    out = ops.decode_attention(q, k, v, jnp.asarray(200), bk=64)
    exp = ref.decode_attention_ref(q, k, v, 200)
    assert (
        float(jnp.max(jnp.abs(out.astype(jnp.float32) - exp.astype(jnp.float32))))
        < 3e-2
    )
