"""Bounded-staleness delay line (the §5 algorithm on TPU, DESIGN.md §2)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.staleness import (
    delay_init,
    delay_push_pop,
    make_stale_update,
    staleness_bound_lr,
)
from repro.optim import sgd


def test_delay_line_fifo_order():
    params = jnp.zeros(3)
    d = delay_init(params, 2)
    d, g = delay_push_pop(d, jnp.full(3, 1.0))
    np.testing.assert_array_equal(g, jnp.zeros(3))  # warm-up
    d, g = delay_push_pop(d, jnp.full(3, 2.0))
    np.testing.assert_array_equal(g, jnp.zeros(3))
    d, g = delay_push_pop(d, jnp.full(3, 3.0))
    np.testing.assert_array_equal(g, jnp.full(3, 1.0))  # D=2 behind
    d, g = delay_push_pop(d, jnp.full(3, 4.0))
    np.testing.assert_array_equal(g, jnp.full(3, 2.0))


def test_depth_zero_rejected():
    import pytest

    with pytest.raises(ValueError):
        delay_init(jnp.zeros(2), 0)


def _quadratic_grads(theta, A, b):
    return A @ theta - b


def test_staleness_zero_is_synchronous():
    A = jnp.eye(4) * 2.0
    b = jnp.ones(4)
    opt = sgd(0.1)

    def opt_update(grads, state, params):
        upd, state = opt.update(grads, state, params)
        return jax.tree.map(jnp.add, params, upd), state

    init, update = make_stale_update(opt_update, staleness=0)
    st = init(jnp.zeros(4), opt.init(jnp.zeros(4)))
    theta_ref = jnp.zeros(4)
    for _ in range(20):
        g = _quadratic_grads(st.params, A, b)
        st = update(st, g)
        theta_ref = theta_ref - 0.1 * _quadratic_grads(theta_ref, A, b)
    np.testing.assert_allclose(st.params, theta_ref, rtol=1e-6)


def test_stale_gradients_still_converge():
    A = jnp.eye(4) * 2.0
    b = jnp.ones(4)
    opt = sgd(staleness_bound_lr(0.2, 3))

    def opt_update(grads, state, params):
        upd, state = opt.update(grads, state, params)
        return jax.tree.map(jnp.add, params, upd), state

    init, update = make_stale_update(opt_update, staleness=3)
    st = init(jnp.zeros(4), opt.init(jnp.zeros(4)))
    for _ in range(300):
        g = _quadratic_grads(st.params, A, b)
        st = update(st, g)
    np.testing.assert_allclose(st.params, jnp.linalg.solve(A, b), atol=1e-3)


def test_staleness_bound_lr():
    assert staleness_bound_lr(1.0, 0) == 1.0
    assert staleness_bound_lr(1.0, 4) == 0.2
