"""Optimizers."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam, adagrad, clip_by_global_norm, momentum, sgd, warmup_cosine
from repro.optim.optimizers import apply_updates


def _quad(theta):
    return 0.5 * jnp.sum(theta ** 2)


def _run(opt, steps=200, n=4):
    params = jnp.full((n,), 5.0)
    state = opt.init(params)
    for _ in range(steps):
        g = jax.grad(_quad)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    return params


def test_all_optimizers_minimize_quadratic():
    for name, opt in [
        ("sgd", sgd(0.1)),
        ("momentum", momentum(0.05)),
        ("adam", adam(0.1)),
        ("adagrad", adagrad(1.0)),
    ]:
        final = _run(opt)
        assert float(jnp.max(jnp.abs(final))) < 0.1, name


def test_adam_first_step_formula():
    opt = adam(0.1, b1=0.9, b2=0.999, eps=1e-8)
    p = {"w": jnp.asarray([1.0])}
    st = opt.init(p)
    g = {"w": jnp.asarray([0.5])}
    upd, st = opt.update(g, st, p)
    # bias-corrected first step = -lr * g/|g| = -lr (up to eps)
    np.testing.assert_allclose(upd["w"], [-0.1], rtol=1e-4)


def test_clip_caps_global_norm():
    opt = clip_by_global_norm(sgd(1.0), 1.0)
    p = jnp.zeros(4)
    st = opt.init(p)
    g = jnp.full((4,), 100.0)
    upd, _ = opt.update(g, st, p)
    np.testing.assert_allclose(jnp.linalg.norm(upd), 1.0, rtol=1e-5)


def test_bf16_moments():
    opt = adam(0.1, moment_dtype="bfloat16")
    p = {"w": jnp.ones((8,))}
    st = opt.init(p)
    assert st["m"]["w"].dtype == jnp.bfloat16
    upd, st = opt.update({"w": jnp.ones((8,))}, st, p)
    assert jnp.all(jnp.isfinite(upd["w"]))


def test_warmup_cosine_schedule():
    sched = warmup_cosine(1.0, warmup=10, total=100, floor=0.1)
    assert float(sched(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(sched(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(sched(jnp.asarray(100))) < 0.15
    assert float(sched(jnp.asarray(100))) >= 0.099


def test_weight_decay():
    opt = adam(0.1, weight_decay=0.1)
    p = {"w": jnp.asarray([10.0])}
    st = opt.init(p)
    upd, _ = opt.update({"w": jnp.asarray([0.0])}, st, p)
    assert float(upd["w"][0]) < 0  # decays toward zero even with zero grad
