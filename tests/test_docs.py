"""Docs stay executable: the fenced python blocks in the user-facing
markdown run end-to-end (on 8 fake CPU devices, in a subprocess per
file) and every intra-repo reference resolves — the checks behind the
``docs-check`` CI job (``tools/check_docs.py``)."""

import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_docs  # noqa: E402


@pytest.mark.parametrize("md", check_docs.link_files())
def test_intra_repo_references_resolve(md):
    if not os.path.exists(os.path.join(REPO, md)):
        pytest.skip(f"{md} not present")
    assert check_docs.check_links(md) == []


def test_link_files_discovers_root_and_docs():
    found = check_docs.link_files()
    assert "README.md" in found
    assert any(f.startswith("docs" + os.sep) for f in found)


def test_check_links_reports_every_broken_ref(tmp_path):
    """Unit test on a fixture tree: one run reports ALL broken refs with
    line numbers, and resolving either doc-relative or repo-root-relative
    counts as good."""
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "REAL.md").write_text("# real\n")
    (tmp_path / "tools").mkdir()
    (tmp_path / "tools" / "real.py").write_text("")
    (tmp_path / "docs" / "GUIDE.md").write_text(
        "see [real](REAL.md) and [also real](../tools/real.py)\n"
        "and `tools/real.py` (root-relative)\n"
        "but [gone](MISSING.md) is broken\n"
        "and so is `tools/nope.py` plus [dead](../dead.md)\n"
        "[external](https://example.com/x.md) is ignored\n"
    )
    errors = check_docs.check_links(
        os.path.join("docs", "GUIDE.md"), repo=str(tmp_path)
    )
    assert errors == [
        f"docs{os.sep}GUIDE.md:3: broken intra-repo reference 'MISSING.md'",
        f"docs{os.sep}GUIDE.md:4: broken intra-repo reference '../dead.md'",
        f"docs{os.sep}GUIDE.md:4: broken intra-repo reference "
        "'tools/nope.py'",
    ]


def test_check_links_isolates_unreadable_files(tmp_path):
    errors = check_docs.check_links("docs/ABSENT.md", repo=str(tmp_path))
    assert len(errors) == 1 and "unreadable" in errors[0]


def test_extract_blocks_and_skip_marker():
    text = (
        "intro\n```python\nx = 1\n```\n"
        "<!-- docs-check: skip -->\n```python\nraise SystemExit\n```\n"
    )
    blocks = check_docs.extract_blocks(text)
    assert [(src, skip) for _, src, skip in blocks] == [
        ("x = 1", False), ("raise SystemExit", True)
    ]


@pytest.mark.parametrize("md", check_docs.SNIPPET_FILES)
def test_doc_snippets_execute(md):
    if not os.path.exists(os.path.join(REPO, md)):
        pytest.skip(f"{md} not present")
    assert check_docs.run_snippets(md) == []
