"""Docs stay executable: the fenced python blocks in the user-facing
markdown run end-to-end (on 8 fake CPU devices, in a subprocess per
file) and every intra-repo reference resolves — the checks behind the
``docs-check`` CI job (``tools/check_docs.py``)."""

import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_docs  # noqa: E402


@pytest.mark.parametrize("md", check_docs.LINK_FILES)
def test_intra_repo_references_resolve(md):
    if not os.path.exists(os.path.join(REPO, md)):
        pytest.skip(f"{md} not present")
    assert check_docs.check_links(md) == []


def test_extract_blocks_and_skip_marker():
    text = (
        "intro\n```python\nx = 1\n```\n"
        "<!-- docs-check: skip -->\n```python\nraise SystemExit\n```\n"
    )
    blocks = check_docs.extract_blocks(text)
    assert [(src, skip) for _, src, skip in blocks] == [
        ("x = 1", False), ("raise SystemExit", True)
    ]


@pytest.mark.parametrize("md", check_docs.SNIPPET_FILES)
def test_doc_snippets_execute(md):
    if not os.path.exists(os.path.join(REPO, md)):
        pytest.skip(f"{md} not present")
    assert check_docs.run_snippets(md) == []
