"""Shared fixtures: RNG, the 8-fake-device subprocess launcher (one
implementation instead of the copy in every executor-family test file),
and parameterized fault plans for the client-fleet suite."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_on_fake_devices(script, *, devices=8, timeout=600):
    """Run ``script`` in a fresh interpreter with ``devices`` fake CPU
    devices and return its LAST stdout line parsed as JSON.

    Mesh/multipod placements need more than one XLA device, which a
    normal CPU test process doesn't have — and the device-count flag
    must be set before jax initializes, hence the subprocess.  The
    script's contract: print exactly one JSON object as its final line.
    """
    from repro import api

    # repro may be a namespace package (no __file__) — anchor on api
    src = os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(api.__file__)))
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
    )
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.fixture(scope="session")
def fake_devices():
    """The shared launcher as a fixture (tests/test_executors,
    test_serve, test_trace, …)."""
    return run_on_fake_devices


# the fault-plan grid every parametrized fleet test runs over: pure
# dropout, pure stragglers, a quorum gate, and the combined plan
FAULT_PLAN_SPECS = [
    pytest.param({"dropout_p": 0.3}, id="dropout"),
    pytest.param({"straggler": 2}, id="straggler"),
    pytest.param({"dropout_p": 0.4, "quorum": 2}, id="quorum"),
    pytest.param(
        {"dropout_p": 0.3, "straggler": 1, "quorum": 2}, id="combined"
    ),
]


@pytest.fixture(params=FAULT_PLAN_SPECS)
def fault_plan(request):
    """A fresh seeded FaultPlan per parametrization (seed fixed so every
    consumer of the fixture sees the same schedule)."""
    from repro.api.faults import FaultPlan

    return FaultPlan(seed=11, **request.param)
