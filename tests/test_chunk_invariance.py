"""Chunking must never change results: the chunked/scanned compute paths
(mamba chunked scan, chunkwise mLSTM, q-chunked attention, chunked CE) are
pure refactorings of their monolithic forms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment"
)
from hypothesis import given, settings, strategies as st

from repro.models import mamba, xlstm
from repro.models.cache import MLSTMCache
from repro.models.config import ModelConfig, SSMConfig, XLSTMConfig

SETTINGS = dict(max_examples=8, deadline=None)


@settings(**SETTINGS)
@given(T=st.integers(5, 40), chunk=st.sampled_from([4, 8, 16, 64]), seed=st.integers(0, 20))
def test_mlstm_chunk_invariance(T, chunk, seed):
    B, H, Dh = 2, 2, 8
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, T, H, Dh))
    k = jax.random.normal(ks[1], (B, T, H, Dh))
    v = jax.random.normal(ks[2], (B, T, H, Dh))
    i_pre = jax.random.normal(ks[3], (B, T, H))
    f_pre = jax.random.normal(ks[4], (B, T, H)) + 1.0
    ref = xlstm._mlstm_parallel(q, k, v, i_pre, f_pre, chunk=max(T, 64))
    out = xlstm._mlstm_parallel(q, k, v, i_pre, f_pre, chunk=chunk)
    np.testing.assert_allclose(out, ref, atol=2e-4)


@settings(**SETTINGS)
@given(T=st.integers(4, 48), chunk=st.sampled_from([4, 16, 256]), seed=st.integers(0, 20))
def test_mamba_chunk_invariance(T, chunk, seed):
    cfg = ModelConfig(
        num_layers=1, d_model=16, num_heads=2, num_kv_heads=2, d_ff=32,
        vocab_size=32, head_dim=8, ssm=SSMConfig(d_state=4, d_conv=3),
        hybrid_pattern=("mamba",), compute_dtype="float32",
    )
    p = mamba.mamba_init(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 100), (2, T, 16))
    ref, _ = mamba.mamba_apply(p, cfg, x, chunk=max(T, 256))
    out, _ = mamba.mamba_apply(p, cfg, x, chunk=chunk)
    np.testing.assert_allclose(out, ref, atol=2e-4)


def test_mlstm_recurrent_equals_chunked():
    """The decode recurrence is the T=1 limit of the chunkwise form."""
    B, T, H, Dh = 1, 10, 2, 8
    ks = jax.random.split(jax.random.key(3), 5)
    q, k, v = (jax.random.normal(ks[i], (B, T, H, Dh)) for i in range(3))
    i_pre = jax.random.normal(ks[3], (B, T, H))
    f_pre = jax.random.normal(ks[4], (B, T, H))
    par = xlstm._mlstm_parallel(q, k, v, i_pre, f_pre, chunk=5)
    st_ = MLSTMCache(
        C=jnp.zeros((B, H, Dh, Dh)), n=jnp.zeros((B, H, Dh)),
        m=jnp.full((B, H), -1e30),
    )
    outs = []
    for t in range(T):
        st_, h = xlstm._mlstm_step(
            st_, q[:, t], k[:, t], v[:, t], i_pre[:, t], f_pre[:, t]
        )
        outs.append(h)
    np.testing.assert_allclose(jnp.stack(outs, 1), par, atol=2e-4)


def test_chunked_ce_matches_dense():
    from repro.models import transformer as tf
    from repro.models.layers import cross_entropy

    cfg = ModelConfig(
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
        vocab_size=64, head_dim=16, tie_embeddings=True,
        compute_dtype="float32",
    )
    params = tf.init_params(jax.random.key(0), cfg)
    B, T = 2, 24
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, 64)
    labels = jnp.roll(toks, -1, 1)
    logits, _, _, hidden = tf.forward(params, cfg, toks, return_hidden=True)
    dense_ce = cross_entropy(logits, labels)
    for chunk in (6, 12, 24, 512):
        cc = tf.chunked_ce(params, cfg, hidden, labels, chunk=chunk)
        np.testing.assert_allclose(float(cc), float(dense_ce), rtol=1e-5)


def test_qchunk_grad_matches():
    """Gradients (not just outputs) must agree through the chunked path."""
    from repro.models import attention

    cfg = ModelConfig(
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
        vocab_size=64, head_dim=16, compute_dtype="float32",
    )
    p = attention.attn_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32))
    pos = jnp.broadcast_to(jnp.arange(32), (2, 32))

    def loss(p, cfg_):
        y, _ = attention.attn_apply(p, cfg_, x, positions=pos)
        return jnp.sum(y ** 2)

    g0 = jax.grad(loss)(p, cfg)
    g1 = jax.grad(loss)(p, cfg.replace(attn_q_chunk=8))
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
