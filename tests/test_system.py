"""End-to-end behaviour tests for the paper's system.

The paper's headline claims, verified on the full stack (real reduced LM,
real gradients, real central-server protocol):

1. §5 round-robin central-server training of a model equals the serial
   composition of node updates (the mini-batch-GD equivalence).
2. §5 asynchronous training converges comparably to synchronous.
3. The low-communication push (top-k + error feedback) trains at a
   fraction of the bytes.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import schedules, server
from repro.data import synthetic_lm_batch
from repro.models import transformer as tf


def _setup(seed=0, K=4, T=32, vocab=256):
    cfg = get_config("tinyllama-1.1b").reduced().replace(vocab_size=vocab)
    params = tf.init_params(jax.random.key(seed), cfg)
    batches = [
        synthetic_lm_batch(jax.random.key(100 + k), 2, T, vocab) for k in range(K)
    ]
    return cfg, params, batches


def _stacked(batches):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)


def _node_update(cfg, batches, lr):
    stacked = _stacked(batches)
    grad_fn = jax.jit(jax.grad(lambda p, b: tf.loss_fn(p, cfg, b)[0]))

    def F(k, theta):
        g = grad_fn(theta, jax.tree.map(lambda x: x[k], stacked))
        return jax.tree.map(lambda t, gi: t - lr * gi, theta, g)

    return F


def test_round_robin_lm_training_equals_serial():
    cfg, params, batches = _setup()
    F = _node_update(cfg, batches, lr=0.05)
    sched = schedules.round_robin(4, 2)
    final, _ = server.run_protocol(params, F, sched)
    theta = params
    for t in range(len(sched)):
        theta = F(int(sched[t]), theta)
    for a, b in zip(jax.tree.leaves(final.theta), jax.tree.leaves(theta)):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=3e-5)


def test_async_lm_training_converges():
    cfg, params, batches = _setup()
    F = _node_update(cfg, batches, lr=0.05)
    loss_fn = jax.jit(lambda p, b: tf.loss_fn(p, cfg, b)[0])

    def mean_loss(theta):
        return float(
            np.mean([float(loss_fn(theta, b)) for b in batches])
        )

    l0 = mean_loss(params)
    sched = schedules.asynchronous(jax.random.key(5), 4, 24)
    final, _ = server.run_protocol(params, F, sched)
    l_async = mean_loss(final.theta)
    final_rr, _ = server.run_protocol(params, F, schedules.round_robin(4, 6))
    l_sync = mean_loss(final_rr.theta)
    assert l_async < l0 - 0.05
    # same ballpark (paper §5 claim): async realizes most of the sync
    # improvement.  Relative criterion — the absolute gap is seed/backend
    # dependent for a 24-contact run.
    assert (l0 - l_async) > 0.7 * (l0 - l_sync)


def test_compressed_push_trains():
    from repro.core.compression import ef_compress, ef_init, raw_bytes, topk_compress

    cfg, params, batches = _setup()
    grad_fn = jax.jit(jax.grad(lambda p, b: tf.loss_fn(p, cfg, b)[0]))
    loss_fn = jax.jit(lambda p, b: tf.loss_fn(p, cfg, b)[0])
    ef = ef_init(params)
    theta = params
    wire = 0.0
    for i in range(8):
        g = grad_fn(theta, batches[i % 4])
        ef, comp = ef_compress(ef, g, lambda t: topk_compress(t, 0.1))
        wire += float(comp.wire_bytes)
        theta = jax.tree.map(lambda t, gi: t - 0.05 * gi, theta, comp.tree)
    l0 = float(loss_fn(params, batches[0]))
    l1 = float(loss_fn(theta, batches[0]))
    assert l1 < l0
    assert wire < 8 * raw_bytes(params) * 0.25  # ≥4× wire saving
