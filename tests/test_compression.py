"""Low-communication-overhead push path (top-k / rand-k / int8 / EF)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import (
    ef_compress,
    ef_init,
    int8_compress,
    randk_compress,
    raw_bytes,
    topk_compress,
)


def _tree(rng):
    return {
        "a": jnp.asarray(rng.normal(size=(64,))),
        "b": {"c": jnp.asarray(rng.normal(size=(8, 16)))},
    }


def test_topk_keeps_fraction(rng):
    t = _tree(rng)
    comp = topk_compress(t, 0.25)
    nz_a = int(jnp.sum(comp.tree["a"] != 0))
    nz_c = int(jnp.sum(comp.tree["b"]["c"] != 0))
    assert nz_a == 16
    assert nz_c == 32
    assert float(comp.wire_bytes) < raw_bytes(t)


def test_topk_keeps_largest(rng):
    x = jnp.asarray(rng.normal(size=(100,)))
    comp = topk_compress({"x": x}, 0.1)
    kept = jnp.abs(comp.tree["x"][comp.tree["x"] != 0])
    dropped_max = jnp.max(jnp.abs(x * (comp.tree["x"] == 0)))
    assert float(jnp.min(kept)) >= float(dropped_max)


def test_randk_unbiased(rng):
    x = jnp.asarray(rng.normal(size=(32,)))
    acc = jnp.zeros_like(x)
    n = 300
    for i in range(n):
        comp = randk_compress(jax.random.key(i), {"x": x}, 0.5)
        acc = acc + comp.tree["x"]
    np.testing.assert_allclose(acc / n, x, atol=0.25)


def test_int8_roundtrip_error_bound(rng):
    x = jnp.asarray(rng.normal(size=(128,)))
    comp = int8_compress({"x": x})
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(comp.tree["x"] - x))) <= scale * 0.5 + 1e-6


def test_error_feedback_conservation(rng):
    """EF invariant: transmitted + residual == update + previous residual."""
    t = _tree(rng)
    ef = ef_init(t)
    ef2, comp = ef_compress(ef, t, lambda u: topk_compress(u, 0.25))
    recon = jax.tree.map(jnp.add, comp.tree, ef2.residual)
    for a, b in zip(jax.tree.leaves(recon), jax.tree.leaves(t)):
        np.testing.assert_allclose(a, b, rtol=1e-6)


def test_error_feedback_eventually_transmits():
    """Nothing is lost forever: repeated EF pushes of the same gradient sum
    to ~steps × gradient (the EF-SGD convergence mechanism)."""
    import numpy as _np

    g = {"x": jnp.asarray(_np.random.default_rng(42).normal(size=(50,)))}
    ef = ef_init(g)
    total = jnp.zeros(50)
    steps = 40
    for _ in range(steps):
        ef, comp = ef_compress(ef, g, lambda u: topk_compress(u, 0.1))
        total = total + comp.tree["x"]
    np.testing.assert_allclose(total / steps, g["x"], atol=0.15)


def test_kernel_matches_reference_path(rng):
    from repro.kernels.topk_compress import ref as tk_ref

    x = jnp.asarray(rng.normal(size=(2048,)))
    comp_ref = topk_compress({"x": x}, 0.05, use_kernel=False)
    comp_k = topk_compress({"x": x}, 0.05, use_kernel=True)
    np.testing.assert_allclose(comp_ref.tree["x"], comp_k.tree["x"], rtol=1e-6)
