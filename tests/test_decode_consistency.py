"""Decode-with-cache must reproduce the full forward pass exactly for every
mixer family — the core serving-correctness invariant."""

import jax
import jax.numpy as jnp
import pytest

from repro.models import transformer as tf, whisper
from repro.models.config import (
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    XLSTMConfig,
)

CASES = {
    "dense_gqa": ModelConfig(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=128, head_dim=16,
    ),
    "sliding_window": ModelConfig(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=128, head_dim=16, sliding_window=6,
    ),
    "mla": ModelConfig(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=128, mixer="mla",
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
    ),
    "moe": ModelConfig(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=128, head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                      num_shared_experts=1, d_ff_shared=64, capacity_factor=2.0),
    ),
    "mamba": ModelConfig(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=128, head_dim=16, ssm=SSMConfig(), hybrid_pattern=("mamba",),
    ),
    "hybrid": ModelConfig(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=128, head_dim=16, ssm=SSMConfig(),
        hybrid_pattern=("mamba", "attn"),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                      layer_mode="every_other", capacity_factor=2.0),
    ),
    "xlstm": ModelConfig(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=0,
        vocab_size=128, xlstm=XLSTMConfig(slstm_at=(1, 3)),
    ),
}


@pytest.mark.parametrize(
    "name",
    [
        pytest.param(
            n,
            marks=pytest.mark.xfail(
                reason="pure-mamba decode drifts ~2e-2 from the chunked "
                "forward on CPU jax 0.4.x (bf16 scan-order numerics); "
                "hybrid mamba+attn matches",
                strict=False,
            ),
        )
        if n == "mamba"
        else n
        for n in sorted(CASES)
    ],
)
def test_decode_matches_full_forward(name):
    cfg = CASES[name]
    T, B = 12, 2
    params = tf.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)
    full, _, _ = tf.forward(params, cfg, toks)
    cache = tf.init_cache(cfg, B, T, jnp.float32)
    outs = []
    for t in range(T):
        lg, cache = tf.decode_step(params, cfg, toks[:, t : t + 1], cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(full - dec)))
    assert err < 2e-3, f"{name}: decode diverges from forward by {err}"


def test_mla_absorb_matches_unabsorbed():
    """The absorbed (latent-space) MLA decode is a pure refactoring: same
    math, fewer per-step FLOPs — outputs must match (fp32 compute so the
    comparison is not dominated by bf16 rounding)."""
    cfg = CASES["mla"].replace(compute_dtype="float32")
    B, T = 2, 8
    params = tf.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)
    c1 = tf.init_cache(cfg, B, T, jnp.float32)
    c2 = tf.init_cache(cfg, B, T, jnp.float32)
    for t in range(T):
        lg1, c1 = tf.decode_step(params, cfg, toks[:, t : t + 1], c1)
        lg2, c2 = tf.decode_step(
            params, cfg, toks[:, t : t + 1], c2, mla_absorb=True
        )
        assert float(jnp.max(jnp.abs(lg1 - lg2))) < 2e-3


def test_whisper_decode_matches_full():
    cfg = ModelConfig(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=128, head_dim=16, is_encoder_decoder=True,
        num_encoder_layers=2, encoder_seq_len=8,
    )
    B, T = 2, 8
    wp = whisper.init_params(jax.random.key(0), cfg)
    frames = jax.random.normal(jax.random.key(2), (B, 8, 64))
    toks = jax.random.randint(jax.random.key(3), (B, T), 0, 128)
    mem = whisper.encode(wp, cfg, frames)
    full, _ = whisper.decode(wp, cfg, toks, mem)
    cache = whisper.init_decoder_cache(cfg, B, T, jnp.float32)
    outs = []
    for t in range(T):
        lg, cache = whisper.decode_step(wp, cfg, toks[:, t : t + 1], mem, cache, position=t)
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(full - jnp.stack(outs, 1))))
    assert err < 2e-3


def test_prefill_then_decode_consistency():
    """Multi-token cache prefill (attention archs) == token-by-token."""
    cfg = CASES["dense_gqa"]
    B, T = 2, 12
    params = tf.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)
    # prefill 8 tokens at once, then decode 4
    cache = tf.init_cache(cfg, B, T, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8), (B, 8))
    lg, _, cache = tf.forward(params, cfg, toks[:, :8], positions=pos, cache=cache)
    outs = [lg[:, -1]]
    for t in range(8, T):
        lg1, cache = tf.decode_step(params, cfg, toks[:, t : t + 1], cache)
        outs.append(lg1[:, 0])
    full, _, _ = tf.forward(params, cfg, toks)
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(full[:, 7:] - dec)))
    assert err < 2e-3
