"""Nearest-centroid Pallas kernel vs oracle (all metrics, shape sweep)."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.pdist_argmin import ops, ref

CASES = [
    (500, 16, 8, "l2"),
    (300, 7, 5, "l1"),
    (260, 5, 3, "linf"),
    (128, 32, 64, "l2"),
    (1000, 3, 2, "linf"),
    (65, 4, 4, "l1"),  # N not a multiple of bn
]


@pytest.mark.parametrize("case", CASES)
def test_pdist_matches_ref(case):
    N, K, d, metric = case
    kx, kc = jax.random.split(jax.random.key(N + K))
    X = jax.random.normal(kx, (N, d))
    C = jax.random.normal(kc, (K, d))
    idx, dist = ops.pdist_argmin(X, C, metric=metric, bn=64)
    eidx, edist = ref.pdist_argmin_ref(X, C, metric=metric)
    assert bool(jnp.all(idx == eidx))
    assert bool(jnp.allclose(dist, edist, atol=1e-5))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pdist_dtypes(dtype):
    kx, kc = jax.random.split(jax.random.key(0))
    X = jax.random.normal(kx, (200, 8)).astype(dtype)
    C = jax.random.normal(kc, (5, 8)).astype(dtype)
    idx, _ = ops.pdist_argmin(X, C, metric="l2", bn=64)
    eidx, _ = ref.pdist_argmin_ref(X.astype(jnp.float32), C.astype(jnp.float32), "l2")
    # bf16 rounding may flip genuinely ambiguous points; demand 99%
    agree = float(jnp.mean((idx == eidx).astype(jnp.float32)))
    assert agree > 0.99


def test_kmeans_estep_equivalence():
    """Kernel must agree with the clustering module's reference E-step."""
    from repro.ml.clustering import pdist

    kx, kc = jax.random.split(jax.random.key(1))
    X = jax.random.normal(kx, (300, 4))
    C = jax.random.normal(kc, (6, 4))
    idx, _ = ops.pdist_argmin(X, C, metric="l2", bn=128)
    expected = jnp.argmin(pdist(X, C, metric="l2sq"), axis=1)
    assert bool(jnp.all(idx == expected))
