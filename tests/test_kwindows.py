"""K-windows (paper §4.2) — three phases + distributed variant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ml import kwindows


@pytest.fixture(scope="module")
def blobs():
    rng = np.random.default_rng(17)
    centers = np.asarray([(-5.0, -5.0), (0.0, 5.0), (5.0, -2.0)])
    X = np.concatenate([rng.normal(size=(60, 2)) * 0.6 + c for c in centers])
    return jnp.asarray(X), centers


def test_phase1_moves_windows_onto_blobs(blobs):
    X, centers = blobs
    win = kwindows.init_windows(jax.random.key(0), X, 6, r=1.5)
    win = kwindows.phase1_movements(X, win, iters=25)
    # every window center must sit near SOME blob center
    d = np.min(
        np.linalg.norm(
            np.asarray(win.centers)[:, None, :] - centers[None], axis=-1
        ),
        axis=1,
    )
    assert np.all(d < 1.5)


def test_phase2_enlargement_grows_capture(blobs):
    X, _ = blobs
    win = kwindows.init_windows(jax.random.key(0), X, 6, r=0.8)
    win = kwindows.phase1_movements(X, win)
    before = float(jnp.sum(jnp.sum(kwindows.window_membership(X, win), axis=1) > 0))
    win2 = kwindows.phase2_enlargement(X, win, rounds=6)
    after = float(jnp.sum(jnp.sum(kwindows.window_membership(X, win2), axis=1) > 0))
    assert after >= before
    assert bool(jnp.all(win2.halfwidths >= win.halfwidths - 1e-6))


def test_phase3_merging_reduces_window_count(blobs):
    X, _ = blobs
    win = kwindows.kwindows(jax.random.key(1), X, num_windows=9, r=1.5)
    assert int(jnp.sum(win.alive)) <= 6  # started with 9, blobs are 3
    assert int(jnp.sum(win.alive)) >= 3


def test_full_kwindows_high_precision(blobs):
    """Paper: 'the precision is high (due to the enlargement of windows
    procedure)' — captured points belong to the right blob."""
    X, centers = blobs
    win = kwindows.kwindows(jax.random.key(2), X, num_windows=9, r=1.2)
    assign = kwindows.assign_points(X, win)
    true_label = np.repeat(np.arange(3), 60)
    correct = 0
    total = 0
    for w in range(win.centers.shape[0]):
        pts = np.asarray(assign) == w
        if pts.sum() == 0:
            continue
        majority = np.bincount(true_label[pts]).max()
        correct += majority
        total += pts.sum()
    assert total > 0.65 * X.shape[0]  # recall is allowed to be lower
    assert correct / total > 0.95  # precision is high


def test_distributed_naive_merges_at_least_as_much(blobs):
    """[60]'s naive rule (merge on ANY overlap) over-merges vs. the
    count-gated centralized phase 3 — the paper's criticism."""
    X, _ = blobs
    Xs = X.reshape(3, 60, 2)
    win_c = kwindows.kwindows(jax.random.key(3), X, num_windows=6, r=1.2)
    win_d = kwindows.distributed_kwindows(
        jax.random.key(3), Xs, num_windows=6, r=1.2
    )
    # distributed starts with 3×6 windows; naive overlap-merge collapses
    assert int(jnp.sum(win_d.alive)) <= 3 * int(jnp.sum(win_c.alive))


def test_window_membership_box_semantics():
    X = jnp.asarray([[0.0, 0.0], [0.5, 0.5], [2.0, 0.0]])
    win = kwindows.KWindows(
        centers=jnp.asarray([[0.0, 0.0]]),
        halfwidths=jnp.asarray([[1.0, 1.0]]),
        alive=jnp.ones(1),
        counts=jnp.zeros(1),
    )
    m = kwindows.window_membership(X, win)
    np.testing.assert_array_equal(np.asarray(m[:, 0]), [True, True, False])


def test_boxes_overlap():
    win = kwindows.KWindows(
        centers=jnp.asarray([[0.0, 0.0], [1.5, 0.0], [9.0, 9.0]]),
        halfwidths=jnp.ones((3, 2)),
        alive=jnp.ones(3),
        counts=jnp.zeros(3),
    )
    ov = kwindows.boxes_overlap(win)
    assert bool(ov[0, 1]) and not bool(ov[0, 2])
