"""Per-architecture smoke tests: REDUCED variant of each assigned family,
one forward/train step on CPU, asserting shapes + no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models import transformer as tf, whisper


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


def _lm_batch(cfg, key, B=2, T=16):
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "vlm":
        Tv = 4
        batch["vision_embeds"] = jax.random.normal(
            key, (B, Tv, cfg.d_model), jnp.dtype(cfg.compute_dtype)
        )
        pos = jnp.broadcast_to(jnp.arange(T), (B, T))
        batch["mrope_positions"] = jnp.stack([pos, pos // 2, pos // 2])
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch, key):
    cfg = get_config(arch).reduced()
    B, T = 2, 16
    if cfg.is_encoder_decoder:
        params = whisper.init_params(key, cfg)
        batch = {
            "frame_embeds": jax.random.normal(
                key, (B, cfg.encoder_seq_len, cfg.d_model)
            ),
            "tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
        }
        batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
        loss_fn = lambda p: whisper.loss_fn(p, cfg, batch)[0]
    else:
        params = tf.init_params(key, cfg)
        batch = _lm_batch(cfg, key, B, T)
        loss_fn = lambda p: tf.loss_fn(p, cfg, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert jnp.isfinite(gnorm) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes(arch, key):
    cfg = get_config(arch).reduced()
    B, T = 2, 16
    if cfg.is_encoder_decoder:
        params = whisper.init_params(key, cfg)
        frames = jax.random.normal(key, (B, cfg.encoder_seq_len, cfg.d_model))
        toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
        memory = whisper.encode(params, cfg, frames)
        assert memory.shape == (B, cfg.encoder_seq_len, cfg.d_model)
        logits, _ = whisper.decode(params, cfg, toks, memory)
        assert logits.shape == (B, T, cfg.padded_vocab)
    else:
        params = tf.init_params(key, cfg)
        batch = _lm_batch(cfg, key, B, T)
        logits, aux, _ = tf.forward(
            params,
            cfg,
            batch["tokens"],
            mrope_positions=batch.get("mrope_positions"),
            vision_embeds=batch.get("vision_embeds"),
        )
        assert logits.shape == (B, T, cfg.padded_vocab)
        assert jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size]))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch, key):
    cfg = get_config(arch).reduced()
    B, S = 2, 24
    if cfg.is_encoder_decoder:
        params = whisper.init_params(key, cfg)
        frames = jax.random.normal(key, (B, cfg.encoder_seq_len, cfg.d_model))
        memory = whisper.encode(params, cfg, frames)
        cache = whisper.init_decoder_cache(cfg, B, S, jnp.float32)
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
        logits, cache2 = whisper.decode_step(
            params, cfg, tok, memory, cache, position=0
        )
    else:
        params = tf.init_params(key, cfg)
        cache = tf.init_cache(cfg, B, S, jnp.float32, index=4)
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
        logits, cache2 = tf.decode_step(params, cfg, tok, cache)
    assert logits.shape[:2] == (B, 1)
    assert jnp.all(jnp.isfinite(logits[..., : cfg.vocab_size]))


def test_reduced_configs_satisfy_brief():
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        pat = len(cfg.hybrid_pattern) if cfg.hybrid_pattern else 2
        assert cfg.num_layers <= max(2, pat)
        assert cfg.d_model <= 512
        if cfg.moe is not None:
            assert cfg.moe.num_experts <= 4
