"""Tracing plane: overhead/exactness contracts, Chrome export schema,
RunReport joins, and the serve-path spans.

The load-bearing guarantees (docs/OBSERVABILITY.md):

* a fit with a tracer (on, off, or absent) returns BITWISE-identical
  results — tracing is host-side only, never inside the compiled
  program;
* ``trace="phases"`` replays fenced probes AFTER the fit, so it is
  bit-exact by construction — asserted anyway;
* ``export_chrome`` emits valid trace-event JSON (``ph``/``ts``/``pid``/
  ``tid``/``name`` on every event) loadable in Perfetto;
* mesh/multipod placements get per-hop collective spans and per-phase
  device timings (8-fake-device subprocess case);
* ``ServeMetrics`` keeps a bounded latency window evicting oldest-first
  with p50/p95/p99 over the survivors.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from collections import deque

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.ml.linear import lsq_loss
from repro.serve import MicroBatcher, ServeEngine, ServeMetrics
from repro.telemetry import RunReport, Tracer
from repro.telemetry import trace as trace_mod

K, NK, N, STEPS = 8, 12, 5, 25


@pytest.fixture()
def problem():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(K, NK, N)))
    w = jnp.asarray(rng.normal(size=(N,)))
    y = jnp.einsum("kni,i->kn", X, w)
    return (X, y)


def _fit(data, **kw):
    return api.fit(
        api.GradientDescent(lsq_loss, lr=0.05), data,
        transport="allreduce", steps=STEPS, **kw,
    )


def _bitwise(a, b) -> bool:
    a, b = np.asarray(a), np.asarray(b)
    return a.shape == b.shape and a.tobytes() == b.tobytes()


class TestExactness:
    def test_tracer_off_bitwise_identical(self, problem):
        """The zero-overhead contract's correctness half: no-tracer,
        disabled-tracer, and live-tracer fits all produce the same bits
        (theta, trajectory, ledger)."""
        base = _fit(problem)
        for tracer in (Tracer(enabled=False), Tracer()):
            res = _fit(problem, tracer=tracer)
            assert _bitwise(base.theta, res.theta)
            assert _bitwise(base.trajectory, res.trajectory)
            assert base.ledger.summary() == res.ledger.summary()

    def test_disabled_tracer_records_nothing(self, problem):
        t = Tracer(enabled=False)
        _fit(problem, tracer=t)
        t.count("x")
        assert t.spans == [] and t.counters == {}

    def test_trace_phases_bit_exact(self, problem):
        """trace="phases" never touches the fit program — the probes
        replay afterwards — so results stay bitwise identical."""
        base = _fit(problem)
        t = Tracer()
        res = _fit(problem, tracer=t, trace="phases")
        assert _bitwise(base.theta, res.theta)
        assert _bitwise(base.trajectory, res.trajectory)
        assert base.ledger.summary() == res.ledger.summary()
        names = {s["name"] for s in t.spans}
        assert {"fit/loop", "phase/local_step", "phase/encode"} <= names

    def test_trace_phases_requires_tracer(self, problem):
        with pytest.raises(ValueError, match="tracer"):
            _fit(problem, trace="phases")
        with pytest.raises(ValueError, match="trace"):
            _fit(problem, trace="rounds")


class TestTracer:
    def test_span_nesting_and_summary(self):
        t = Tracer()
        with t.span("outer", round=1):
            with t.span("inner"):
                pass
            with t.span("inner"):
                pass
        agg = t.summary()
        assert agg["inner"]["count"] == 2
        assert agg["outer"]["count"] == 1
        assert agg["outer"]["total_s"] >= agg["inner"]["total_s"]
        assert t.wall_s("outer") == agg["outer"]["total_s"]

    def test_counters_and_gauges(self):
        t = Tracer()
        t.count("hits")
        t.count("hits", 2)
        t.gauge("depth", 7)
        t.gauge("depth", 3)
        assert t.counters == {"hits": 3}
        assert t.gauges == {"depth": 3}

    def test_span_tags_mutable_inside(self):
        t = Tracer()
        with t.span("s", a=1) as rec:
            rec["tags"]["b"] = 2
        assert t.spans[0]["tags"] == {"a": 1, "b": 2}

    def test_ambient_span_noop_without_tracer(self):
        assert trace_mod.current_tracer() is None
        with trace_mod.span("nothing"):
            pass  # must not raise, must not record anywhere

    def test_ambient_activation(self):
        t = Tracer()
        with trace_mod.activated(t):
            assert trace_mod.current_tracer() is t
            with trace_mod.span("ambient"):
                pass
        assert trace_mod.current_tracer() is None
        assert [s["name"] for s in t.spans] == ["ambient"]

    def test_chrome_export_schema(self, problem, tmp_path):
        """The acceptance criterion: every exported event carries the
        trace-event schema keys, complete events carry dur, and the file
        is valid JSON under a traceEvents root."""
        t = Tracer()
        _fit(problem, tracer=t, trace="phases")
        t.count("custom", 3)
        path = t.export_chrome(str(tmp_path / "run.trace.json"))
        with open(path) as f:
            payload = json.load(f)
        events = payload["traceEvents"]
        assert events, "no events exported"
        for e in events:
            assert {"ph", "ts", "pid", "tid", "name"} <= set(e), e
            assert e["ph"] in ("X", "C", "M"), e
            if e["ph"] == "X":
                assert "dur" in e and e["dur"] >= 0
        names = {e["name"] for e in events if e["ph"] == "X"}
        assert "fit/loop" in names and "phase/local_step" in names
        assert any(
            e["ph"] == "C" and e["name"] == "custom" for e in events
        )

    def test_traceview_cli(self, problem, tmp_path):
        t = Tracer()
        _fit(problem, tracer=t)
        path = t.export_chrome(str(tmp_path / "run.trace.json"))
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        proc = subprocess.run(
            [sys.executable, os.path.join(root, "tools", "traceview.py"),
             path],
            capture_output=True, text=True, timeout=60,
        )
        assert proc.returncode == 0, proc.stderr
        assert "fit/loop" in proc.stdout


class TestRunReport:
    def test_from_fit_joins_everything(self, problem):
        t = Tracer()
        res = _fit(problem, wire="topk:0.5+ef", tracer=t, trace="phases")
        rep = RunReport.from_fit(res, tracer=t)
        d = rep.as_dict()
        assert d["config"]["wire"] == "topk:0.5+ef"
        assert d["comm"]["total_bytes"] == res.ledger.total_bytes
        assert "fit/loop" in d["spans"]
        assert "wire_kernel_hits" in d
        assert {"hits", "misses", "size"} <= set(d["program_cache"])
        json.dumps(d)  # the whole artifact is one JSON-serializable dict
        md = rep.to_markdown()
        assert "RunReport (fit)" in md and "fit/loop" in md

    def test_from_serve(self, problem):
        res = _fit(problem)
        strategy = api.GradientDescent(lsq_loss, lr=0.05)
        t = Tracer()
        eng = ServeEngine.from_fit(res, strategy, tracer=t)
        eng.predict(np.zeros((3, N), np.float32))
        rep = RunReport.from_serve(eng)
        d = rep.as_dict()
        assert d["serve"]["requests"] == 3
        assert "serve/predict" in d["spans"]
        assert "p99_latency_ms" in d["serve"]
        assert "RunReport (serve)" in rep.to_markdown()

    def test_sweep_fit_report(self, problem):
        t = Tracer()
        res = _fit(
            problem, tracer=t,
            executor=api.SweepExecutor({"lr": jnp.asarray([0.02, 0.1])}),
        )
        d = RunReport.from_fit(res, tracer=t).as_dict()
        assert d["comm"]["scenarios"] == 2
        json.dumps(d)

    def test_metrics_json(self, problem):
        res = _fit(problem, executor="serve")
        m = res.metrics_json()
        assert "carry" not in m
        assert m["serve_engine"] == "<ServeEngine>"
        assert m["transport"] == "allreduce"
        json.dumps(m)
        # and the raw metrics really are NOT serializable — the reason
        # metrics_json exists
        with pytest.raises(TypeError):
            json.dumps(res.metrics)


class TestServeTracing:
    def test_engine_spans_and_counters(self, problem):
        res = _fit(problem)
        strategy = api.GradientDescent(lsq_loss, lr=0.05)
        t = Tracer()
        eng = ServeEngine.from_fit(res, strategy, tracer=t)
        eng.predict(np.zeros((2, N), np.float32))
        eng.swap(res.theta)
        names = [s["name"] for s in t.spans]
        assert "serve/swap" in names and "serve/predict" in names
        assert t.counters["serve/requests"] == 2

    def test_engine_captures_ambient_tracer(self, problem):
        t = Tracer()
        res = _fit(problem, executor="serve", tracer=t)
        assert res.metrics["serve_engine"].tracer is t

    def test_batcher_queue_wait(self):
        now = [0.0]
        t = Tracer()
        mb = MicroBatcher(
            lambda X: X * 2.0, max_batch=4, clock=lambda: now[0], tracer=t,
        )
        mb.submit(np.zeros(3, np.float32))
        now[0] = 1.0
        mb.submit(np.zeros(3, np.float32))
        now[0] = 5.0
        mb.flush()
        (serve_span,) = [s for s in t.spans if s["name"] == "batcher/serve"]
        assert serve_span["tags"]["queue_wait_ms"] == pytest.approx(5000.0)
        assert serve_span["tags"]["valid"] == 2
        assert serve_span["tags"]["bucket"] == 2
        assert t.counters["batcher/queue_wait_s"] == pytest.approx(9.0)
        assert t.counters["batcher/requests"] == 2


class TestServeMetricsWindow:
    def test_p99_key(self):
        m = ServeMetrics()
        s = m.summary()
        assert "p99_latency_ms" in s and s["p99_latency_ms"] == 0.0

    def test_window_evicts_oldest_first(self):
        """The bounded latency window is a deque(maxlen=W): request W+1
        pushes out request 1, never a newer one — percentiles always
        describe the most recent W requests."""
        m = ServeMetrics(latencies_s=deque(maxlen=4))
        z = np.zeros(1, np.float32)
        for lat in (1.0, 2.0, 3.0, 4.0, 5.0, 6.0):
            m.record_batch(1, 1, lat, z, z, tag="t")
        assert list(m.latencies_s) == [3.0, 4.0, 5.0, 6.0]
        s = m.summary()
        assert s["p99_latency_ms"] == pytest.approx(6000.0)
        assert s["p50_latency_ms"] == pytest.approx(5000.0)
        # exact totals are NOT windowed — all six requests counted
        assert s["requests"] == 6


class TestMultipodEightDevices:
    """Acceptance case: on a 2×4 ``("pod", "data")`` mesh (8 fake CPU
    devices, forced in a subprocess), a traced multipod fit with a
    topk+ef wire yields per-hop collective spans (``hop/intra_pod`` /
    ``hop/inter_pod``), per-phase wall times, cache state and kernel
    hits in ONE RunReport — and stays bitwise identical to the untraced
    fit."""

    SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import json
import jax
import jax.numpy as jnp
import numpy as np
from repro import api
from repro.ml.linear import lsq_loss
from repro.telemetry import RunReport, Tracer

rng = np.random.default_rng(0)
X = jnp.asarray(rng.normal(size=(8, 10, 16)))
w = jnp.asarray(rng.normal(size=(16,)))
y = jnp.einsum("kni,i->kn", X, w)
mesh = jax.make_mesh((2, 4), ("pod", "data"))

def fit(**kw):
    return api.fit(api.GradientDescent(lsq_loss, lr=0.05), (X, y),
                   transport="allreduce", steps=20,
                   executor=api.MultiPodExecutor(mesh),
                   wire="topk:0.5+ef", **kw)

base = fit()
tracer = Tracer()
res = fit(tracer=tracer, trace="phases")
a, b = np.asarray(base.theta), np.asarray(res.theta)
d = RunReport.from_fit(res, tracer=tracer).as_dict()
events = tracer.chrome_events()
out = {
    "num_devices": jax.device_count(),
    "theta_bitwise": bool((a.view(np.uint32) == b.view(np.uint32)).all()),
    "span_names": sorted({s["name"] for s in tracer.spans}),
    "by_hop": sorted(d["comm"]["by_hop"]),
    "hop_bytes_positive": all(
        h["total_bytes"] > 0 for h in d["comm"]["by_hop"].values()
    ),
    "has_kernel_hits": "wire_kernel_hits" in d,
    "report_json_ok": bool(json.dumps(d)),
    "schema_ok": all(
        {"ph", "ts", "pid", "tid", "name"} <= set(e) for e in events
    ),
}
print(json.dumps(out))
"""

    def test_per_hop_spans(self, fake_devices):
        out = fake_devices(self.SCRIPT)
        assert out["num_devices"] == 8
        assert out["theta_bitwise"], "traced multipod fit drifted"
        names = set(out["span_names"])
        assert {"hop/intra_pod", "hop/inter_pod", "phase/local_step",
                "phase/encode", "phase/stats_completion",
                "dispatch/multipod-update", "fit/loop"} <= names, names
        assert out["by_hop"] == ["inter_pod", "intra_pod"]
        assert out["hop_bytes_positive"]
        assert out["has_kernel_hits"]
        assert out["report_json_ok"] and out["schema_ok"]
