"""Consensus ADMM engine (paper §3.1/§3.2 Douglas-Rachford)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.admm import (
    consensus_admm,
    gradient_local_prox,
    prox_l1,
    prox_l2sq,
)


def test_prox_l1_soft_threshold():
    v = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
    np.testing.assert_allclose(
        prox_l1(v, 1.0), jnp.asarray([-1.0, 0.0, 0.0, 0.0, 1.0])
    )


def test_prox_l2sq():
    np.testing.assert_allclose(prox_l2sq(jnp.asarray([2.0]), 1.0), [1.0])


def test_consensus_least_squares_matches_closed_form(rng):
    K, Nk, n = 3, 20, 4
    X = jnp.asarray(rng.normal(size=(K, Nk, n)))
    w = jnp.asarray(rng.normal(size=(n,)))
    y = jnp.einsum("kni,i->kn", X, w)

    XtX = jnp.einsum("kni,knj->kij", X, X)
    Xty = jnp.einsum("kni,kn->ki", X, y)

    def local_prox(v, u, rho):
        A = XtX + rho * jnp.eye(n)[None]
        b = Xty + rho * v
        return jax.vmap(jnp.linalg.solve)(A, b)

    res = consensus_admm(local_prox, K, n, rho=1.0, iters=100)
    # unregularized consensus LS = global least squares = w (noiseless)
    np.testing.assert_allclose(res.z, w, atol=1e-3)


def test_residuals_decrease(rng):
    K, Nk, n = 3, 15, 4
    X = jnp.asarray(rng.normal(size=(K, Nk, n)))
    y = jnp.asarray(rng.normal(size=(K, Nk)))
    XtX = jnp.einsum("kni,knj->kij", X, X)
    Xty = jnp.einsum("kni,kn->ki", X, y)

    def local_prox(v, u, rho):
        return jax.vmap(jnp.linalg.solve)(
            XtX + rho * jnp.eye(n)[None], Xty + rho * v
        )

    res = consensus_admm(local_prox, K, n, rho=1.0, iters=150)
    hist = np.asarray(res.history)
    assert hist[-1, 0] < hist[3, 0]  # primal residual shrinks
    assert hist[-1, 0] < 1e-2


def test_gradient_local_prox_solves_subproblem(rng):
    # f_k(θ) = 0.5‖θ − a_k‖²  ⇒ prox = (a_k + ρ v)/(1 + ρ)
    K, n = 2, 3
    a = jnp.asarray(rng.normal(size=(K, n)))

    def grad_f(theta):
        return theta - a

    prox = gradient_local_prox(grad_f, inner_iters=200, lr=0.3)
    v = jnp.asarray(rng.normal(size=(K, n)))
    rho = 2.0
    out = prox(v, None, rho)
    expected = (a + rho * v) / (1.0 + rho)
    np.testing.assert_allclose(out, expected, atol=1e-4)
