"""HLO collective parsing + roofline model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.telemetry.hlo import _shape_bytes, collective_stats
from repro.telemetry.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    model_flops_train,
    roofline,
)


def test_shape_bytes():
    assert _shape_bytes("f32[128,1024]") == 128 * 1024 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert _shape_bytes("pred[8]") == 8


def test_collective_stats_detects_psum():
    mesh = jax.make_mesh((jax.device_count(),), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    @jax.jit
    def f(v):
        return jax.lax.with_sharding_constraint(
            v, NamedSharding(mesh, P())
        ) + 0.0

    # force an all-reduce via shard_map psum
    from jax.experimental.shard_map import shard_map

    g = shard_map(
        lambda v: jax.lax.psum(v, "x"),
        mesh=mesh,
        in_specs=P("x"),
        out_specs=P(),
    )
    txt = jax.jit(g).lower(jnp.ones((jax.device_count() * 4,))).compile().as_text()
    stats = collective_stats(txt)
    assert stats.get("all-reduce", {}).get("count", 0) >= 1
    assert stats["total_bytes"] > 0


def test_roofline_terms():
    r = roofline(
        flops_per_device=PEAK_FLOPS_BF16,  # exactly 1 second of compute
        bytes_per_device=HBM_BW * 2.0,  # 2 seconds of HBM
        collective_bytes_per_device=ICI_BW * 0.5,
        chips=256,
        model_flops=PEAK_FLOPS_BF16 * 256 * 0.5,
    )
    np.testing.assert_allclose(r.compute_s, 1.0)
    np.testing.assert_allclose(r.memory_s, 2.0)
    np.testing.assert_allclose(r.collective_s, 0.5)
    assert r.dominant == "memory"
    np.testing.assert_allclose(r.useful_ratio, 0.5)


def test_model_flops():
    assert model_flops_train(1e9, 1e6) == 6e15


def test_costprobe_segment_math():
    """combine(): full = base + Σ (R_s − 1)·marginal_s."""
    from repro.telemetry import costprobe

    # emulate the probe result combination with synthetic numbers
    base = {"flops": 10.0, "bytes": 100.0, "coll": 1.0}
    seg_plus = {"flops": 14.0, "bytes": 130.0, "coll": 1.5}  # marginal = 4/30/0.5
    R = 10
    expect_flops = 10.0 + (R - 1) * 4.0
    got = base["flops"] + (seg_plus["flops"] - base["flops"]) * (R - 1)
    assert got == expect_flops
