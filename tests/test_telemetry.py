"""HLO collective parsing + roofline model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.telemetry.hlo import _shape_bytes, collective_stats
from repro.telemetry.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    model_flops_train,
    roofline,
)


def test_shape_bytes():
    assert _shape_bytes("f32[128,1024]") == 128 * 1024 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert _shape_bytes("pred[8]") == 8


def test_collective_stats_detects_psum():
    mesh = jax.make_mesh((jax.device_count(),), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    @jax.jit
    def f(v):
        return jax.lax.with_sharding_constraint(
            v, NamedSharding(mesh, P())
        ) + 0.0

    # force an all-reduce via shard_map psum
    from jax.experimental.shard_map import shard_map

    g = shard_map(
        lambda v: jax.lax.psum(v, "x"),
        mesh=mesh,
        in_specs=P("x"),
        out_specs=P(),
    )
    txt = jax.jit(g).lower(jnp.ones((jax.device_count() * 4,))).compile().as_text()
    stats = collective_stats(txt)
    assert stats.get("all-reduce", {}).get("count", 0) >= 1
    assert stats["total_bytes"] > 0


def test_roofline_terms():
    r = roofline(
        flops_per_device=PEAK_FLOPS_BF16,  # exactly 1 second of compute
        bytes_per_device=HBM_BW * 2.0,  # 2 seconds of HBM
        collective_bytes_per_device=ICI_BW * 0.5,
        chips=256,
        model_flops=PEAK_FLOPS_BF16 * 256 * 0.5,
    )
    np.testing.assert_allclose(r.compute_s, 1.0)
    np.testing.assert_allclose(r.memory_s, 2.0)
    np.testing.assert_allclose(r.collective_s, 0.5)
    assert r.dominant == "memory"
    np.testing.assert_allclose(r.useful_ratio, 0.5)


def test_model_flops():
    assert model_flops_train(1e9, 1e6) == 6e15


def test_costprobe_segment_math():
    """combine(): full = base + Σ (R_s − 1)·marginal_s."""
    from repro.telemetry import costprobe

    # emulate the probe result combination with synthetic numbers
    base = {"flops": 10.0, "bytes": 100.0, "coll": 1.0}
    seg_plus = {"flops": 14.0, "bytes": 130.0, "coll": 1.5}  # marginal = 4/30/0.5
    R = 10
    expect_flops = 10.0 + (R - 1) * 4.0
    got = base["flops"] + (seg_plus["flops"] - base["flops"]) * (R - 1)
    assert got == expect_flops


def test_parse_replica_groups_explicit():
    from repro.telemetry.hlo import parse_replica_groups

    assert parse_replica_groups("{{0,1},{2,3}}") == [[0, 1], [2, 3]]
    assert parse_replica_groups("{{0,2,4,6},{1,3,5,7}}") == [
        [0, 2, 4, 6], [1, 3, 5, 7]
    ]


def test_parse_replica_groups_iota():
    from repro.telemetry.hlo import parse_replica_groups

    assert parse_replica_groups("[2,2]<=[4]") == [[0, 1], [2, 3]]
    # transposed iota: arange(4).reshape(2,2).T -> groups {0,2},{1,3}
    assert parse_replica_groups("[2,2]<=[2,2]T(1,0)") == [[0, 2], [1, 3]]
    assert parse_replica_groups("bogus") is None


def test_mesh_pod_map():
    from repro.telemetry.hlo import mesh_pod_map

    class FakeMesh:
        axis_names = ("pod", "data")
        shape = {"pod": 2, "data": 4}

    pod_of = mesh_pod_map(FakeMesh())
    assert [pod_of[i] for i in range(8)] == [0, 0, 0, 0, 1, 1, 1, 1]

    class NoPod:
        axis_names = ("data",)
        shape = {"data": 4}

    assert set(mesh_pod_map(NoPod()).values()) == {0}


def test_collective_stats_pod_attribution():
    """Synthetic per-device HLO: one intra-pod and one inter-pod
    all-reduce classified by their replica groups against a 2-pod map."""
    from repro.telemetry.hlo import collective_stats

    hlo = """
  %ar0 = f32[8]{0} all-reduce(f32[8]{0} %x), replica_groups={{0,1},{2,3}}, to_apply=%add
  %ar1 = f32[4]{0} all-reduce(f32[4]{0} %y), replica_groups={{0,2},{1,3}}, to_apply=%add
"""
    pod_of = {0: 0, 1: 0, 2: 1, 3: 1}
    stats = collective_stats(hlo, pod_of=pod_of)
    assert stats["all-reduce"]["count"] == 2
    assert stats["by_tier"]["intra_pod"] == {"count": 1, "bytes": 32}
    assert stats["by_tier"]["inter_pod"] == {"count": 1, "bytes": 16}


def test_collective_stats_pod_attribution_real_lowering():
    """A real staged hierarchical psum lowers to collectives whose
    replica groups classify as intra- then inter-pod (single-device runs
    degenerate to intra-pod only)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.allreduce import hierarchical_allreduce
    from repro.core.topology import Topology
    from repro.launch.mesh import make_multipod_mesh
    from repro.telemetry.hlo import collective_stats, mesh_pod_map

    mesh = make_multipod_mesh()
    topo = Topology.from_mesh(("pod", "data"))

    def f(v):
        return hierarchical_allreduce(v, topo.hops)

    g = jax.jit(shard_map(
        f, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P()
    ))
    n = mesh.shape["pod"] * mesh.shape["data"]
    txt = g.lower(jnp.ones((n * 4,))).compile().as_text()
    stats = collective_stats(txt, pod_of=mesh_pod_map(mesh))
    by_tier = stats.get("by_tier", {})
    assert stats["total_count"] >= 1
    # everything must be attributed (no unparseable replica groups)
    assert by_tier.get("unattributed", {"count": 0})["count"] == 0
    if mesh.shape["pod"] > 1:
        assert by_tier["inter_pod"]["bytes"] > 0
        assert by_tier["intra_pod"]["bytes"] > 0
