"""Sharding rules + mesh context."""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as tf
from repro.models.config import ModelConfig, MoEConfig
from repro.sharding.rules import (
    MeshContext,
    maybe_shard,
    partition_params,
    set_mesh_context,
)


def _params():
    cfg = ModelConfig(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=512, head_dim=16,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64),
    )
    return cfg, tf.init_params(jax.random.key(0), cfg)


def _get(tree, *path):
    for k in path:
        tree = tree[k]
    return tree


def test_param_specs_tp_only():
    cfg, params = _params()
    specs = partition_params(params, model_axis="model", fsdp_axis=None)
    # embedding: vocab over model
    assert _get(specs, "embed", "embedding") == P("model", None)
    # attention projections carry a leading scan dim (None) then (fsdp, model)
    assert _get(specs, "seg0", "l0", "mixer", "wq", "kernel") == P(None, None, "model")
    assert _get(specs, "seg0", "l0", "mixer", "wo", "kernel") == P(None, "model", None)
    # experts: expert dim over model
    assert _get(specs, "seg0", "l0", "ffn", "experts", "w_gate") == P(
        None, "model", None, None
    )
    # norms replicated
    assert _get(specs, "final_norm", "scale") == P()


def test_param_specs_fsdp():
    cfg, params = _params()
    specs = partition_params(params, model_axis="model", fsdp_axis="data")
    assert _get(specs, "seg0", "l0", "mixer", "wq", "kernel") == P(None, "data", "model")
    assert _get(specs, "seg0", "l0", "ffn", "experts", "w_gate") == P(
        None, "model", "data", None
    )


def test_maybe_shard_noop_without_context():
    set_mesh_context(None)
    x = jnp.ones((4, 4))
    y = maybe_shard(x, "batch", None)
    assert y is x


def test_maybe_shard_with_context():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    set_mesh_context(MeshContext(mesh=mesh, logical={"batch": "data", "model": "model"}))
    try:
        x = jnp.ones((4, 4))
        y = jax.jit(lambda v: maybe_shard(v, "batch", "model"))(x)
        assert y.shape == x.shape
    finally:
        set_mesh_context(None)


def test_cache_specs_structure():
    from repro.launch.mesh import make_host_mesh
    from repro.launch import specs as S

    cfg, params = _params()
    mesh = make_host_mesh()
    cspecs = S.cache_specs(cfg, mesh, B=4)
    cache = tf.init_cache(cfg, 4, 16, jnp.float32)
    # structures must match so jit in_shardings line up
    assert jax.tree.structure(
        jax.tree.map(lambda _: 0, cspecs, is_leaf=lambda s: isinstance(s, P))
    ) == jax.tree.structure(jax.tree.map(lambda _: 0, cache))


def test_mesh_context_pod_axis_resolution():
    """The pod axis is a first-class placement target: node_axes carries
    it, pod_axis/intra_pod_axes split the tiers, and topology() derives
    the hierarchical reduction plan."""
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    ctx = MeshContext(mesh=mesh, logical={})
    assert ctx.node_axes == ("pod", "data")
    assert ctx.pod_axis == "pod"
    assert ctx.intra_pod_axes == ("data",)
    topo = ctx.topology()
    assert topo.tiers == ("intra_pod", "inter_pod")
    assert topo.hops[0].axes == ("data",)
    assert topo.hops[1].axes == ("pod",)

    flat = MeshContext(mesh=jax.make_mesh((1, 1), ("data", "model")), logical={})
    assert flat.pod_axis is None
    assert flat.topology().tiers == ("flat",)


def test_multipod_mesh_context_drives_mesh_executor():
    """An active multipod MeshContext supplies the pod mesh to BOTH mesh
    executors — fits resolve it without re-plumbing the mesh."""
    import numpy as np

    from repro import api
    from repro.launch.mesh import make_multipod_mesh
    from repro.ml.linear import lsq_loss

    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(8, 10, 5)))
    w = jnp.asarray(rng.normal(size=(5,)))
    y = jnp.einsum("kni,i->kn", X, w)
    set_mesh_context(MeshContext(mesh=make_multipod_mesh(), logical={}))
    try:
        flat = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="allreduce", steps=10, executor="mesh")
        hier = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (X, y),
                       transport="allreduce", steps=10, executor="multipod")
    finally:
        set_mesh_context(None)
    np.testing.assert_array_equal(np.asarray(flat.theta), np.asarray(hier.theta))
    assert hier.ledger.summary()["by_hop"] != {}
