"""Quickstart: the paper's §5 central-information-server algorithm in 30
lines — four "nodes" cooperatively train a logistic-regression model by
pushing local updates to the server and receiving the handed-back
parameter, synchronously (round-robin) and asynchronously.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import schedules, server
from repro.data import make_feature_shards
from repro.ml.linear import logistic_loss

K, NK, DIM = 4, 50, 8
Xs, ys, w_true = make_feature_shards(0, K, NK, DIM, task="classification")
LR = 0.3


def F(k, theta):
    """The per-node learning method F^(k): one local gradient step."""
    g = jax.grad(logistic_loss)(theta, Xs[k], ys[k])
    return theta - LR * g


def accuracy(theta):
    pred = jnp.sign(Xs.reshape(-1, DIM) @ theta)
    return float(jnp.mean(pred == ys.reshape(-1)))


theta0 = jnp.zeros(DIM)
print(f"init accuracy: {accuracy(theta0):.3f}")

# --- synchronous: round-robin ≡ mini-batch gradient descent (paper §5)
sched = schedules.round_robin(K, num_rounds=50)
final, _ = server.run_protocol(theta0, F, sched)
print(f"round-robin  ({len(sched)} contacts): accuracy {accuracy(final.theta):.3f}")

# --- asynchronous: random contacts, p(S=i) > 0 for every node
sched = schedules.asynchronous(jax.random.key(0), K, num_contacts=200)
final, _ = server.run_protocol(theta0, F, sched)
print(f"asynchronous ({len(sched)} contacts): accuracy {accuracy(final.theta):.3f}")

# --- the literal θ_{t-1} handoff (one-step-stale pipelined variant)
final, _ = server.run_protocol(theta0, F, sched, handoff="stale")
print(f"stale handoff({len(sched)} contacts): accuracy {accuracy(final.theta):.3f}")
