"""Quickstart: the paper's §5 central-information-server algorithm through
the unified ``repro.api`` — four "nodes" cooperatively train a
logistic-regression model.  The per-node learner is ONE function; the
synchronous, asynchronous and stale variants are just transport/schedule
choices on ``api.fit``, and byte accounting comes for free.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import api
from repro.core import schedules
from repro.data import make_feature_shards
from repro.ml.linear import logistic_loss

K, NK, DIM = 4, 50, 8
Xs, ys, w_true = make_feature_shards(0, K, NK, DIM, task="classification")
LR = 0.3


def F(k, theta):
    """The per-node learning method F^(k): one local gradient step."""
    g = jax.grad(logistic_loss)(theta, Xs[k], ys[k])
    return theta - LR * g


def accuracy(theta):
    pred = jnp.sign(Xs.reshape(-1, DIM) @ theta)
    return float(jnp.mean(pred == ys.reshape(-1)))


strategy = api.FunctionStrategy(F, num_nodes=K)
theta0 = jnp.zeros(DIM)
print(f"init accuracy: {accuracy(theta0):.3f}")

# --- synchronous: round-robin ≡ mini-batch gradient descent (paper §5)
sched = schedules.round_robin(K, num_rounds=50)
res = api.fit(strategy, transport="sequential_server", schedule=sched, theta0=theta0)
print(
    f"round-robin  ({len(sched)} contacts): accuracy {accuracy(res.theta):.3f}  "
    f"wire {res.ledger.total_bytes} B"
)

# --- asynchronous: random contacts, p(S=i) > 0 for every node
sched = schedules.asynchronous(jax.random.key(0), K, num_contacts=200)
res = api.fit(strategy, transport="sequential_server", schedule=sched, theta0=theta0)
print(
    f"asynchronous ({len(sched)} contacts): accuracy {accuracy(res.theta):.3f}  "
    f"wire {res.ledger.total_bytes} B"
)

# --- the literal θ_{t-1} handoff (one-step-stale pipelined variant)
res = api.fit(strategy, transport="stale_server", schedule=sched, theta0=theta0)
print(
    f"stale handoff({len(sched)} contacts): accuracy {accuracy(res.theta):.3f}  "
    f"wire {res.ledger.total_bytes} B"
)

# --- same learner, compressed pushes: top-25% delta + error feedback
res = api.fit(
    strategy, transport="stale_server", wire="topk:0.25+ef",
    schedule=sched, theta0=theta0,
)
print(
    f"topk+ef wire ({len(sched)} contacts): accuracy {accuracy(res.theta):.3f}  "
    f"wire {res.ledger.total_bytes} B (uplink {res.ledger.uplink_bytes} B)"
)
