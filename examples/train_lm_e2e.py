"""End-to-end driver: train a ~100M-class LM for a few hundred steps with
the paper's techniques on (staleness + compressed push), checkpoint, then
serve it with a batched decode loop.

Default is a CPU-friendly ~10M variant (a couple of minutes); pass --full
for the ~100M-parameter configuration (hours on CPU, minutes on a real
accelerator — same code path).

  PYTHONPATH=src python examples/train_lm_e2e.py [--full] [--steps 300]
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_config
from repro.launch.serve import prefill_and_decode
from repro.launch.train import main as train_main
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    arch = "tinyllama-1.1b"
    if args.full:
        # ~100M-parameter family member: 12 layers, d_model 768
        cfg = get_config(arch).replace(
            num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=8192,
            param_dtype="float32", compute_dtype="float32",
        )
        seq, batch = 512, 8
    else:
        cfg = get_config(arch).reduced()
        seq, batch = 128, 8

    n_params = sum(
        x.size for x in jax.tree.leaves(tf.init_params(jax.random.key(0), cfg))
    )
    print(f"model: {n_params/1e6:.1f}M params, seq {seq}, batch {batch}")

    with tempfile.TemporaryDirectory() as ckpt:
        # --- train with the paper's §5 features on
        hist = train_main(
            [
                "--arch", arch, *([] if args.full else ["--reduced"]),
                "--steps", str(args.steps), "--batch", str(batch),
                "--seq", str(seq), "--lr", "1e-3",
                "--staleness", "1",            # the paper's θ_{t-1} handoff
                "--compress-topk", "0.25",     # low-communication push
                "--log-every", str(max(args.steps // 10, 1)),
                "--ckpt-dir", ckpt, "--ckpt-every", str(args.steps // 2),
            ]
        )
        assert hist[-1]["loss"] < hist[0]["loss"], "training must improve"

        # --- restore the final checkpoint and serve it
        step = latest_step(ckpt)
        print(f"\nrestoring checkpoint step {step} and serving:")
        cfg_srv = cfg
        params = tf.init_params(jax.random.key(0), cfg_srv)
        params = restore(ckpt, step, params)
        prompts = jax.random.randint(jax.random.key(9), (4, 16), 0, cfg_srv.vocab_size)
        out = prefill_and_decode(
            cfg_srv, params, prompts, gen=24, cache_len=48
        )
        print("generated:", out[0].tolist())
        print("e2e OK: trained → checkpointed → restored → served")


if __name__ == "__main__":
    main()
