"""Hyperparameter sweep ON the mesh — the composed ``mesh+sweep`` executor.

The §5 scaling argument only pays off when a hyperparameter search can
use the hardware you already have: this example trains the full
staleness × compression-threshold grid (delay-line D × threshold-wire τ)
as ONE executable on an 8-device mesh.  The scenario vmap runs *inside*
the shard_map body, so every device hosts its node slice and trains all
S scenarios on it; each scenario gets its own byte-accurate
``CommLedger`` (the τ axis changes what crosses the wire, the D axis
when it lands), and every row is bit-exact with the same fit run alone
on the mesh.

Run on CPU with 8 fake devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/sweep_on_mesh.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import api  # noqa: E402
from repro.ml.linear import lsq_loss  # noqa: E402

K, NK, DIM, STEPS = 8, 32, 16, 150

rng = np.random.default_rng(0)
Xs = jnp.asarray(rng.normal(size=(K, NK, DIM)))
w_true = jnp.asarray(rng.normal(size=(DIM,)))
ys = jnp.einsum("kni,i->kn", Xs, w_true) + 0.01 * jnp.asarray(
    rng.normal(size=(K, NK))
)

# the swept grid: staleness D (the §5 delay) × threshold τ (what fraction
# of each push survives the wire) — flattened to S = |D| × |τ| scenarios,
# every one a lane of the same vmapped scan inside the same shard_map
DS = (0, 1, 2)
TAUS = (0.0, 0.02, 0.1)
grid_d, grid_tau = np.meshgrid(DS, TAUS, indexing="ij")
sweep = {
    "staleness": jnp.asarray(grid_d.ravel()),
    "tau": jnp.asarray(grid_tau.ravel(), dtype=jnp.float32),
}

res = api.fit(
    api.GradientDescent(lsq_loss, lr=0.05),
    (Xs, ys),
    transport="delay_line",
    wire="thresh:0.1",          # τ rebinds per scenario
    steps=STEPS,
    executor="mesh+sweep",      # == SweepExecutor(sweep, inner=MeshExecutor())
    sweep=sweep,
)

print(
    f"{jax.device_count()} devices, K={K} nodes, "
    f"S={len(grid_d.ravel())} scenarios in one executable "
    f"(executor={res.metrics['executor']})\n"
)
print(f"{'D':>3} {'tau':>6} {'final loss':>12} {'uplink B':>10} "
      f"{'downlink B':>11} {'vs dense':>9}")
dense_up = res.ledger[0].uplink_bytes  # τ=0 meters every entry
traj = np.asarray(res.trajectory)
for s in range(traj.shape[0]):
    led = res.ledger[s]
    print(
        f"{int(grid_d.ravel()[s]):>3} {float(grid_tau.ravel()[s]):>6.2f} "
        f"{traj[s, -1]:>12.5f} {led.uplink_bytes:>10} "
        f"{led.downlink_bytes:>11} {led.uplink_bytes / dense_up:>8.0%}"
    )

best = int(np.argmin(traj[:, -1]))
print(
    f"\nbest scenario: D={int(grid_d.ravel()[best])} "
    f"tau={float(grid_tau.ravel()[best]):.2f} "
    f"(loss {traj[best, -1]:.5f}, "
    f"uplink {res.ledger[best].uplink_bytes / dense_up:.0%} of dense)"
)
