"""Long-context serving with sub-quadratic mixers — why xLSTM/Jamba run the
``long_500k`` shape: the decode state is O(1) in context length, so cache
memory and per-token cost stay flat while an attention KV cache grows
linearly (and its attention reads with it).

This demo serves a reduced xLSTM and a reduced sliding-window dense model
side by side, growing the context, and prints per-token decode state sizes.

  PYTHONPATH=src python examples/long_context_ssm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as tf


def state_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def decode_n(cfg, params, cache, n, key):
    decode = jax.jit(lambda p, t, c: tf.decode_step(p, cfg, t, c))
    tok = jax.random.randint(key, (1, 1), 0, cfg.vocab_size)
    for _ in range(n):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, :, : cfg.vocab_size], -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    return cache


def main():
    contexts = [256, 1024, 4096]

    print("=== xLSTM (O(1) state) vs sliding-window dense (O(window)) ===\n")
    for name, cfg in [
        ("xlstm-125m (reduced)", get_config("xlstm-125m").reduced()),
        (
            "tinyllama sw=256 (reduced)",
            get_config("tinyllama-1.1b").reduced().replace(sliding_window=256),
        ),
    ]:
        params = tf.init_params(jax.random.key(0), cfg)
        print(name)
        for ctx in contexts:
            cache = tf.init_cache(cfg, 1, ctx, jnp.float32)
            t0 = time.perf_counter()
            cache = decode_n(cfg, params, cache, 8, jax.random.key(1))
            dt = (time.perf_counter() - t0) / 8 * 1e3
            print(
                f"  ctx {ctx:6d}: decode state {state_bytes(cache)/2**20:7.2f} MiB,"
                f"  {dt:6.1f} ms/token (CPU, incl. dispatch)"
            )
        print()

    print("note: the xLSTM state is context-INDEPENDENT (matrix memory C per")
    print("head); the attention cache grows with ctx — at 524k context the")
    print("full-attention variant needs a sequence-sharded cache (see the")
    print("long_500k dry-runs) while SSM state still fits in one core's VMEM.")


if __name__ == "__main__":
    main()
