"""K-windows walkthrough (paper §4.2): the three phases on synthetic blobs,
the ℓ∞ k-means connection, and the naive distributed variant's over-merging.

  PYTHONPATH=src python examples/kwindows_clustering.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.ml import clustering, kwindows

rng = np.random.default_rng(3)
centers = np.asarray([(-5.0, -5.0), (0.0, 5.0), (5.0, -2.0)])
X = jnp.asarray(np.concatenate([rng.normal(size=(70, 2)) * 0.7 + c for c in centers]))
labels = np.repeat(np.arange(3), 70)

print("=== centralized k-windows, 9 initial windows ===")
win = kwindows.init_windows(jax.random.key(0), X, 9, r=1.3)
win = kwindows.phase1_movements(X, win)
print(f"phase 1 (movements): captured {int(jnp.sum(win.counts))} points")
win = kwindows.phase2_enlargement(X, win)
member = kwindows.window_membership(X, win)
print(f"phase 2 (enlargement): captured {int(jnp.sum(jnp.any(member, 1)))} points")
win = kwindows.phase3_merging(X, win)
alive = int(jnp.sum(win.alive))
print(f"phase 3 (merging): {alive} windows remain (3 blobs)")

assign = kwindows.assign_points(X, win)
correct = sum(
    np.bincount(labels[np.asarray(assign) == w]).max()
    for w in range(9)
    if (np.asarray(assign) == w).sum() > 0
)
captured = int((np.asarray(assign) >= 0).sum())
print(f"precision {correct/captured:.3f}, recall {captured/len(labels):.3f} "
      "(paper: high precision from window growth)\n")

print("=== ℓ∞ k-means (the paper's formal link: uniform prior ML) ===")
C0 = clustering.kmeans_pp_init(jax.random.key(1), X, 3)
for metric in ("l2", "linf", "l1"):
    res = clustering.kmeans(X, C0, num_clusters=3, metric=metric)
    print(f"  {metric:4s}: inertia {float(res.inertia):8.1f}")

print("\n=== naive distributed k-windows ([60]) on CLOSE blobs ===")
close = jnp.asarray(
    np.concatenate([rng.normal(size=(70, 2)) * 0.8 + c for c in centers / 3.2])
)
win_c = kwindows.kwindows(jax.random.key(2), close, num_windows=6, r=1.2)
win_d = kwindows.distributed_kwindows(
    jax.random.key(2), close.reshape(3, 70, 2), num_windows=6, r=1.2
)
print(f"centralized merge-by-count: {int(jnp.sum(win_c.alive))} clusters")
print(f"naive merge-on-any-overlap: {int(jnp.sum(win_d.alive))} clusters "
      "(the paper's observed over-merging)")
