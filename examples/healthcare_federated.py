"""The paper's motivating scenario: personal-healthcare clients that must
not share raw data, coordinating through a strict client-server model.

K clinics each hold private patient features.  Three §3.1 tools compose:

1. privacy-preserving regression — only second-order statistics leave a
   clinic ([6]);
2. consensus LASSO via ADMM — interpretable sparse risk model, one
   Allreduce of the coefficient vector per iteration;
3. the §5 asynchronous server — clinics contact whenever they finish,
   with contact frequency ∝ 1/dataset size.

Everything reports its communication footprint (the paper's evaluation
axis for mobile/clinical clients).

  PYTHONPATH=src python examples/healthcare_federated.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import schedules
from repro.data import make_feature_shards
from repro.ml import linear

K, DIM = 6, 12
rng = np.random.default_rng(7)
# heterogeneous clinics: different patient populations, different sizes
sizes = [30, 45, 60, 80, 120, 200]
w_true = rng.normal(size=DIM) * (rng.uniform(size=DIM) > 0.5)  # sparse risk factors
Xs_list, ys_list = [], []
for k in range(K):
    X = rng.normal(size=(sizes[k], DIM)) + 0.3 * rng.normal(size=DIM)
    y = X @ w_true + 0.1 * rng.normal(size=sizes[k])
    Xs_list.append(X)
    ys_list.append(y)

raw_bytes = sum(x.size * 8 + y.size * 8 for x, y in zip(Xs_list, ys_list))
print(f"raw data that NEVER leaves the clinics: {raw_bytes/1024:.1f} KiB\n")

# ---- 1. privacy-preserving OLS via sufficient statistics -------------------
pad = max(sizes)
Xp = jnp.asarray(np.stack([np.pad(x, ((0, pad - len(x)), (0, 0))) for x in Xs_list]))
yp = jnp.asarray(np.stack([np.pad(y, (0, pad - len(y))) for y in ys_list]))
theta_priv, ledger = linear.private_second_order(Xp, yp)
err = float(jnp.linalg.norm(theta_priv - jnp.asarray(w_true)))
print("1. second-order-statistics regression ([6])")
print(f"   ‖θ − w*‖ = {err:.4f};  wire = {ledger.total_bytes} bytes "
      f"({ledger.total_bytes/raw_bytes:.1%} of raw)\n")

# ---- 2. consensus LASSO: sparse, interpretable, distributed ----------------
res = api.fit(
    api.ProxStrategy(linear.lasso_prox_builder),
    (Xp, yp),
    transport="admm_consensus",
    steps=150,
    g="l1",
    g_lam=3.0,
)
support_true = np.abs(w_true) > 1e-9
support_found = np.abs(np.asarray(res.theta)) > 1e-2
agree = (support_true == support_found).mean()
print("2. consensus LASSO via ADMM (§3.1)")
print(f"   support recovery: {agree:.1%};  wire = {res.ledger.total_bytes} bytes\n")

# ---- 3. asynchronous central server, work-proportional contacts (§5) -------
probs = schedules.work_proportional_probs(jnp.asarray(sizes, jnp.float32))
print("3. asynchronous §5 server, contact probs ∝ 1/size:")
print("   ", np.round(np.asarray(probs), 3))
lr = 0.1

def F(k, theta):
    X, y = Xp[k], yp[k]
    n = jnp.asarray(sizes)[k]
    g = X.T @ (X @ theta - y) / n
    return theta - lr * g

sched = schedules.asynchronous(jax.random.key(1), K, 400, probs=probs)
res = api.fit(
    api.FunctionStrategy(F, num_nodes=K),
    transport="sequential_server",
    schedule=sched,
    theta0=jnp.zeros(DIM),
)
err = float(jnp.linalg.norm(res.theta - jnp.asarray(w_true)))
led = res.ledger  # push + handoff accounting comes from the engine now
print(f"   after {len(sched)} contacts: ‖θ − w*‖ = {err:.4f}; "
      f"wire = {led.total_bytes} bytes ({led.total_bytes/raw_bytes:.1%} of raw)")
