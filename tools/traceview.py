#!/usr/bin/env python
"""Summarize a Chrome trace-event file written by ``Tracer.export_chrome``.

Pure stdlib — usable on any machine (CI, a laptop reading a trace
scp'd off a worker) without jax or the repo on PYTHONPATH::

    python tools/traceview.py run.trace.json
    python tools/traceview.py run.trace.json --sort total --top 20

Prints one row per span name (count, total/mean/max duration, % of the
trace's busiest track) followed by the counter samples.  For the full
timeline, load the same file in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing`` — this tool is the terminal-sized view of it.
"""

from __future__ import annotations

import argparse
import json
import sys


def summarize(events: list) -> tuple[dict, dict]:
    """Aggregate complete ("X") events by name; collect "C" counters."""
    spans: dict = {}
    counters: dict = {}
    for e in events:
        ph = e.get("ph")
        if ph == "X":
            agg = spans.setdefault(
                e["name"], {"count": 0, "total_us": 0.0, "max_us": 0.0}
            )
            dur = float(e.get("dur", 0.0))
            agg["count"] += 1
            agg["total_us"] += dur
            agg["max_us"] = max(agg["max_us"], dur)
        elif ph == "C":
            counters[e["name"]] = e.get("args", {}).get("value")
    return spans, counters


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.3f}s"
    if us >= 1e3:
        return f"{us / 1e3:.2f}ms"
    return f"{us:.0f}us"


def render(spans: dict, counters: dict, *, sort: str, top: int) -> str:
    key = {"total": "total_us", "max": "max_us", "count": "count"}[sort]
    rows = sorted(spans.items(), key=lambda kv: -kv[1][key])[:top]
    denom = max((a["total_us"] for a in spans.values()), default=0.0)
    w = max([len(n) for n, _ in rows] + [4])
    out = [
        f"{'span':<{w}}  {'count':>6}  {'total':>10}  {'mean':>10}  "
        f"{'max':>10}  {'%':>6}"
    ]
    for name, a in rows:
        mean = a["total_us"] / a["count"]
        pct = 100.0 * a["total_us"] / denom if denom else 0.0
        out.append(
            f"{name:<{w}}  {a['count']:>6}  {_fmt_us(a['total_us']):>10}  "
            f"{_fmt_us(mean):>10}  {_fmt_us(a['max_us']):>10}  {pct:>5.1f}%"
        )
    if counters:
        out.append("")
        cw = max(len(n) for n in counters)
        for name in sorted(counters):
            out.append(f"{name:<{cw}}  {counters[name]}")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="summarize a Tracer.export_chrome trace file"
    )
    ap.add_argument("trace", help="path to the trace-event JSON")
    ap.add_argument(
        "--sort", choices=("total", "max", "count"), default="total",
        help="span ordering (default: total duration)",
    )
    ap.add_argument("--top", type=int, default=40, help="max span rows")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        payload = json.load(f)
    events = (
        payload["traceEvents"] if isinstance(payload, dict) else payload
    )
    spans, counters = summarize(events)
    if not spans and not counters:
        print("no span or counter events found", file=sys.stderr)
        return 1
    print(render(spans, counters, sort=args.sort, top=args.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
