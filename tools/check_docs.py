"""Docs checker — keep the documentation from rotting silently.

Two checks, both run by the ``docs-check`` CI job (and by
``tests/test_docs.py``, so a broken snippet fails tier-1 locally too):

1. **Snippet execution** — every fenced ```python block in ``docs/*.md``
   and ``README.md`` is executed on CPU jax, per file, in one shared
   namespace seeded with a small prelude (an 8-node least-squares
   problem, a ``strategy``, a ``key``, …) so quickstart-style snippets
   can reference conventional names without re-deriving them.  Files run
   in a subprocess with 8 fake CPU devices, so mesh/multipod demos
   exercise a real multi-shard placement.  A block preceded by an HTML
   comment ``<!-- docs-check: skip -->`` is skipped (use sparingly: for
   snippets that need hardware the CI host cannot fake, e.g. the
   512-chip production mesh).

2. **Intra-repo links** — markdown links whose target is a relative
   path, plus backticked repo paths (``docs/FOO.md``, ``src/repro/…``,
   ``examples/…``, …), must point at files that exist.

Run everything:   python tools/check_docs.py
Links only:       python tools/check_docs.py --links-only
One file:         python tools/check_docs.py docs/EXECUTORS.md
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: documentation files whose python blocks must execute
SNIPPET_FILES = ("README.md", "docs/API.md", "docs/EXECUTORS.md",
                 "docs/SERVING.md", "docs/OBSERVABILITY.md",
                 "docs/FAULTS.md")


def link_files(repo: str = REPO) -> list[str]:
    """Every markdown file at the repo root and under docs/ — discovered,
    not hand-listed, so a new doc cannot dodge the link check."""
    found = []
    for rel_dir in ("", "docs"):
        d = os.path.join(repo, rel_dir)
        if not os.path.isdir(d):
            continue
        for name in sorted(os.listdir(d)):
            if name.endswith(".md"):
                found.append(os.path.join(rel_dir, name) if rel_dir else name)
    return found

SKIP_MARK = "<!-- docs-check: skip -->"

#: names quickstart-style snippets may assume — a tiny 8-node
#: least-squares problem plus the conventional handles
PRELUDE = """\
import os
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax
import jax.numpy as jnp
import numpy as np
from repro import api
from repro.core import schedules
from repro.ml.linear import lsq_loss

_rng = np.random.default_rng(0)
Xs = jnp.asarray(_rng.normal(size=(8, 10, 5)))
_w = jnp.asarray(_rng.normal(size=(5,)))
ys = jnp.einsum("kni,i->kn", Xs, _w)
X, y = Xs, ys
Xq = jnp.asarray(_rng.normal(size=(4, 5)))
data = (Xs, ys)
strategy = api.GradientDescent(lsq_loss, lr=0.1)
key = jax.random.key(0)
K = 8
"""


def extract_blocks(text: str) -> list[tuple[int, str, bool]]:
    """Fenced ```python blocks as ``(first_line_no, source, skipped)``."""
    blocks = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        stripped = lines[i].strip()
        if stripped in ("```python", "```py"):
            skip = any(
                SKIP_MARK in lines[j]
                for j in range(max(0, i - 2), i)
            )
            start = i + 1
            j = start
            while j < len(lines) and lines[j].strip() != "```":
                j += 1
            blocks.append((start + 1, "\n".join(lines[start:j]), skip))
            i = j
        i += 1
    return blocks


def run_snippets(md_path: str) -> list[str]:
    """Execute one file's python blocks sequentially in a subprocess
    (shared namespace, 8 fake CPU devices, tmpdir cwd so snippets that
    write — e.g. a model registry — stay contained)."""
    with open(os.path.join(REPO, md_path)) as f:
        blocks = extract_blocks(f.read())
    runnable = [(ln, src) for ln, src, skip in blocks if not skip]
    if not runnable:
        return []
    parts = [PRELUDE]
    for ln, src in runnable:
        parts.append(f"print('--- {md_path}:{ln}', flush=True)")
        parts.append(src)
    program = "\n".join(parts)
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    with tempfile.TemporaryDirectory() as tmp:
        proc = subprocess.run(
            [sys.executable, "-c", program], capture_output=True, text=True,
            env=env, cwd=tmp, timeout=900,
        )
    if proc.returncode != 0:
        marker_lines = [
            ln for ln in proc.stdout.splitlines() if ln.startswith("--- ")
        ]
        where = marker_lines[-1][4:] if marker_lines else md_path
        return [f"{where}: snippet failed\n{proc.stderr.strip()[-2000:]}"]
    return []


_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")
_TICK_PATH = re.compile(
    r"`((?:docs|examples|benchmarks|tests|tools|src/repro)/[\w./-]+?"
    r"\.(?:md|py|json|yml))`"
)


def check_links(md_path: str, repo: str = REPO) -> list[str]:
    """All broken intra-repo references in one file, one error per
    occurrence, with line numbers.  ``repo`` is overridable so the unit
    test can point at a fixture tree."""
    errors = []
    full = os.path.join(repo, md_path)
    base = os.path.dirname(full)
    try:
        with open(full) as f:
            lines = f.read().splitlines()
    except OSError as e:
        return [f"{md_path}: unreadable ({e})"]
    for lineno, line in enumerate(lines, start=1):
        refs = []
        for m in _MD_LINK.finditer(line):
            target = m.group(1)
            if "://" in target or target.startswith("mailto:"):
                continue
            refs.append(target)
        refs.extend(m.group(1) for m in _TICK_PATH.finditer(line))
        for target in refs:
            # resolve relative to the doc AND to the repo root (both
            # styles appear; either resolving counts)
            if not (os.path.exists(os.path.join(base, target))
                    or os.path.exists(os.path.join(repo, target))):
                errors.append(
                    f"{md_path}:{lineno}: broken intra-repo reference "
                    f"{target!r}"
                )
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", help="markdown files (default: all)")
    ap.add_argument("--links-only", action="store_true")
    args = ap.parse_args(argv)

    errors = []
    checked = args.files or link_files()
    for md in checked:
        if os.path.exists(os.path.join(REPO, md)):
            # every file is checked even when an earlier one has errors:
            # one run reports ALL broken links across the doc set
            errors.extend(check_links(md))
    if not args.links_only:
        for md in args.files or SNIPPET_FILES:
            print(f"running snippets: {md}", flush=True)
            errors.extend(run_snippets(md))
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"docs-check: {'FAIL' if errors else 'OK'} "
          f"({len(checked)} files link-checked)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
