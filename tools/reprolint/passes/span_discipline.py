"""span-discipline — tracer spans in src/repro must be context-managed.

``Tracer.span(...)`` returns a context manager; the paired
``span_begin``/``span_end`` primitives exist only so that context
manager has something to wrap.  A raw ``span_begin`` in library code is
a leak waiting to happen: any exception (or early return) between begin
and end leaves the span open forever — it silently drops out of
``chrome_events()`` (open spans are not exportable) and its wall time
vanishes from every ``RunReport``.  The tracing layer's credibility is
its completeness, same argument as ledger-completeness.

Flagged (in ``src/repro``, except ``telemetry/trace.py`` which owns the
primitives):

* any call to ``span_begin`` / ``span_end`` — use
  ``with tracer.span(...)``;
* a ``.span(...)`` call used as a bare expression statement — the
  context manager is created and dropped, so nothing is ever timed.

Calls spelled ``re_match.span()`` (no args, not a statement) are not
flagged — the rule only fires on dropped span contexts and on the
begin/end primitives by name.
"""

from __future__ import annotations

import ast

from tools.reprolint.core import Finding

RULE = "span-discipline"

_PRIMITIVES = {"span_begin", "span_end"}
_OWNER = "telemetry/trace.py"


def _callee_name(call: ast.Call) -> str | None:
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    if isinstance(call.func, ast.Name):
        return call.func.id
    return None


def run(ctx) -> list:
    findings = []
    for sf in ctx.files:
        if sf.tree is None or not sf.rel.startswith("src/repro"):
            continue
        if sf.rel.endswith(_OWNER):
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Expr) and isinstance(
                node.value, ast.Call
            ):
                call = node.value
                if (
                    _callee_name(call) == "span"
                    and isinstance(call.func, ast.Attribute)
                ):
                    findings.append(Finding(
                        path=sf.rel, line=call.lineno,
                        col=call.col_offset + 1, rule=RULE,
                        message=(
                            ".span(...) used as a bare statement — the "
                            "context manager is dropped unentered, so the "
                            "span never closes and nothing is timed; use "
                            "`with tracer.span(...):`"
                        ),
                    ))
                    continue
            if not isinstance(node, ast.Call):
                continue
            name = _callee_name(node)
            if name in _PRIMITIVES:
                findings.append(Finding(
                    path=sf.rel, line=node.lineno,
                    col=node.col_offset + 1, rule=RULE,
                    message=(
                        f"raw {name}(...) outside telemetry/trace.py — an "
                        "exception between begin and end leaks the span "
                        "(open spans are dropped from export); use the "
                        "`with tracer.span(...):` context manager"
                    ),
                ))
    return findings
