"""tracer-hygiene — no Python control flow or host syncs on traced values.

Inside a traced region (a jit-decorated/-wrapped function, a
scan/shard_map/cond/while_loop body, a pallas kernel), branching on a
value derived from the function's arguments is either a
``TracerBoolConversionError`` at trace time or — worse — a silent
device→host sync and retrace when the value is concrete on the first
call.  The §5 transports retrace per contact if this slips into a step
body, which is exactly the class of coordination bug the surveys flag as
dominant at scale.

Flagged: ``if``/``while``/``assert`` on a tainted value,
``bool()``/``float()``/``int()``/``complex()`` casts, ``.item()`` /
``.tolist()`` / ``np.asarray(...)`` host syncs.  Taint starts at the
traced function's parameters (minus jit static args) and stops at
trace-time-static accessors (``.shape``/``.ndim``/``.dtype``, ``len``,
``isinstance``, ``x is None``), so idiomatic shape-driven Python stays
clean.
"""

from __future__ import annotations

from tools.reprolint.astutil import taint_events
from tools.reprolint.core import Finding

RULE = "tracer-hygiene"

_MESSAGES = {
    "if": (
        "Python `if {detail}` on an argument-derived value inside a "
        "{reason} — this host-syncs or raises under trace; use "
        "jax.lax.cond / jnp.where (or hoist the branch out of the traced "
        "region)"
    ),
    "while": (
        "Python `while {detail}` on an argument-derived value inside a "
        "{reason} — use jax.lax.while_loop"
    ),
    "assert": (
        "Python `assert {detail}` on an argument-derived value inside a "
        "{reason} — asserts on tracers raise at trace time; use "
        "checkify or validate outside the traced region"
    ),
    "bool-cast": (
        "{detail} applied to an argument-derived value inside a {reason} "
        "— forces a device→host sync (TracerBoolConversion hazard)"
    ),
    "host-sync": (
        "{detail} on an argument-derived value inside a {reason} — "
        "forces a device→host sync; keep the hot path on device"
    ),
}


def run(ctx) -> list:
    findings = []
    for sf in ctx.files:
        for ev in taint_events(sf):
            msg = _MESSAGES.get(ev.kind)
            if msg is None:
                continue  # "for-iter" belongs to retrace-smell
            findings.append(Finding(
                path=sf.rel,
                line=ev.node.lineno,
                col=ev.node.col_offset + 1,
                rule=RULE,
                message=msg.format(detail=ev.detail, reason=ev.reason),
            ))
    return findings
