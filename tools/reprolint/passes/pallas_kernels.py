"""pallas-kernel — TPU kernel structural checks (``kernels/*/kernel.py``).

Checks, each only where the answer is statically decidable (a block size
held in a module/local constant resolves; one computed from runtime shape
arithmetic stays silent):

* **tile alignment** — a ``BlockSpec`` block shape whose last dimension is
  neither 1 nor a multiple of the 128-lane VPU/MXU width, or whose
  second-to-last dimension is neither 1 nor a multiple of the 8-sublane
  f32 tile, forces the compiler to pad every tile (``memory_space=...``
  SMEM/scalar specs are exempt);
* **index-map arity** — each ``BlockSpec`` index map must take exactly one
  required parameter per grid dimension (extra *defaulted* params are the
  sanctioned ``lambda ..., G=G:`` closure-avoidance idiom and are fine),
  and must return one coordinate per block-shape dimension;
* **kernel-body purity** — no ``print``/``open``/``breakpoint`` and no
  ``global``/``nonlocal`` inside a kernel body: kernels run per grid step
  on device, Python side effects fire once at trace time (use
  ``pl.debug_print``);
* **no closures over enclosing arguments** — a kernel that reads a
  parameter of an enclosing function closes over what is usually a traced
  array; route arrays through ``pallas_call`` operands and statics through
  ``functools.partial`` / lambda defaults;
* **scratch memory spaces** — every ``scratch_shapes`` entry must carry an
  explicit ``pltpu.VMEM``/``pltpu.SMEM`` (or other ``pltpu.*``) space;
* **kernel arity** — the kernel body must accept exactly one ref per
  ``in_specs`` entry + one per output (``out_shape``) + one per
  ``scratch_shapes`` entry; a mismatch (e.g. a fused kernel grew an
  output but the signature didn't) fails at runtime with an opaque
  trace-time error, so surface it statically where the spec lists are
  literal.
"""

from __future__ import annotations

import ast

from tools.reprolint.astutil import (
    FUNC_NODES,
    build_imports,
    build_scopes,
    qualify,
    resolve_int,
)
from tools.reprolint.core import Finding

RULE = "pallas-kernel"

LANE = 128
SUBLANE = 8

_SIDE_EFFECT_CALLS = {"print", "open", "breakpoint", "input"}


def _parents(tree):
    out = {}
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            out[child] = parent
    return out


def _nearest_scope(node, parents, scopes, tree):
    p = parents.get(node)
    while p is not None:
        if isinstance(p, FUNC_NODES + (ast.Lambda,)) and p in scopes:
            return scopes[p]
        p = parents.get(p)
    return scopes[tree]


def _kw(call: ast.Call, name: str):
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _grid_len(call: ast.Call, scope) -> int | None:
    grid = _kw(call, "grid")
    if isinstance(grid, ast.Name) and scope is not None:
        grid = scope.lookup_const(grid.id)
    if isinstance(grid, (ast.Tuple, ast.List)):
        return len(grid.elts)
    return None


def _resolve_kernel_fn(arg, scope, imports):
    """The kernel function node handed to pallas_call, unwrapping the
    ``functools.partial(kernel, **statics)`` binding idiom.  Returns
    ``(fn, bound_pos, bound_kw)`` — the function node plus how many
    positional and which keyword parameters the partial chain bound."""
    bound_pos = 0
    bound_kw: set = set()
    for _ in range(4):  # partial-of-partial chains, defensively bounded
        if isinstance(arg, (ast.Lambda,) + FUNC_NODES):
            return arg, bound_pos, bound_kw
        if isinstance(arg, ast.Name) and scope is not None:
            fn = scope.lookup(arg.id)
            if fn is not None:
                return fn, bound_pos, bound_kw
            arg = scope.lookup_const(arg.id)
            continue
        if isinstance(arg, ast.Call):
            q = qualify(arg.func, imports)
            if q in ("functools.partial", "partial") and arg.args:
                bound_pos += len(arg.args) - 1
                bound_kw |= {k.arg for k in arg.keywords if k.arg}
                arg = arg.args[0]
                continue
        return None, bound_pos, bound_kw
    return None, bound_pos, bound_kw


def _count_entries(node, scope):
    """Number of entries in a specs/shapes argument: a literal
    tuple/list counts exactly; a bare BlockSpec/ShapeDtypeStruct call is
    one entry; anything unresolvable (conditionally-built lists, runtime
    values) → None, and the arity check stays silent."""
    if isinstance(node, ast.Name) and scope is not None:
        node = scope.lookup_const(node.id)
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    if isinstance(node, ast.Call):
        return 1
    return None


def _check_kernel_arity(sf, call, fn, bound_pos, bound_kw, scope, findings):
    a = fn.args
    if a.vararg is not None:
        return  # *refs soaks up anything — nothing to check
    n_in = _count_entries(_kw(call, "in_specs"), scope)
    out_shape = _kw(call, "out_shape")
    n_out = _count_entries(out_shape, scope) if out_shape is not None else None
    scratch = _kw(call, "scratch_shapes")
    n_scratch = 0 if scratch is None else _count_entries(scratch, scope)
    if n_in is None or n_out is None or n_scratch is None:
        return
    expected = n_in + n_out + n_scratch
    params = [p.arg for p in a.posonlyargs + a.args]
    defaulted = set(params[len(params) - len(a.defaults):]) if a.defaults else set()
    remaining = [p for p in params[bound_pos:] if p not in bound_kw]
    required = [p for p in remaining if p not in defaulted]
    if len(required) <= expected <= len(remaining):
        return
    name = getattr(fn, "name", "<lambda>")
    findings.append(Finding(
        path=sf.rel, line=call.lineno, col=call.col_offset + 1,
        rule=RULE,
        message=(
            f"kernel {name}() takes {len(remaining)} ref parameter(s) "
            f"but this pallas_call supplies {expected} "
            f"({n_in} in_specs + {n_out} output(s) + {n_scratch} "
            "scratch) — one ref per operand, output, and scratch entry, "
            "in that order"
        ),
    ))


def _local_bindings(fn) -> set:
    """Every name bound anywhere inside ``fn`` (params, assignments,
    loop targets, nested defs, comprehension targets)."""
    names = set()
    a = fn.args
    for p in a.posonlyargs + a.args + a.kwonlyargs:
        names.add(p.arg)
    for p in (a.vararg, a.kwarg):
        if p is not None:
            names.add(p.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(
            node.ctx, (ast.Store, ast.Del)
        ):
            names.add(node.id)
        elif isinstance(node, FUNC_NODES):
            names.add(node.name)
            if node is not fn:
                sub = node.args
                for p in sub.posonlyargs + sub.args + sub.kwonlyargs:
                    names.add(p.arg)
        elif isinstance(node, ast.Lambda):
            for p in node.args.posonlyargs + node.args.args:
                names.add(p.arg)
    return names


def _check_kernel_body(sf, fn, parents, findings):
    # side effects
    for node in ast.walk(fn):
        if isinstance(node, (ast.Global, ast.Nonlocal)):
            findings.append(Finding(
                path=sf.rel, line=node.lineno, col=node.col_offset + 1,
                rule=RULE,
                message=(
                    "global/nonlocal inside a pallas kernel body — kernels "
                    "must be pure; carry state in VMEM/SMEM scratch refs"
                ),
            ))
        elif (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id in _SIDE_EFFECT_CALLS
        ):
            findings.append(Finding(
                path=sf.rel, line=node.lineno, col=node.col_offset + 1,
                rule=RULE,
                message=(
                    f"Python {node.func.id}() inside a pallas kernel body "
                    "— fires once at trace time, not per grid step; use "
                    "pl.debug_print for on-device values"
                ),
            ))

    # closures over enclosing-function parameters (likely traced arrays)
    if not isinstance(fn, FUNC_NODES):
        return
    enclosing_params = {}
    p = parents.get(fn)
    while p is not None:
        if isinstance(p, FUNC_NODES):
            a = p.args
            for prm in a.posonlyargs + a.args + a.kwonlyargs:
                enclosing_params.setdefault(prm.arg, p.name)
        p = parents.get(p)
    if not enclosing_params:
        return
    local = _local_bindings(fn)
    reported = set()
    for node in ast.walk(fn):
        if (
            isinstance(node, ast.Name)
            and isinstance(node.ctx, ast.Load)
            and node.id in enclosing_params
            and node.id not in local
            and node.id not in reported
        ):
            reported.add(node.id)
            findings.append(Finding(
                path=sf.rel, line=node.lineno, col=node.col_offset + 1,
                rule=RULE,
                message=(
                    f"kernel closes over {node.id!r}, a parameter of "
                    f"enclosing {enclosing_params[node.id]}() — closed-over "
                    "arrays are baked in as constants at trace time; pass "
                    "arrays as pallas_call operands and statics via "
                    "functools.partial or a lambda default"
                ),
            ))


def _check_blockspec(sf, spec: ast.Call, scope, grid_len, findings):
    if _kw(spec, "memory_space") is not None:
        return  # SMEM/scalar specs follow different tiling rules
    shape = spec.args[0] if spec.args else _kw(spec, "block_shape")
    rank = None
    if isinstance(shape, (ast.Tuple, ast.List)):
        rank = len(shape.elts)
        dims = [resolve_int(e, scope) for e in shape.elts]
        checks = [
            (-1, LANE, "last"),
            (-2, SUBLANE, "second-to-last"),
        ]
        for idx, unit, label in checks:
            if rank + idx < 0:
                continue
            v = dims[idx]
            if v is None or v == 1 or v % unit == 0:
                continue
            findings.append(Finding(
                path=sf.rel, line=shape.lineno, col=shape.col_offset + 1,
                rule=RULE,
                message=(
                    f"BlockSpec {label} dimension {v} is neither 1 nor a "
                    f"multiple of {unit} — TPU tiles are (8, 128); "
                    "misaligned blocks are padded on every grid step"
                ),
            ))
    imap = spec.args[1] if len(spec.args) > 1 else _kw(spec, "index_map")
    if isinstance(imap, ast.Lambda):
        required = (
            len(imap.args.posonlyargs) + len(imap.args.args)
            - len(imap.args.defaults)
        )
        if grid_len is not None and required != grid_len:
            findings.append(Finding(
                path=sf.rel, line=imap.lineno, col=imap.col_offset + 1,
                rule=RULE,
                message=(
                    f"index_map takes {required} required parameter(s) but "
                    f"the grid has {grid_len} dimension(s) — one grid index "
                    "per dimension (defaulted extras like `G=G` are fine)"
                ),
            ))
        if isinstance(imap.body, ast.Tuple) and rank is not None:
            if len(imap.body.elts) != rank:
                findings.append(Finding(
                    path=sf.rel, line=imap.lineno, col=imap.col_offset + 1,
                    rule=RULE,
                    message=(
                        f"index_map returns {len(imap.body.elts)} "
                        f"coordinate(s) for a rank-{rank} block shape — "
                        "must return one block coordinate per dimension"
                    ),
                ))


def _check_scratch(sf, call: ast.Call, imports, findings):
    scratch = _kw(call, "scratch_shapes")
    if not isinstance(scratch, (ast.Tuple, ast.List)):
        return
    for entry in scratch.elts:
        q = qualify(entry.func, imports) if isinstance(entry, ast.Call) else None
        if q is not None and (
            q.startswith("jax.experimental.pallas.tpu.")
            or q.startswith("jax.experimental.pallas.")
        ):
            continue
        findings.append(Finding(
            path=sf.rel, line=entry.lineno, col=entry.col_offset + 1,
            rule=RULE,
            message=(
                "scratch_shapes entry without an explicit memory space — "
                "use pltpu.VMEM((...), dtype) / pltpu.SMEM(...) so the "
                "working set is pinned where the kernel expects it"
            ),
        ))


def run(ctx) -> list:
    findings = []
    for sf in ctx.files:
        if sf.tree is None or "pallas_call" not in sf.text:
            continue
        imports = build_imports(sf.tree)
        scopes = build_scopes(sf.tree)
        parents = _parents(sf.tree)
        checked_fns = set()
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            q = qualify(node.func, imports) or ""
            if not q.endswith("pallas.pallas_call"):
                continue
            scope = _nearest_scope(node, parents, scopes, sf.tree)
            grid_len = _grid_len(node, scope)
            for sub in ast.walk(node):
                if (
                    isinstance(sub, ast.Call)
                    and (qualify(sub.func, imports) or "").endswith(
                        ".BlockSpec"
                    )
                ):
                    _check_blockspec(sf, sub, scope, grid_len, findings)
            _check_scratch(sf, node, imports, findings)
            if node.args:
                fn, bound_pos, bound_kw = _resolve_kernel_fn(
                    node.args[0], scope, imports
                )
                if fn is not None:
                    _check_kernel_arity(
                        sf, node, fn, bound_pos, bound_kw, scope, findings
                    )
                    if id(fn) not in checked_fns:
                        checked_fns.add(id(fn))
                        _check_kernel_body(sf, fn, parents, findings)
    return findings
