"""compat-matrix — docs/EXECUTORS.md can never silently lie again.

The Transport × Executor compatibility matrix used to be hand-maintained
prose.  This pass derives the REAL matrix from the code and diffs it
against the documented table:

* transport families come from ``api/transport.py``: a transport whose
  ``run`` calls ``executor.run_server`` is server-family, one that calls
  ``executor.run_update`` is update-family, and one that guards
  ``isinstance(executor, <Class>)`` before raising is local-only
  (supported exactly on that class and its subclasses);
* executor capabilities come from ``api/executor.py``: an executor
  supports a family iff its (inherited) ``run_server``/``run_update``
  implementation is not a bare ``raise``;
* spec strings map through each executor class's ``name`` attribute and
  the ``EXECUTORS``/``COMPOSED_EXECUTORS`` tuples (a composed
  ``"<inner>+sweep"`` spec behaves as the outer sweep wrapper, exactly
  as ``make_executor`` builds it).

Any cell where the table and the derivation disagree — or a missing/extra
row or column — is a finding anchored at the doc table.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

from tools.reprolint.core import Finding

RULE = "compat-matrix"

_CHECK, _CROSS = "✓", "✗"


# -- code side ----------------------------------------------------------------


def _parse(path: Path) -> ast.Module | None:
    try:
        return ast.parse(path.read_text(), filename=str(path))
    except (OSError, SyntaxError):
        return None


def _calls_attr_on(fn: ast.FunctionDef, obj: str, attr: str) -> bool:
    for node in ast.walk(fn):
        if (
            isinstance(node, ast.Attribute)
            and node.attr == attr
            and isinstance(node.value, ast.Name)
            and node.value.id == obj
        ):
            return True
    return False


def _isinstance_guard(fn: ast.FunctionDef, obj: str) -> str | None:
    """Class name in an ``isinstance(<obj>, Cls)`` test inside ``fn``
    (the local-only rejection idiom), if present."""
    for node in ast.walk(fn):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "isinstance"
            and len(node.args) == 2
            and isinstance(node.args[0], ast.Name)
            and node.args[0].id == obj
            and isinstance(node.args[1], ast.Name)
        ):
            return node.args[1].id
    return None


def _module_tuple(tree: ast.Module, name: str) -> list:
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name) and tgt.id == name:
                if isinstance(node.value, (ast.Tuple, ast.List)):
                    return [
                        e.value for e in node.value.elts
                        if isinstance(e, ast.Constant)
                    ]
    return []


class _Classes:
    """Class table of one module: bases, string attrs, method defs."""

    def __init__(self, tree: ast.Module):
        self.info = {}
        for node in tree.body:
            if not isinstance(node, ast.ClassDef):
                continue
            bases = [b.id for b in node.bases if isinstance(b, ast.Name)]
            attrs, methods = {}, {}
            for stmt in node.body:
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                    t = stmt.targets[0]
                    if isinstance(t, ast.Name) and isinstance(
                        stmt.value, ast.Constant
                    ):
                        attrs[t.id] = stmt.value.value
                elif isinstance(stmt, ast.FunctionDef):
                    methods[stmt.name] = stmt
            self.info[node.name] = {
                "bases": bases, "attrs": attrs, "methods": methods,
            }

    def resolve_method(self, cls: str, name: str):
        while cls in self.info:
            m = self.info[cls]["methods"].get(name)
            if m is not None:
                return m
            bases = self.info[cls]["bases"]
            cls = bases[0] if bases else ""
        return None

    def is_subclass(self, cls: str, ancestor: str) -> bool:
        while cls in self.info:
            if cls == ancestor:
                return True
            bases = self.info[cls]["bases"]
            cls = bases[0] if bases else ""
        return cls == ancestor

    def by_name_attr(self, value: str) -> str | None:
        for cls, info in self.info.items():
            if info["attrs"].get("name") == value:
                return cls
        return None


def _raising_only(fn: ast.FunctionDef | None) -> bool:
    if fn is None:
        return True
    body = fn.body
    if (
        body
        and isinstance(body[0], ast.Expr)
        and isinstance(body[0].value, ast.Constant)
        and isinstance(body[0].value.value, str)
    ):
        body = body[1:]  # docstring
    return bool(body) and all(isinstance(s, ast.Raise) for s in body)


def derive_matrix(transport_py: Path, executor_py: Path):
    """``(matrix, executor_specs, errors)`` where matrix maps
    ``transport_spec -> {executor_spec: bool}`` as the code enforces it."""
    errors: list = []
    ttree, etree = _parse(transport_py), _parse(executor_py)
    if ttree is None or etree is None:
        return None, [], ["api transport/executor module failed to parse"]
    tclasses, eclasses = _Classes(ttree), _Classes(etree)

    # transport spec -> class, from make_transport's dispatch
    spec_to_tclass = {}
    mk = None
    for node in ttree.body:
        if isinstance(node, ast.FunctionDef) and node.name == "make_transport":
            mk = node
    if mk is not None:
        for node in ast.walk(mk):
            if not isinstance(node, ast.If):
                continue
            test = node.test
            if not (
                isinstance(test, ast.Compare)
                and isinstance(test.left, ast.Name)
                and test.left.id == "spec"
                and len(test.comparators) == 1
                and isinstance(test.comparators[0], ast.Constant)
            ):
                continue
            spec = test.comparators[0].value
            for sub in ast.walk(node):
                if (
                    isinstance(sub, ast.Return)
                    and isinstance(sub.value, ast.Call)
                    and isinstance(sub.value.func, ast.Name)
                ):
                    spec_to_tclass[spec] = sub.value.func.id
                    break
    if not spec_to_tclass:
        errors.append(
            f"{transport_py}: could not derive the transport spec table "
            "from make_transport"
        )

    # executor spec -> class (composed specs behave as the sweep wrapper,
    # exactly as make_executor builds them)
    executor_specs = list(_module_tuple(etree, "EXECUTORS"))
    composed = list(_module_tuple(etree, "COMPOSED_EXECUTORS"))
    executor_specs += composed

    def spec_to_eclass(spec: str) -> str | None:
        if "+" in spec:
            return eclasses.by_name_attr(spec.split("+")[-1])
        return eclasses.by_name_attr(spec)

    def executor_supports(spec: str, family: str) -> bool:
        cls = spec_to_eclass(spec)
        if cls is None:
            return False
        impl = eclasses.resolve_method(cls, f"run_{family}")
        return not _raising_only(impl)

    matrix = {}
    for tspec, tcls in spec_to_tclass.items():
        run = tclasses.resolve_method(tcls, "run")
        if run is None:
            errors.append(f"transport class {tcls} has no run method")
            continue
        guard = _isinstance_guard(run, "executor")
        row = {}
        for espec in executor_specs:
            if guard is not None:
                cls = spec_to_eclass(espec)
                row[espec] = cls is not None and eclasses.is_subclass(
                    cls, guard
                )
            elif _calls_attr_on(run, "executor", "run_server"):
                row[espec] = executor_supports(espec, "server")
            elif _calls_attr_on(run, "executor", "run_update"):
                row[espec] = executor_supports(espec, "update")
            else:
                errors.append(
                    f"transport class {tcls}: run() neither dispatches to "
                    "executor.run_server/run_update nor guards the "
                    "executor type — the compat matrix cannot be derived"
                )
                row = None
                break
        if row is not None:
            matrix[tspec] = row
    return matrix, executor_specs, errors


# -- docs side ----------------------------------------------------------------


def parse_doc_matrix(doc_path: Path):
    """``(rows, line_of_row, errors)``: rows maps transport name ->
    {executor spec -> True/False/None}."""
    text = doc_path.read_text()
    lines = text.splitlines()
    header_idx = None
    for i, line in enumerate(lines):
        cells = [c.strip() for c in line.strip().strip("|").split("|")]
        if cells and cells[0].strip("`* ") == "transport":
            header_idx = i
            columns = [
                [s.strip().strip("`") for s in c.split(",")]
                for c in cells[1:]
            ]
            break
    if header_idx is None:
        return None, {}, [
            f"{doc_path.name}: no 'transport' compatibility table found"
        ]
    rows, row_lines, errors = {}, {}, []
    for i in range(header_idx + 2, len(lines)):  # skip the |---| rule
        line = lines[i].strip()
        if not line.startswith("|"):
            break
        cells = [c.strip() for c in line.strip("|").split("|")]
        if not cells:
            continue
        name = re.sub(r"[`*]", "", cells[0]).strip()
        row = {}
        for specs, cell in zip(columns, cells[1:]):
            if _CHECK in cell:
                val = True
            elif _CROSS in cell:
                val = False
            else:
                val = None
                errors.append(
                    f"row {name!r}: cell {cell!r} has neither "
                    f"{_CHECK} nor {_CROSS}"
                )
            for spec in specs:
                row[spec] = val
        rows[name] = row
        row_lines[name] = i + 1
    return rows, row_lines, errors


# -- the pass -----------------------------------------------------------------


def run(ctx) -> list:
    doc = ctx.executors_doc
    if doc is None or ctx.repo is None:
        return []
    transport_py = ctx.repo / "src" / "repro" / "api" / "transport.py"
    executor_py = ctx.repo / "src" / "repro" / "api" / "executor.py"
    if not (doc.exists() and transport_py.exists() and executor_py.exists()):
        return []
    try:
        doc_rel = doc.relative_to(ctx.repo).as_posix()
    except ValueError:
        doc_rel = doc.as_posix()

    findings = []

    def report(line, msg):
        findings.append(
            Finding(path=doc_rel, line=line, col=1, rule=RULE, message=msg)
        )

    code, executor_specs, errors = derive_matrix(transport_py, executor_py)
    for e in errors:
        report(1, e)
    if code is None:
        return findings
    docm, row_lines, doc_errors = parse_doc_matrix(doc)
    if docm is None:
        for e in doc_errors:
            report(1, e)
        return findings
    for e in doc_errors:
        report(1, e)

    for tspec in code:
        if tspec not in docm:
            report(1, (
                f"transport {tspec!r} exists in api/transport.py but has "
                "no row in the compatibility matrix"
            ))
    for tname in docm:
        if tname not in code:
            report(row_lines[tname], (
                f"matrix row {tname!r} has no such transport in "
                "api/transport.py (make_transport)"
            ))
    doc_cols = set().union(*(set(r) for r in docm.values())) if docm else set()
    for espec in executor_specs:
        if espec not in doc_cols:
            report(1, (
                f"executor {espec!r} is declared in api/executor.py but "
                "missing from the compatibility matrix columns"
            ))
    for espec in doc_cols:
        if espec not in executor_specs:
            report(1, (
                f"matrix column {espec!r} names no executor declared in "
                "api/executor.py (EXECUTORS/COMPOSED_EXECUTORS)"
            ))

    for tspec, row in code.items():
        if tspec not in docm:
            continue
        for espec, expected in row.items():
            documented = docm[tspec].get(espec)
            if documented is None or documented == expected:
                continue
            word = {True: "supported", False: "rejected"}
            report(row_lines[tspec], (
                f"matrix drift: {tspec!r} × {espec!r} is documented "
                f"{_CHECK if documented else _CROSS} but the code says "
                f"{word[expected]} (derived from the run_server/run_update/"
                "isinstance rejection paths in api/transport.py + "
                "api/executor.py)"
            ))
    return findings
