"""retrace-smell — things that make jit recompile (or crash) on contact.

Retraces are the silent tax of §5-style protocols: a transport that
retraces per contact turns an O(1) compile into O(T).  Statically
catchable smells:

* **non-hashable defaults on a jitted function** — list/dict/set defaults
  break jit's cache key the moment the parameter is marked static, and
  mutable defaults are a latent aliasing bug regardless;
* **static/donate argnum drift** — ``static_argnums``/``donate_argnums``
  pointing past the parameter list, or ``static_argnames``/
  ``donate_argnames`` naming a parameter that no longer exists: the
  classic signature-change leftover, which either raises at first call or
  silently stops marking the argument it used to;
* **Python iteration over traced data** — a ``for`` loop (or
  comprehension) over an argument-derived value inside a traced region
  unrolls the loop into the graph at best and host-syncs at worst; use
  ``lax.scan``/``lax.fori_loop``.
"""

from __future__ import annotations

import ast

from tools.reprolint.astutil import find_traced, taint_events
from tools.reprolint.core import Finding

RULE = "retrace-smell"

_MUTABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                     ast.SetComp)


def _positional_params(fn) -> list:
    a = fn.args
    return [p.arg for p in (a.posonlyargs + a.args)]


def _all_params(fn) -> set:
    a = fn.args
    names = {p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)}
    for p in (a.vararg, a.kwarg):
        if p is not None:
            names.add(p.arg)
    return names


def _int_elems(node):
    vals = [node] if isinstance(node, ast.Constant) else list(
        getattr(node, "elts", [])
    )
    return [
        v.value for v in vals
        if isinstance(v, ast.Constant) and isinstance(v.value, int)
    ]


def _str_elems(node):
    vals = [node] if isinstance(node, ast.Constant) else list(
        getattr(node, "elts", [])
    )
    return [
        v.value for v in vals
        if isinstance(v, ast.Constant) and isinstance(v.value, str)
    ]


def _check_jit_call(sf, fn, call: ast.Call, findings):
    pos = _positional_params(fn)
    names = _all_params(fn)
    for kw in call.keywords:
        if kw.arg in ("static_argnums", "donate_argnums"):
            for v in _int_elems(kw.value):
                if not (0 <= v < len(pos)):
                    findings.append(Finding(
                        path=sf.rel, line=kw.value.lineno,
                        col=kw.value.col_offset + 1, rule=RULE,
                        message=(
                            f"{kw.arg}={v} but the jitted function has "
                            f"only {len(pos)} positional parameter(s) "
                            f"({', '.join(pos) or 'none'}) — stale index "
                            "after a signature change"
                        ),
                    ))
        elif kw.arg in ("static_argnames", "donate_argnames"):
            for v in _str_elems(kw.value):
                if v not in names:
                    findings.append(Finding(
                        path=sf.rel, line=kw.value.lineno,
                        col=kw.value.col_offset + 1, rule=RULE,
                        message=(
                            f"{kw.arg} names {v!r}, which is not a "
                            "parameter of the jitted function — stale "
                            "name after a signature change"
                        ),
                    ))


def run(ctx) -> list:
    findings = []
    for sf in ctx.files:
        if sf.tree is None:
            continue
        traced = find_traced(sf)
        for fn, use in traced.items():
            if "jit" not in use.reason:
                continue
            if isinstance(fn, ast.Lambda):
                defaults = fn.args.defaults
            else:
                defaults = fn.args.defaults + [
                    d for d in fn.args.kw_defaults if d is not None
                ]
            for d in defaults:
                if isinstance(d, _MUTABLE_LITERALS):
                    findings.append(Finding(
                        path=sf.rel, line=d.lineno, col=d.col_offset + 1,
                        rule=RULE,
                        message=(
                            "mutable (non-hashable) default on a jitted "
                            "function — breaks the jit cache key if the "
                            "parameter is ever marked static, and aliases "
                            "across calls regardless; default to None and "
                            "materialize inside"
                        ),
                    ))
            if isinstance(use.jit_call, ast.Call):
                _check_jit_call(sf, fn, use.jit_call, findings)
        for ev in taint_events(sf):
            if ev.kind != "for-iter":
                continue
            findings.append(Finding(
                path=sf.rel, line=ev.node.lineno, col=ev.node.col_offset + 1,
                rule=RULE,
                message=(
                    f"Python iteration over `{ev.detail}` (argument-"
                    f"derived) inside a {ev.reason} — unrolls into the "
                    "traced graph and retraces when the length changes; "
                    "use jax.lax.scan / fori_loop"
                ),
            ))
    return findings
