"""ledger-completeness — every byte the wire emits reaches the accounting.

The cost model (§4) is only as honest as its plumbing: ``Wire.encode_push``
and ``Wire.encode_updates`` return ``(wstate, payload, nbytes)`` and that
third element must flow into the run's uplink accounting (``sum_bytes`` /
``from_owner`` / the RawRun uplink column / a ``CommLedger.record_*``).
A transport that drops it still *trains* correctly — the comm/accuracy
trade-off plots just silently under-report, which is the worst failure
mode a measurement repo can have.

Flagged:

* an ``encode_push``/``encode_updates`` call whose result is discarded
  outright (bare expression statement);
* a 3-way unpack of such a call whose byte element is bound to ``_`` or
  to a name never read afterwards in the enclosing function;
* a ``wire.measure(...)``/``wire.push_bytes(...)`` byte measurement used
  as a bare statement (measured, then dropped);
* a ``CommLedger()`` constructed and never touched again — dead ledgers
  usually mean a refactor disconnected the recording path.
"""

from __future__ import annotations

import ast

from tools.reprolint.astutil import FUNC_NODES
from tools.reprolint.core import Finding

RULE = "ledger-completeness"

_ENCODERS = {"encode_push", "encode_updates"}
_MEASURERS = {"measure", "push_bytes"}


def _parents(tree):
    out = {}
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            out[child] = parent
    return out


def _enclosing_fn(node, parents):
    p = parents.get(node)
    while p is not None:
        if isinstance(p, FUNC_NODES + (ast.Lambda,)):
            return p
        p = parents.get(p)
    return None


def _loads(scope_node, name: str) -> int:
    n = 0
    for node in ast.walk(scope_node):
        if (
            isinstance(node, ast.Name)
            and node.id == name
            and isinstance(node.ctx, ast.Load)
        ):
            n += 1
    return n


def _method_name(call: ast.Call) -> str | None:
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    return None


def run(ctx) -> list:
    findings = []
    for sf in ctx.files:
        if sf.tree is None:
            continue
        parents = _parents(sf.tree)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            meth = _method_name(node)

            # constructor check: CommLedger() bound and never used again
            if (
                isinstance(node.func, ast.Name)
                and node.func.id == "CommLedger"
            ):
                parent = parents.get(node)
                if (
                    isinstance(parent, ast.Assign)
                    and len(parent.targets) == 1
                    and isinstance(parent.targets[0], ast.Name)
                ):
                    name = parent.targets[0].id
                    fn = _enclosing_fn(node, parents) or sf.tree
                    if _loads(fn, name) == 0:
                        findings.append(Finding(
                            path=sf.rel, line=node.lineno,
                            col=node.col_offset + 1, rule=RULE,
                            message=(
                                f"CommLedger bound to {name!r} but never "
                                "read — nothing records into it, so the "
                                "comm accounting it was meant to carry is "
                                "silently lost"
                            ),
                        ))
                continue

            if meth in _ENCODERS or meth in _MEASURERS:
                parent = parents.get(node)
                if isinstance(parent, ast.Expr):
                    what = (
                        "wire payload and its byte count"
                        if meth in _ENCODERS else "byte measurement"
                    )
                    findings.append(Finding(
                        path=sf.rel, line=node.lineno,
                        col=node.col_offset + 1, rule=RULE,
                        message=(
                            f".{meth}(...) result discarded — the {what} "
                            "must flow into uplink/downlink accounting "
                            "(sum_bytes / RawRun columns / "
                            "CommLedger.record_*)"
                        ),
                    ))
                    continue

            if meth not in _ENCODERS:
                continue
            # 3-way unpack: (wstate, payload, nbytes) — audit the nbytes slot
            parent = parents.get(node)
            if not (
                isinstance(parent, ast.Assign)
                and len(parent.targets) == 1
                and isinstance(parent.targets[0], (ast.Tuple, ast.List))
                and len(parent.targets[0].elts) == 3
            ):
                continue
            byte_tgt = parent.targets[0].elts[2]
            if not isinstance(byte_tgt, ast.Name):
                continue
            fn = _enclosing_fn(node, parents) or sf.tree
            if byte_tgt.id == "_" or _loads(fn, byte_tgt.id) == 0:
                findings.append(Finding(
                    path=sf.rel, line=byte_tgt.lineno,
                    col=byte_tgt.col_offset + 1, rule=RULE,
                    message=(
                        f"byte count from .{meth}(...) bound to "
                        f"{byte_tgt.id!r} and never read — wire bytes that "
                        "skip the accounting under-report every "
                        "comm/accuracy trade-off downstream"
                    ),
                ))
    return findings
