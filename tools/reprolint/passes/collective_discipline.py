"""collective-discipline — raw collectives stay behind the executor layer.

Two invariants from ``docs/EXECUTORS.md``:

1. Raw ``jax.lax`` collectives (``psum``/``pmean``/``ppermute``/
   ``all_gather``/…) are only legal inside the executor layer itself —
   ``api/executor.py`` (the primitive set) and ``core/allreduce.py`` /
   ``core/topology.py`` (the staged reductions it is built on).  A
   transport, strategy or serving path that calls one directly bypasses
   topology staging AND the ``CommLedger`` accounting; it must go through
   the executor primitive set (``aggregate`` / ``broadcast`` /
   ``metric_mean`` / ``sum_bytes`` / ``from_owner`` / …).

2. Any collective whose axis-name argument is a string literal must name
   an axis some ``Mesh``/``Topology`` in the linted tree declares — a
   typo'd axis name is a runtime ``NameError`` deep inside shard_map,
   found only on the placement that exercises that code path.
"""

from __future__ import annotations

import ast

from tools.reprolint.astutil import build_imports, qualify
from tools.reprolint.core import Finding

RULE = "collective-discipline"

COLLECTIVES = {
    "psum", "pmean", "pmax", "pmin", "ppermute", "pshuffle",
    "all_gather", "all_to_all", "psum_scatter", "pbroadcast",
}

#: files allowed to speak raw collectives (repo-relative posix suffixes)
ALLOWED_FILES = (
    "src/repro/api/executor.py",
    "src/repro/core/allreduce.py",
    "src/repro/core/topology.py",
)

#: calls that declare mesh/topology axis names, with the argument that
#: carries them (position, keyword)
_AXIS_DECLS = {
    "make_mesh": (1, "axis_names"),
    "Mesh": (1, "axis_names"),
    "AbstractMesh": (1, "axis_names"),
    "Hop": (0, "axes"),
    "flat": (0, None),  # Topology.flat(axes)
}


def _literal_strs(node) -> list | None:
    """String literal / tuple-list of string literals -> names, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant)
                    and isinstance(elt.value, str)):
                return None
            out.append(elt.value)
        return out
    return None


def declared_axes(ctx) -> set:
    """Axis names declared by any Mesh/Topology construction in the
    linted tree (cached on the context)."""
    if "declared_axes" in ctx.cache:
        return ctx.cache["declared_axes"]
    axes: set = set()
    for sf in ctx.files:
        if sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = None
            if isinstance(node.func, ast.Attribute):
                name = node.func.attr
            elif isinstance(node.func, ast.Name):
                name = node.func.id
            if name not in _AXIS_DECLS:
                continue
            pos, kw = _AXIS_DECLS[name]
            arg = None
            if kw is not None:
                for k in node.keywords:
                    if k.arg == kw:
                        arg = k.value
            if arg is None and pos < len(node.args):
                arg = node.args[pos]
            names = _literal_strs(arg) if arg is not None else None
            if names:
                axes.update(names)
    ctx.cache["declared_axes"] = axes
    return axes


def _axis_arg(call: ast.Call):
    """The axis-name argument of a collective call (2nd positional, or the
    ``axis_name`` keyword)."""
    for k in call.keywords:
        if k.arg == "axis_name":
            return k.value
    if len(call.args) >= 2:
        return call.args[1]
    return None


def run(ctx) -> list:
    findings = []
    axes = declared_axes(ctx)
    for sf in ctx.files:
        if sf.tree is None:
            continue
        imports = build_imports(sf.tree)
        allowed = any(sf.rel.endswith(suffix) for suffix in ALLOWED_FILES)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            q = qualify(node.func, imports) or ""
            parts = q.split(".")
            if parts[-1] not in COLLECTIVES:
                continue
            # a raw collective is a jax.lax.* call (or a name imported
            # from jax.lax); same-named repo wrappers (mesh_allreduce)
            # resolve to their own modules and are not raw
            if not q.startswith("jax.lax."):
                continue
            name = parts[-1]
            if not allowed:
                findings.append(Finding(
                    path=sf.rel, line=node.lineno, col=node.col_offset + 1,
                    rule=RULE,
                    message=(
                        f"raw collective jax.lax.{name} outside the "
                        "executor layer — transports/strategies must use "
                        "the repro.api.executor primitive set (aggregate/"
                        "broadcast/metric_mean/sum_bytes/from_owner/...), "
                        "which stages through the ambient Topology and "
                        "keeps CommLedger accounting complete"
                    ),
                ))
            axis_names = _literal_strs(_axis_arg(node))
            if axis_names and axes:
                for a in axis_names:
                    if a not in axes:
                        findings.append(Finding(
                            path=sf.rel, line=node.lineno,
                            col=node.col_offset + 1, rule=RULE,
                            message=(
                                f"collective jax.lax.{name} over axis "
                                f"{a!r}, which no Mesh/Topology in the "
                                "linted tree declares (declared: "
                                f"{sorted(axes)})"
                            ),
                        ))
    return findings
