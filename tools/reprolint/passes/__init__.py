"""Pass registry.  Each pass module exposes ``RULE`` and ``run(ctx)``."""

from tools.reprolint.passes import (
    collective_discipline,
    compat_matrix,
    ledger_completeness,
    pallas_kernels,
    retrace_smells,
    span_discipline,
    tracer_hygiene,
)

_MODULES = (
    tracer_hygiene,
    collective_discipline,
    compat_matrix,
    pallas_kernels,
    ledger_completeness,
    retrace_smells,
    span_discipline,
)

ALL_PASSES = {m.RULE: m.run for m in _MODULES}
ALL_RULES = tuple(ALL_PASSES)
