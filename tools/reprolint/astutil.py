"""Shared AST machinery: import resolution, scope/def lookup, traced-region
discovery, and the tracer-taint walk the hygiene passes are built on.

Everything here is a *static approximation*.  The guiding rule is
asymmetric cost: a missed hazard is cheap (the next contributor's retrace
is caught in review), a false positive is expensive (it trains people to
sprinkle suppressions) — so where the analysis cannot decide, it stays
silent.  Taint starts at the parameters of a traced function and flows
through assignments; it is *dropped* through the accessors that are
static at trace time (``.shape``/``.ndim``/``.dtype``, ``len()``,
``isinstance``, ``x is None``), which is what keeps idiomatic jax code
clean without suppressions.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field


# -- imports ------------------------------------------------------------------


def build_imports(tree: ast.Module) -> dict:
    """Local name -> dotted module path it refers to."""
    imports = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    imports[a.asname] = a.name
                else:
                    head = a.name.split(".")[0]
                    imports[head] = head
        elif isinstance(node, ast.ImportFrom):
            mod = ("." * node.level) + (node.module or "")
            for a in node.names:
                local = a.asname or a.name
                imports[local] = f"{mod}.{a.name}" if mod else a.name
    return imports


def dotted_name(node) -> str | None:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def qualify(node, imports: dict) -> str | None:
    """Fully-qualified dotted name of an expression, resolving the leading
    segment through the module's imports (``lax.psum`` -> ``jax.lax.psum``,
    ``pl.pallas_call`` -> ``jax.experimental.pallas.pallas_call``)."""
    d = dotted_name(node)
    if d is None:
        return None
    head, _, rest = d.partition(".")
    base = imports.get(head, head)
    return f"{base}.{rest}" if rest else base


# -- scopes -------------------------------------------------------------------

FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def _shallow_stmts(body):
    """Statements of a scope, descending into control flow but NOT into
    nested function/class bodies."""
    stack = list(body)
    while stack:
        stmt = stack.pop(0)
        yield stmt
        if isinstance(stmt, FUNC_NODES + (ast.ClassDef, ast.Lambda)):
            continue
        for fld in ("body", "orelse", "finalbody"):
            stack.extend(getattr(stmt, fld, []) or [])
        for handler in getattr(stmt, "handlers", []) or []:
            stack.extend(handler.body)
        for item in getattr(stmt, "cases", []) or []:  # match statements
            stack.extend(item.body)


@dataclass
class Scope:
    node: object  # Module or function node
    parent: "Scope | None"
    defs: dict = field(default_factory=dict)  # name -> FunctionDef/Lambda
    consts: dict = field(default_factory=dict)  # name -> ast constant expr

    def lookup(self, name: str):
        s = self
        while s is not None:
            if name in s.defs:
                return s.defs[name]
            s = s.parent
        return None

    def lookup_const(self, name: str):
        s = self
        while s is not None:
            if name in s.consts:
                return s.consts[name]
            s = s.parent
        return None


def build_scopes(tree: ast.Module) -> dict:
    """Map every function node (and the module) to its ``Scope``."""
    scopes = {}

    def visit(node, parent: Scope | None):
        scope = Scope(node=node, parent=parent)
        scopes[node] = scope
        body = node.body if not isinstance(node, ast.Lambda) else []
        for stmt in _shallow_stmts(body):
            if isinstance(stmt, FUNC_NODES):
                scope.defs[stmt.name] = stmt
            elif isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                tgt = stmt.targets[0]
                if isinstance(tgt, ast.Name):
                    if isinstance(stmt.value, ast.Lambda):
                        scope.defs[tgt.id] = stmt.value
                    else:
                        scope.consts[tgt.id] = stmt.value
        # recurse into nested functions (wherever they appear)
        for child in ast.walk(node):
            if child is node:
                continue
            if isinstance(child, FUNC_NODES + (ast.Lambda,)):
                if _owner(child, node, scopes):
                    visit(child, scope)
        return scope

    def _owner(child, node, scopes):
        # only recurse from the nearest enclosing function: walk from the
        # module finds every nested fn, so guard against revisiting
        return child not in scopes and _nearest_func(child, tree) is node

    # precompute parent links once
    parents = {}
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            parents[child] = parent

    def _nearest_func(node, root):
        p = parents.get(node)
        while p is not None:
            if isinstance(p, FUNC_NODES + (ast.Lambda,)):
                return p
            p = parents.get(p)
        return root

    visit(tree, None)
    return scopes


# -- traced-region discovery --------------------------------------------------

#: trace-entry callables -> positions of the traced function arguments
#: (negative tuple entry means "a list of callables at this position")
TRACING_CALLS = {
    "jax.jit": (0,),
    "jax.pjit": (0,),
    "jax.vmap": (0,),
    "jax.pmap": (0,),
    "jax.grad": (0,),
    "jax.value_and_grad": (0,),
    "jax.jacfwd": (0,),
    "jax.jacrev": (0,),
    "jax.hessian": (0,),
    "jax.checkpoint": (0,),
    "jax.remat": (0,),
    "jax.lax.scan": (0,),
    "jax.lax.map": (0,),
    "jax.lax.associative_scan": (0,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,),
    "jax.lax.cond": (1, 2),
    "jax.lax.switch": (1,),
    "jax.experimental.shard_map.shard_map": (0,),
    "jax.experimental.pallas.pallas_call": (0,),
}

#: decorators that make the decorated def a traced region
TRACING_DECORATORS = {
    "jax.jit", "jax.pjit", "jax.vmap", "jax.pmap", "jax.checkpoint",
    "jax.remat", "jax.grad", "jax.value_and_grad",
}

_JIT_NAMES = {"jax.jit", "jax.pjit"}


@dataclass
class TracedUse:
    node: object  # the function node
    reason: str  # "jit-decorated function", "scan body", ...
    static_names: set = field(default_factory=set)
    #: the jit()/partial(jit) call carrying static_argnums etc, if any
    jit_call: object = None


def _param_names(fn) -> list:
    a = fn.args
    return [p.arg for p in (a.posonlyargs + a.args)]


def _defaulted_names(fn) -> set:
    """Parameters carrying a default value.  In a traced body these are
    the ``lambda ..., G=G:`` / ``def body(c, x, seg=seg):`` closure-
    avoidance idiom — scan/cond/jit call the body with the declared
    positional signature only, so a defaulted param holds its concrete
    Python default, not a tracer."""
    a = fn.args
    pos = _param_names(fn)
    names = set(pos[len(pos) - len(a.defaults):]) if a.defaults else set()
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None:
            names.add(p.arg)
    return names


def _static_names_from_call(call: ast.Call, fn) -> set:
    """Resolve static_argnums/static_argnames on a jit(...) call against
    the traced function's positional parameters."""
    names = set()
    params = _param_names(fn) if fn is not None else []
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            vals = (
                [kw.value] if isinstance(kw.value, ast.Constant)
                else list(getattr(kw.value, "elts", []))
            )
            names.update(
                v.value for v in vals
                if isinstance(v, ast.Constant) and isinstance(v.value, str)
            )
        elif kw.arg == "static_argnums":
            vals = (
                [kw.value] if isinstance(kw.value, ast.Constant)
                else list(getattr(kw.value, "elts", []))
            )
            for v in vals:
                if isinstance(v, ast.Constant) and isinstance(v.value, int):
                    if 0 <= v.value < len(params):
                        names.add(params[v.value])
    return names


_REASONS = {
    "jax.lax.scan": "scan body",
    "jax.lax.map": "lax.map body",
    "jax.lax.associative_scan": "associative_scan body",
    "jax.lax.while_loop": "while_loop function",
    "jax.lax.fori_loop": "fori_loop body",
    "jax.lax.cond": "cond branch",
    "jax.lax.switch": "switch branch",
    "jax.experimental.shard_map.shard_map": "shard_map body",
    "jax.experimental.pallas.pallas_call": "pallas kernel",
}


def find_traced(sf) -> dict:
    """Map function node -> ``TracedUse`` for every function the file
    syntactically hands to the tracer (jit decoration, jit()/vmap() call
    wrapping, scan/shard_map/cond/... body position).  Cached per file."""
    if "traced" in sf.cache:
        return sf.cache["traced"]
    tree = sf.tree
    traced: dict = {}
    if tree is None:
        sf.cache["traced"] = traced
        return traced
    imports = build_imports(tree)
    scopes = build_scopes(tree)

    def mark(fn, reason, static=(), jit_call=None):
        if fn is None or not isinstance(fn, FUNC_NODES + (ast.Lambda,)):
            return
        if fn in traced:
            traced[fn].static_names.update(static)
            return
        traced[fn] = TracedUse(
            node=fn, reason=reason, static_names=set(static),
            jit_call=jit_call,
        )

    # enclosing-scope map for Name -> def resolution at each call site
    parents = {}
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            parents[child] = parent

    def nearest_scope(node):
        p = parents.get(node)
        while p is not None:
            if isinstance(p, FUNC_NODES + (ast.Lambda,)) and p in scopes:
                return scopes[p]
            p = parents.get(p)
        return scopes[tree]

    call_scope = {
        node: nearest_scope(node)
        for node in ast.walk(tree) if isinstance(node, ast.Call)
    }

    def resolve(arg, scope):
        if isinstance(arg, ast.Lambda):
            return arg
        if isinstance(arg, ast.Name) and scope is not None:
            return scope.lookup(arg.id)
        return None

    # decorators
    for fnode in scopes:
        if not isinstance(fnode, FUNC_NODES):
            continue
        for dec in fnode.decorator_list:
            q = qualify(dec, imports)
            if q in TRACING_DECORATORS:
                mark(fnode, "jit-decorated function"
                     if q in _JIT_NAMES else f"@{q.split('.')[-1]} function")
            elif isinstance(dec, ast.Call):
                qf = qualify(dec.func, imports)
                if qf in ("functools.partial", "partial") and dec.args:
                    inner = qualify(dec.args[0], imports)
                    if inner in TRACING_DECORATORS:
                        static = (
                            _static_names_from_call(dec, fnode)
                            if inner in _JIT_NAMES else set()
                        )
                        mark(fnode, "jit-decorated function"
                             if inner in _JIT_NAMES
                             else f"@{inner.split('.')[-1]} function",
                             static=static, jit_call=dec)
                elif qf in TRACING_DECORATORS:
                    static = (
                        _static_names_from_call(dec, fnode)
                        if qf in _JIT_NAMES else set()
                    )
                    mark(fnode, "jit-decorated function"
                         if qf in _JIT_NAMES
                         else f"@{qf.split('.')[-1]} function",
                         static=static, jit_call=dec)

    # call sites
    for call, scope in call_scope.items():
        q = qualify(call.func, imports)
        if q not in TRACING_CALLS:
            continue
        reason = _REASONS.get(q, "traced function")
        for pos in TRACING_CALLS[q]:
            if pos >= len(call.args):
                continue
            arg = call.args[pos]
            cands = (
                list(getattr(arg, "elts", []))
                if isinstance(arg, (ast.List, ast.Tuple)) else [arg]
            )
            for cand in cands:
                fn = resolve(cand, scope)
                if fn is None:
                    continue
                if q in _JIT_NAMES:
                    mark(fn, "jit-wrapped function",
                         static=_static_names_from_call(call, fn),
                         jit_call=call)
                else:
                    mark(fn, reason)
    sf.cache["traced"] = traced
    return traced


# -- taint analysis -----------------------------------------------------------

#: attribute reads that are static at trace time — accessing them on a
#: tracer yields plain Python, so taint stops here
STATIC_ATTRS = {
    "shape", "ndim", "dtype", "size", "itemsize", "nbytes", "aval",
    "sharding", "weak_type", "names",
}

#: calls whose result is static / host-side regardless of argument taint
SAFE_CALLS = {
    "len", "isinstance", "issubclass", "type", "callable", "hasattr",
    "id", "repr", "str", "format",
}

#: host-synchronizing conversions — flagged when applied to a tracer
BOOL_CASTS = {"bool", "float", "int", "complex"}
HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
HOST_SYNC_CALLS = {
    "numpy.asarray", "numpy.array", "numpy.float32", "numpy.float64",
}


@dataclass(frozen=True)
class TaintEvent:
    kind: str  # "if" | "while" | "assert" | "bool-cast" | "host-sync" | "for-iter"
    node: object
    reason: str  # which traced region this was found in
    detail: str = ""


def _is_none_check(node: ast.Compare) -> bool:
    if not all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
        return False
    operands = [node.left, *node.comparators]
    return any(
        isinstance(o, ast.Constant) and o.value is None for o in operands
    )


class _TaintWalker(ast.NodeVisitor):
    def __init__(self, imports, reason, tainted, events, analyzed):
        self.imports = imports
        self.reason = reason
        self.tainted = set(tainted)
        self.events = events
        self.analyzed = analyzed

    # -- expression taint ----------------------------------------------------

    def taints(self, node) -> bool:
        if node is None or isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            return self.taints(node.value)
        if isinstance(node, ast.Compare):
            if _is_none_check(node):
                return False
            return any(self.taints(c) for c in [node.left, *node.comparators])
        if isinstance(node, ast.Call):
            q = qualify(node.func, self.imports)
            name = (q or "").split(".")[-1]
            if q in SAFE_CALLS or name in SAFE_CALLS:
                return False
            parts = [node.args, [kw.value for kw in node.keywords]]
            if isinstance(node.func, ast.Attribute):
                parts.append([node.func.value])
            return any(self.taints(a) for group in parts for a in group)
        if isinstance(node, ast.Lambda):
            return False
        return any(self.taints(c) for c in ast.iter_child_nodes(node)
                   if isinstance(c, ast.expr))

    # -- events --------------------------------------------------------------

    def _event(self, kind, node, detail=""):
        self.events.append(
            TaintEvent(kind=kind, node=node, reason=self.reason, detail=detail)
        )

    def _bind(self, target, tainted: bool):
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, tainted)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, tainted)

    # -- statements ----------------------------------------------------------

    def visit_Assign(self, node):
        self.visit(node.value)
        t = self.taints(node.value)
        for tgt in node.targets:
            self._bind(tgt, t)

    def visit_AnnAssign(self, node):
        if node.value is not None:
            self.visit(node.value)
            self._bind(node.target, self.taints(node.value))

    def visit_AugAssign(self, node):
        self.visit(node.value)
        if self.taints(node.value):
            self._bind(node.target, True)

    def visit_NamedExpr(self, node):
        self.visit(node.value)
        self._bind(node.target, self.taints(node.value))

    def visit_If(self, node):
        if self.taints(node.test):
            self._event("if", node, ast.unparse(node.test))
        self.visit(node.test)
        for stmt in node.body + node.orelse:
            self.visit(stmt)

    def visit_While(self, node):
        if self.taints(node.test):
            self._event("while", node, ast.unparse(node.test))
        self.visit(node.test)
        for stmt in node.body + node.orelse:
            self.visit(stmt)

    def visit_Assert(self, node):
        if self.taints(node.test):
            self._event("assert", node, ast.unparse(node.test))
        self.generic_visit(node)

    def visit_For(self, node):
        if self.taints(node.iter):
            self._event("for-iter", node, ast.unparse(node.iter))
        self._bind(node.target, self.taints(node.iter))
        self.visit(node.iter)
        for stmt in node.body + node.orelse:
            self.visit(stmt)

    def _check_comprehension(self, node):
        for gen in node.generators:
            if self.taints(gen.iter):
                self._event("for-iter", node, ast.unparse(gen.iter))
        self.generic_visit(node)

    visit_ListComp = _check_comprehension
    visit_SetComp = _check_comprehension
    visit_DictComp = _check_comprehension
    visit_GeneratorExp = _check_comprehension

    def visit_Call(self, node):
        q = qualify(node.func, self.imports) or ""
        name = q.split(".")[-1]
        if name in BOOL_CASTS and q == name and node.args:
            if self.taints(node.args[0]):
                self._event("bool-cast", node, f"{name}()")
        elif q in HOST_SYNC_CALLS and node.args:
            if self.taints(node.args[0]):
                self._event("host-sync", node, q)
        elif (
            isinstance(node.func, ast.Attribute)
            and node.func.attr in HOST_SYNC_METHODS
            and self.taints(node.func.value)
        ):
            self._event("host-sync", node, f".{node.func.attr}()")
        self.generic_visit(node)

    def visit_FunctionDef(self, node):
        # nested defs trace as part of the enclosing region: closures see
        # the enclosing taint, their params carry whatever flows in
        if node in self.analyzed:
            return
        self.analyzed.add(node)
        inner = _TaintWalker(
            self.imports, self.reason,
            self.tainted | (set(_param_names(node)) - _defaulted_names(node)),
            self.events, self.analyzed,
        )
        for stmt in node.body:
            inner.visit(stmt)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        inner = _TaintWalker(
            self.imports, self.reason,
            self.tainted
            | ({a.arg for a in node.args.args} - _defaulted_names(node)),
            self.events, self.analyzed,
        )
        inner.visit(node.body)


def taint_events(sf) -> list:
    """All tracer-taint events across the file's traced regions (cached)."""
    if "taint_events" in sf.cache:
        return sf.cache["taint_events"]
    events: list = []
    if sf.tree is None:
        sf.cache["taint_events"] = events
        return events
    imports = build_imports(sf.tree)
    traced = find_traced(sf)
    analyzed: set = set()
    for fn, use in traced.items():
        if fn in analyzed:
            continue
        analyzed.add(fn)
        params = (
            {a.arg for a in fn.args.args}
            if isinstance(fn, ast.Lambda) else set(_param_names(fn))
        )
        tainted = (
            params - use.static_names - _defaulted_names(fn)
            - {"self", "cls"}
        )
        walker = _TaintWalker(imports, use.reason, tainted, events, analyzed)
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            walker.visit(stmt)
    sf.cache["taint_events"] = events
    return events


# -- constant resolution (pallas pass) ----------------------------------------


def resolve_int(node, scope: Scope | None):
    """Best-effort static int value of an expression: literals, module/
    local constants, and arithmetic over those.  None when undecidable."""
    if isinstance(node, ast.Constant):
        return node.value if isinstance(node.value, int) else None
    if isinstance(node, ast.Name) and scope is not None:
        bound = scope.lookup_const(node.id)
        if bound is not None and bound is not node:
            return resolve_int(bound, scope)
        return None
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = resolve_int(node.operand, scope)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        left = resolve_int(node.left, scope)
        right = resolve_int(node.right, scope)
        if left is None or right is None:
            return None
        try:
            if isinstance(node.op, ast.Add):
                return left + right
            if isinstance(node.op, ast.Sub):
                return left - right
            if isinstance(node.op, ast.Mult):
                return left * right
            if isinstance(node.op, ast.FloorDiv):
                return left // right
            if isinstance(node.op, ast.Mod):
                return left % right
        except (ZeroDivisionError, ValueError):
            return None
    return None
