"""Lint driver: file loading, suppressions, pass orchestration.

A *pass* is a module exposing ``RULE`` (kebab-case id) and
``run(ctx) -> list[Finding]``.  The driver parses every target file once,
hands the shared ``LintContext`` to each pass, then filters findings
through per-line suppression comments::

    x = float(loss)  # reprolint: disable=tracer-hygiene -- host logging path

The justification after ``--`` is REQUIRED: a bare ``# reprolint:
disable=<rule>`` still suppresses the target finding but emits a
``bare-suppression`` finding in its place, so CI stays red until the
suppression says why.
"""

from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from pathlib import Path


@dataclass(frozen=True, order=True)
class Finding:
    path: str  # repo-relative (posix) when a repo root is known
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def as_dict(self) -> dict:
        return {
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "rule": self.rule,
            "message": self.message,
        }


@dataclass
class SourceFile:
    path: Path  # absolute
    rel: str  # repo-relative posix path (or the path as given)
    text: str
    tree: ast.Module | None  # None when the file failed to parse
    parse_error: str | None = None
    #: per-file scratch space for cross-pass shared analyses
    cache: dict = field(default_factory=dict)

    @property
    def lines(self) -> list[str]:
        if "lines" not in self.cache:
            self.cache["lines"] = self.text.splitlines()
        return self.cache["lines"]


@dataclass
class LintContext:
    files: list
    repo: Path | None  # repo root (dir containing src/repro), if detected
    #: path to the executors doc the compat-matrix pass cross-checks;
    #: overridable so tests can point at a mutated fixture copy
    executors_doc: Path | None
    cache: dict = field(default_factory=dict)

    def file(self, rel_suffix: str) -> SourceFile | None:
        """The loaded file whose repo-relative path ends with ``rel_suffix``."""
        for sf in self.files:
            if sf.rel.endswith(rel_suffix):
                return sf
        return None


def find_repo_root(start: Path) -> Path | None:
    """Walk up from ``start`` to the directory holding ``src/repro``."""
    p = start.resolve()
    if p.is_file():
        p = p.parent
    for cand in (p, *p.parents):
        if (cand / "src" / "repro").is_dir() or (cand / ".git").is_dir():
            return cand
    return None


def _iter_py(target: Path):
    if target.is_file():
        if target.suffix == ".py":
            yield target
        return
    for root, dirs, names in os.walk(target):
        dirs[:] = sorted(
            d for d in dirs
            if not d.startswith(".") and d != "__pycache__"
        )
        for name in sorted(names):
            if name.endswith(".py"):
                yield Path(root) / name


def load_files(paths, repo: Path | None) -> list[SourceFile]:
    out = []
    seen = set()
    for p in paths:
        for f in _iter_py(Path(p)):
            f = f.resolve()
            if f in seen:
                continue
            seen.add(f)
            text = f.read_text()
            if repo is not None and f.is_relative_to(repo):
                rel = f.relative_to(repo).as_posix()
            else:
                rel = f.as_posix()
            try:
                tree = ast.parse(text, filename=str(f))
                err = None
            except SyntaxError as e:
                tree, err = None, f"{e.msg} (line {e.lineno})"
            out.append(SourceFile(path=f, rel=rel, text=text, tree=tree,
                                  parse_error=err))
    return out


# -- suppressions -------------------------------------------------------------

_DISABLE = re.compile(
    r"#\s*reprolint:\s*disable=([\w+,-]+)\s*(?:--\s*(\S.*))?$"
)


def _suppressions(sf: SourceFile) -> dict:
    """line -> (set of rules disabled there, justified: bool, col)."""
    if "suppressions" in sf.cache:
        return sf.cache["suppressions"]
    sup = {}
    for i, line in enumerate(sf.lines, start=1):
        m = _DISABLE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        justified = bool(m.group(2))
        sup[i] = (rules, justified, m.start() + 1)
    sf.cache["suppressions"] = sup
    return sup


def _suppressed(sf: SourceFile, finding: Finding) -> bool:
    """A finding is suppressed by a disable comment on its own line, or on
    an immediately preceding comment-only line."""
    sup = _suppressions(sf)
    for ln in (finding.line, finding.line - 1):
        entry = sup.get(ln)
        if entry is None:
            continue
        rules, _justified, _col = entry
        if ln == finding.line - 1:
            # only comment-only lines suppress the statement below them
            if sf.lines[ln - 1].lstrip()[:1] != "#":
                continue
        if finding.rule in rules or "all" in rules:
            return True
    return False


def apply_suppressions(files, findings) -> list[Finding]:
    by_rel = {sf.rel: sf for sf in files}
    kept = []
    for f in findings:
        sf = by_rel.get(f.path)
        if sf is not None and _suppressed(sf, f):
            continue
        kept.append(f)
    # a suppression without a justification is itself a finding — the
    # disable still applies (above), but CI stays red until it says why
    for sf in files:
        for ln, (rules, justified, col) in sorted(_suppressions(sf).items()):
            if not justified:
                kept.append(Finding(
                    path=sf.rel, line=ln, col=col, rule="bare-suppression",
                    message=(
                        "suppression without a justification — write "
                        f"'# reprolint: disable={','.join(sorted(rules))} "
                        "-- <why this is a false positive>'"
                    ),
                ))
    return sorted(set(kept))


# -- driver -------------------------------------------------------------------


def run_lint(
    paths,
    *,
    rules=None,
    repo: Path | None = None,
    executors_doc: Path | None = None,
) -> list[Finding]:
    """Run the (selected) passes over ``paths`` and return live findings.

    ``repo`` defaults to auto-detection from the first target (walking up
    to the directory containing ``src/repro``); repo-level passes
    (compat-matrix) are skipped when no repo root or doc is found, so
    fixture trees exercise only the rules they stage.
    """
    from tools.reprolint.passes import ALL_PASSES

    paths = [Path(p) for p in paths]
    if not paths:
        raise ValueError("no lint targets given")
    repo = Path(repo) if repo is not None else find_repo_root(paths[0])
    if executors_doc is not None:
        executors_doc = Path(executors_doc)
    if executors_doc is None and repo is not None:
        cand = repo / "docs" / "EXECUTORS.md"
        executors_doc = cand if cand.exists() else None
    files = load_files(paths, repo)
    ctx = LintContext(files=files, repo=repo, executors_doc=executors_doc)

    findings = []
    for sf in files:
        if sf.parse_error is not None:
            findings.append(Finding(
                path=sf.rel, line=1, col=1, rule="parse-error",
                message=f"file does not parse: {sf.parse_error}",
            ))
    selected = dict(ALL_PASSES)
    if rules is not None:
        unknown = set(rules) - set(selected)
        if unknown:
            raise ValueError(
                f"unknown rules {sorted(unknown)} — available: "
                f"{sorted(selected)}"
            )
        selected = {k: v for k, v in selected.items() if k in rules}
    for _rule, run in selected.items():
        findings.extend(run(ctx))
    return apply_suppressions(files, findings)
