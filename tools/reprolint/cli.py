"""Command line for repro-lint.

::

    python -m tools.reprolint src/                 # human-readable
    python -m tools.reprolint src/ --format=json   # machine-readable (CI)
    python -m tools.reprolint src/ --rules tracer-hygiene,compat-matrix
    python -m tools.reprolint --list-rules

Exit status: 0 clean, 1 findings, 2 usage error.  Pure stdlib — the
linter never imports jax, so it runs anywhere (CI lint jobs need no
accelerator runtime).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from tools.reprolint.core import run_lint


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description=(
            "JAX/Pallas-aware static analysis for the repro executor-layer "
            "invariants"
        ),
    )
    p.add_argument(
        "paths", nargs="*",
        help="files or directories to lint (e.g. src/)",
    )
    p.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="output format (default: text)",
    )
    p.add_argument(
        "--rules", default=None, metavar="RULE[,RULE...]",
        help="comma-separated subset of rules to run (default: all)",
    )
    p.add_argument(
        "--repo", default=None, metavar="DIR",
        help="repo root (default: auto-detect by walking up to src/repro)",
    )
    p.add_argument(
        "--executors-doc", default=None, metavar="FILE",
        help=(
            "executors doc for the compat-matrix pass (default: "
            "<repo>/docs/EXECUTORS.md)"
        ),
    )
    p.add_argument(
        "--list-rules", action="store_true",
        help="print the available rules and exit",
    )
    return p


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    if args.list_rules:
        from tools.reprolint.passes import _MODULES

        for mod in _MODULES:
            first = (mod.__doc__ or "").strip().splitlines()[0]
            print(f"{mod.RULE:24s} {first}")
        return 0
    if not args.paths:
        print("error: no lint targets given (try: src/)", file=sys.stderr)
        return 2
    rules = None
    if args.rules is not None:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
    try:
        findings = run_lint(
            args.paths,
            rules=rules,
            repo=Path(args.repo) if args.repo else None,
            executors_doc=(
                Path(args.executors_doc) if args.executors_doc else None
            ),
        )
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.format == "json":
        print(json.dumps(
            {
                "findings": [f.as_dict() for f in findings],
                "count": len(findings),
            },
            indent=2,
        ))
    else:
        for f in findings:
            print(f.render())
        n = len(findings)
        print(f"reprolint: {n} finding{'s' if n != 1 else ''}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
