"""repro-lint — JAX/Pallas-aware static analysis for the executor-layer
invariants.

The PR 2–5 architecture rests on invariants that used to be prose:
transports speak the executor primitive set (never raw collectives),
collective axis names match declared mesh axes, the Transport × Executor
compatibility matrix in ``docs/EXECUTORS.md`` matches the rejection code,
Pallas kernel bodies stay pure and lane-aligned, every byte that moves is
metered into a ``CommLedger``, and nothing inside a jit/scan/shard_map
body branches on a tracer.  This package makes them machine-checked.

Pure stdlib (``ast`` only — no jax import), so the lint job needs no
accelerator runtime.  See ``docs/LINTING.md`` for the rule catalog.

    python -m tools.reprolint src/ --format=text
    python -m tools.reprolint src/repro/api/executor.py --rules tracer-hygiene
"""

from tools.reprolint.core import Finding, LintContext, run_lint  # noqa: F401
from tools.reprolint.passes import ALL_RULES  # noqa: F401

__all__ = ["Finding", "LintContext", "run_lint", "ALL_RULES"]
