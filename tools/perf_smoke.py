"""Perf smoke — catch executor-layer performance regressions in CI.

Runs a small fixed GD workload under the local, mesh, and sweep
executors plus the compressed wire, and compares against the checked-in
``benchmarks/perf_baselines.json``.  Any metric worse than
``slack × baseline`` (default 2×) fails the run.

The primary metrics are RATIOS (mesh/local, per-scenario-sweep/local,
topk/dense, cold/warm amortization, bucketed/continuous LM serving),
which are machine-speed invariant — a slower CI runner shifts numerator
and denominator together.  The absolute local wall time is checked too,
with the same slack, as a backstop against global slowdowns the ratios
cannot see.

Two metrics are held to FIXED bounds instead of the baseline×slack rule:

* ``traced_over_untraced`` — a warm mesh fit with a live
  ``telemetry.trace.Tracer`` vs the same fit untraced — must stay ≤
  1.05× (``TRACED_BOUND``).  That is the tracing layer's overhead
  contract (docs/OBSERVABILITY.md): host-side spans around
  whole-program dispatch may not tax the hot path, traced or not.
* ``faulted_over_clean`` — a warm mesh fit under a full
  ``FaultPlan`` (dropout + straggler + quorum) vs the fault-free warm
  fit — must stay ≤ 1.1× (``FAULTED_BOUND``).  The fault layer's
  masks-are-jit-arguments contract (docs/FAULTS.md): per-round
  participation is data, so faults cost a comparison + select, never a
  retrace.  The same pass asserts the program cache compiled exactly
  ONE executable across two different-seed plans.

Usage:
  PYTHONPATH=src python tools/perf_smoke.py            # check
  PYTHONPATH=src python tools/perf_smoke.py --update   # rewrite baselines
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

BASELINES = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "benchmarks", "perf_baselines.json",
)

SLACK = 2.0
# tiny-LM serving comparison (continuous vs bucketed, mixed lengths)
LM_REQUESTS, LM_PROMPT, LM_GEN_MAX, LM_SLOTS = 12, 8, 16, 4
#: hard ceiling on tracer-on / tracer-off warm-fit wall time — the
#: tracing layer's "zero overhead" contract, checked absolutely (no
#: baseline, no slack)
TRACED_BOUND = 1.05
#: hard ceiling on faulted / fault-free warm mesh fit wall time — the
#: fault layer's masks-are-jit-arguments contract (no retraces, mask
#: math is a comparison + select on the hot path)
FAULTED_BOUND = 1.1
K, NK, N = 8, 64, 256
STEPS = 100
LRS = (0.02, 0.05, 0.1, 0.2)


def _measure() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.api import executor as _exec
    from repro.ml.linear import lsq_loss

    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(K, NK, N)))
    w = jnp.asarray(rng.normal(size=(N,)))
    y = jnp.einsum("kni,i->kn", X, w)
    data = (X, y)

    def timed(fn, repeats=3):
        _exec.clear_program_cache()
        t0 = time.perf_counter()
        jax.block_until_ready(fn().theta)
        cold = time.perf_counter() - t0
        warm = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn().theta)
            warm = min(warm, time.perf_counter() - t0)
        return cold, warm

    def fit(**kw):
        return api.fit(
            api.GradientDescent(lsq_loss, lr=0.05), data,
            transport="allreduce", steps=STEPS, **kw,
        )

    _, local = timed(lambda: fit())
    cold_mesh, mesh = timed(lambda: fit(executor="mesh"))
    _, local_topk = timed(lambda: fit(wire="topk:0.1+ef"))
    _, sweep = timed(
        lambda: fit(executor=api.SweepExecutor({"lr": jnp.asarray(LRS)}))
    )

    # tracing overhead contract: the SAME warm mesh executable (the
    # program cache key ignores the tracer), tracer off vs on, best of 5
    # each so scheduler noise doesn't dominate a µs-scale difference
    from repro.telemetry.trace import Tracer

    def warm_best(fn, repeats=5):
        jax.block_until_ready(fn().theta)  # warm the program cache
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn().theta)
            best = min(best, time.perf_counter() - t0)
        return best

    untraced = warm_best(lambda: fit(executor="mesh"))
    traced = warm_best(lambda: fit(executor="mesh", tracer=Tracer()))

    # fault overhead contract: a full fault plan on the warm mesh path
    # (masks ride as jit arguments — comparison + select, no retrace),
    # measured against the fault-free warm fit above; two different-seed
    # plans must share ONE compiled program
    from repro.api.faults import FaultPlan

    def fplan(seed):
        return FaultPlan(seed=seed, dropout_p=0.3, straggler=1, quorum=4)

    _exec.clear_program_cache()
    faulted = warm_best(lambda: fit(executor="mesh", faults=fplan(1)))
    jax.block_until_ready(fit(executor="mesh", faults=fplan(2)).theta)
    fault_programs = _exec.program_cache_stats()["size"]

    return {
        "local_warm_s": local,
        "mesh_over_local": mesh / local,
        "sweep_scenario_over_local": (sweep / len(LRS)) / local,
        "topk_over_dense": local_topk / local,
        "mesh_cold_over_warm": cold_mesh / mesh,
        "traced_over_untraced": traced / untraced,
        "faulted_over_clean": faulted / untraced,
        "fault_programs_across_seeds": fault_programs,
        "bucketed_over_continuous_tokens_per_s": _measure_lm_serving(),
    }


def _measure_lm_serving() -> float:
    """Useful-tokens/s ratio of the fixed-bucket LM baseline over the
    continuous-batching engine on a saturated mixed-length trace — the
    serving plane's machine-invariant contract (continuous must not
    regress below the bucketed path; the whole point of the slot
    scheduler is this ratio staying < 1)."""
    import jax
    import numpy as np

    from repro.api.strategy import OptimizerStrategy
    from repro.launch.serve import lm_predict_fn
    from repro.models import transformer as tf
    from repro.models.config import ModelConfig
    from repro.serve import ContinuousLMEngine, MicroBatcher, ServeEngine

    cfg = ModelConfig(
        name="smoke-lm", vocab_size=256, d_model=32, num_layers=2,
        num_heads=4, num_kv_heads=2, head_dim=8, d_ff=128,
        compute_dtype="float32", param_dtype="float32",
    )
    params = tf.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(3)
    prompts = rng.integers(
        0, cfg.vocab_size, size=(LM_REQUESTS, LM_PROMPT)
    ).astype(np.int32)
    max_new = rng.integers(2, LM_GEN_MAX + 1, size=LM_REQUESTS)
    useful = int(max_new.sum())

    # bucketed baseline: every request in a bucket decodes LM_GEN_MAX
    strategy = OptimizerStrategy(
        None, None, predict_fn=lm_predict_fn(cfg, gen=LM_GEN_MAX)
    )
    b_engine = ServeEngine(strategy, params)
    batcher = MicroBatcher(b_engine, max_batch=LM_SLOTS)
    for p in prompts[:LM_SLOTS]:  # compile outside the clock
        batcher.submit(p)
    batcher.flush()
    t0 = time.perf_counter()
    tickets = [batcher.submit(p) for p in prompts]
    batcher.flush()
    for t in tickets:
        t.result()
    bucketed = useful / (time.perf_counter() - t0)

    # continuous: slots retire early and refill from the backlog
    c_engine = ContinuousLMEngine(
        cfg, params, n_slots=LM_SLOTS, page_size=8,
        max_seq=LM_PROMPT + LM_GEN_MAX,
    )
    c_engine.submit(prompts[0], max_new=2).result()  # compile
    t0 = time.perf_counter()
    tickets = [
        c_engine.submit(p, max_new=int(m)) for p, m in zip(prompts, max_new)
    ]
    c_engine.run_until_idle()
    for t in tickets:
        t.result()
    continuous = useful / (time.perf_counter() - t0)
    return bucketed / continuous


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baselines file from this machine")
    ap.add_argument("--slack", type=float, default=SLACK)
    args = ap.parse_args()

    measured = _measure()
    print("measured:")
    for k, v in measured.items():
        print(f"  {k}: {v:.4f}")

    # fixed-bound contracts, not baseline ratios: tracing must stay
    # free, and faults must cost masks (not retraces) on the warm path
    traced_ratio = measured.pop("traced_over_untraced")
    faulted_ratio = measured.pop("faulted_over_clean")
    fault_programs = measured.pop("fault_programs_across_seeds")

    if args.update:
        with open(BASELINES, "w") as f:
            json.dump(
                {"workload": {"K": K, "Nk": NK, "n": N, "steps": STEPS},
                 "slack": args.slack, "metrics": measured},
                f, indent=2,
            )
            f.write("\n")
        print(f"wrote {BASELINES}")
        return 0

    with open(BASELINES) as f:
        base = json.load(f)["metrics"]

    failures = []
    for key, ref in base.items():
        got = measured.get(key)
        if got is None:
            failures.append(f"{key}: missing from measurement")
        elif got > args.slack * ref:
            failures.append(
                f"{key}: {got:.4f} > {args.slack:.1f}x baseline {ref:.4f}"
            )
    if traced_ratio > TRACED_BOUND:
        failures.append(
            f"traced_over_untraced: {traced_ratio:.4f} > fixed "
            f"{TRACED_BOUND}x tracing-overhead bound"
        )
    if faulted_ratio > FAULTED_BOUND:
        failures.append(
            f"faulted_over_clean: {faulted_ratio:.4f} > fixed "
            f"{FAULTED_BOUND}x fault-overhead bound (masks must ride as "
            f"jit arguments, not retraces)"
        )
    if fault_programs != 1:
        failures.append(
            f"fault_programs_across_seeds: {fault_programs} != 1 — "
            f"different-seed fault plans must share ONE compiled program"
        )
    if failures:
        print("PERF REGRESSION (>{:.1f}x baseline):".format(args.slack))
        for fmsg in failures:
            print(f"  {fmsg}")
        return 1
    print(f"ok — all metrics within {args.slack:.1f}x of baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
