"""Perf smoke — catch executor-layer performance regressions in CI.

Runs a small fixed GD workload under the local, mesh, and sweep
executors plus the compressed wire, and compares against the checked-in
``benchmarks/perf_baselines.json``.  Any metric worse than
``slack × baseline`` (default 2×) fails the run.

The primary metrics are RATIOS (mesh/local, per-scenario-sweep/local,
topk/dense, cold/warm amortization), which are machine-speed invariant —
a slower CI runner shifts numerator and denominator together.  The
absolute local wall time is checked too, with the same slack, as a
backstop against global slowdowns the ratios cannot see.

One metric is held to a FIXED bound instead of the baseline×slack rule:
``traced_over_untraced`` — a warm mesh fit with a live
``telemetry.trace.Tracer`` vs the same fit untraced — must stay ≤ 1.05×
(``TRACED_BOUND``).  That is the tracing layer's overhead contract
(docs/OBSERVABILITY.md): host-side spans around whole-program dispatch
may not tax the hot path, traced or not.

Usage:
  PYTHONPATH=src python tools/perf_smoke.py            # check
  PYTHONPATH=src python tools/perf_smoke.py --update   # rewrite baselines
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

BASELINES = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "benchmarks", "perf_baselines.json",
)

SLACK = 2.0
#: hard ceiling on tracer-on / tracer-off warm-fit wall time — the
#: tracing layer's "zero overhead" contract, checked absolutely (no
#: baseline, no slack)
TRACED_BOUND = 1.05
K, NK, N = 8, 64, 256
STEPS = 100
LRS = (0.02, 0.05, 0.1, 0.2)


def _measure() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import api
    from repro.api import executor as _exec
    from repro.ml.linear import lsq_loss

    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(K, NK, N)))
    w = jnp.asarray(rng.normal(size=(N,)))
    y = jnp.einsum("kni,i->kn", X, w)
    data = (X, y)

    def timed(fn, repeats=3):
        _exec.clear_program_cache()
        t0 = time.perf_counter()
        jax.block_until_ready(fn().theta)
        cold = time.perf_counter() - t0
        warm = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn().theta)
            warm = min(warm, time.perf_counter() - t0)
        return cold, warm

    def fit(**kw):
        return api.fit(
            api.GradientDescent(lsq_loss, lr=0.05), data,
            transport="allreduce", steps=STEPS, **kw,
        )

    _, local = timed(lambda: fit())
    cold_mesh, mesh = timed(lambda: fit(executor="mesh"))
    _, local_topk = timed(lambda: fit(wire="topk:0.1+ef"))
    _, sweep = timed(
        lambda: fit(executor=api.SweepExecutor({"lr": jnp.asarray(LRS)}))
    )

    # tracing overhead contract: the SAME warm mesh executable (the
    # program cache key ignores the tracer), tracer off vs on, best of 5
    # each so scheduler noise doesn't dominate a µs-scale difference
    from repro.telemetry.trace import Tracer

    def warm_best(fn, repeats=5):
        jax.block_until_ready(fn().theta)  # warm the program cache
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn().theta)
            best = min(best, time.perf_counter() - t0)
        return best

    untraced = warm_best(lambda: fit(executor="mesh"))
    traced = warm_best(lambda: fit(executor="mesh", tracer=Tracer()))

    return {
        "local_warm_s": local,
        "mesh_over_local": mesh / local,
        "sweep_scenario_over_local": (sweep / len(LRS)) / local,
        "topk_over_dense": local_topk / local,
        "mesh_cold_over_warm": cold_mesh / mesh,
        "traced_over_untraced": traced / untraced,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baselines file from this machine")
    ap.add_argument("--slack", type=float, default=SLACK)
    args = ap.parse_args()

    measured = _measure()
    print("measured:")
    for k, v in measured.items():
        print(f"  {k}: {v:.4f}")

    # fixed-bound contract, not a baseline ratio: tracing must stay free
    traced_ratio = measured.pop("traced_over_untraced")

    if args.update:
        with open(BASELINES, "w") as f:
            json.dump(
                {"workload": {"K": K, "Nk": NK, "n": N, "steps": STEPS},
                 "slack": args.slack, "metrics": measured},
                f, indent=2,
            )
            f.write("\n")
        print(f"wrote {BASELINES}")
        return 0

    with open(BASELINES) as f:
        base = json.load(f)["metrics"]

    failures = []
    for key, ref in base.items():
        got = measured.get(key)
        if got is None:
            failures.append(f"{key}: missing from measurement")
        elif got > args.slack * ref:
            failures.append(
                f"{key}: {got:.4f} > {args.slack:.1f}x baseline {ref:.4f}"
            )
    if traced_ratio > TRACED_BOUND:
        failures.append(
            f"traced_over_untraced: {traced_ratio:.4f} > fixed "
            f"{TRACED_BOUND}x tracing-overhead bound"
        )
    if failures:
        print("PERF REGRESSION (>{:.1f}x baseline):".format(args.slack))
        for fmsg in failures:
            print(f"  {fmsg}")
        return 1
    print(f"ok — all metrics within {args.slack:.1f}x of baselines")
    return 0


if __name__ == "__main__":
    sys.exit(main())
