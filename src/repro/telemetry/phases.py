"""Per-phase DEVICE timings for ``fit(..., trace="phases")``.

A host-side span around an async-dispatched jax program measures
submission, not execution — and fencing *inside* the fit's scan would
split the compiled program (different fusion, different numerics risk).
So ``trace="phases"`` never touches the fit program at all: after the
(bit-exact, untouched) fit completes, :func:`profile_phases` replays the
round's constituent phases as **standalone** jitted probe programs at
the run's real shapes, each compiled+warmed first and then timed once
under a ``jax.block_until_ready`` fence inside its span:

* ``phase/local_step``      — per-node grads + stack reduce + apply: the
  compute floor every executor shares;
* ``phase/encode``          — the wire's stacked encode (top-k select /
  quantize / EF residual) on the run's own first-round messages;
* ``hop/<name>``            — one span per reduction hop of the mesh /
  multipod topology (``intra_pod``, ``inter_pod``, ``flat``): a
  shard_map'd scan reducing the message shape over just that hop via
  ``hierarchical_allreduce`` — what placement itself adds, per link;
* ``phase/stats_completion`` — the deferred ``metric_mean`` completion
  (a trajectory-shaped pmean over the node axis).

This mirrors the probe methodology of ``benchmarks/bench_fit_executors``
(phase decomposition) and ``benchmarks/bench_multipod`` (per-hop loops),
promoted into the library so every traced fit can carry its own
attribution.  Each probe scans ``steps`` rounds, so span durations are
directly comparable to the ``fit/loop`` span.

Probes are best-effort: a strategy/executor combination a probe doesn't
apply to (non-stacked messages, closure data, indivisible placement)
skips that probe, bumps the ``phases/skipped`` counter, and leaves the
rest of the report intact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.allreduce import hierarchical_allreduce, mesh_allreduce

__all__ = ["profile_phases"]


def _fenced(tracer, name, prog, *args, **tags):
    """Compile+warm ``prog`` outside the span, then time one fenced call
    inside it.  Any failure marks the probe skipped instead of failing
    the fit."""
    try:
        jax.block_until_ready(prog(*args))
        with tracer.span(name, **tags):
            jax.block_until_ready(prog(*args))
        return True
    except Exception as e:  # probe inapplicable — record why, move on
        tracer.count("phases/skipped")
        tracer.gauge(f"{name}/skipped", type(e).__name__)
        return False


def _tree_reduce_stack(msgs, op: str):
    red = jnp.mean if op == "mean" else jnp.sum
    return jax.tree.map(lambda m: red(m, axis=0), msgs)


def _consume(tree):
    """Scalar folding every leaf, so scanned probe outputs defeat DCE."""
    return sum(jnp.sum(leaf) for leaf in jax.tree.leaves(tree))


def profile_phases(
    tracer, strategy, data, *,
    wire, transport, executor,
    schedule=None, steps=None, stream=None, theta0=None,
) -> None:
    """Record the per-phase probe spans for one fit configuration (see
    module docstring).  Called by ``api.fit`` when ``trace="phases"``."""
    from repro.api.executor import MeshExecutor, SweepExecutor

    if isinstance(executor, SweepExecutor):
        executor = executor.inner  # probe one scenario's placement
    if steps is not None:
        T = int(steps)
    elif schedule is not None:
        T = int(jnp.shape(jnp.asarray(schedule))[0])
    else:
        T = 1
    tname = getattr(transport, "name", str(transport))

    theta = theta0 if theta0 is not None else strategy.init_theta(data)
    try:
        state = strategy.init_state(theta, data)
    except Exception:
        state = ()
    batch = None if stream is None else jax.tree.map(lambda s: s[0], stream)
    op = strategy.aggregate_op

    # -- phase/local_step: grads + stack reduce + apply, no wire, no mesh
    msgs = None
    if strategy.stacked_msgs:
        try:
            msgs, _ = strategy.local_updates(theta, state, data, batch)
        except Exception:
            tracer.count("phases/skipped")
            tracer.gauge("phase/local_step/skipped", "local_updates")
        if msgs is not None:

            def local_prog(th, st, d):
                def step(c, _):
                    th1, st1 = c
                    m, st2 = strategy.local_updates(th1, st1, d, batch)
                    th2, st3 = strategy.apply_update(
                        th1, _tree_reduce_stack(m, op), st2, d
                    )
                    return (th2, st3), ()

                return _consume(
                    jax.lax.scan(step, (th, st), None, length=T)[0]
                )

            _fenced(
                tracer, "phase/local_step", jax.jit(local_prog),
                theta, state, data, steps=T, transport=tname,
            )

    # -- phase/encode: the wire's stacked encode at the real message shape
    if msgs is not None:
        try:
            K = strategy.num_nodes(data)
            wstate = wire.init_state(theta, K, stacked=True)
        except Exception:
            wstate = None
            tracer.count("phases/skipped")
            tracer.gauge("phase/encode/skipped", "init_state")
        if wstate is not None:

            def encode_prog(w0, m):
                def step(c, _):
                    ws, acc = c
                    ws, m_hat, _up = wire.encode_updates(ws, m, stacked=True)  # reprolint: disable=ledger-completeness -- timing probe; the traced fit already accounted these bytes
                    return (ws, acc + _consume(m_hat)), ()

                return jax.lax.scan(
                    step, (w0, jnp.zeros(())), None, length=T
                )[0]

            _fenced(
                tracer, "phase/encode", jax.jit(encode_prog),
                wstate, msgs, steps=T, wire=wire.name,
            )

    # -- hop/<name> + phase/stats_completion: mesh placements only
    if not isinstance(executor, MeshExecutor) or msgs is None:
        return
    try:
        r = executor.resolve()
    except Exception:
        tracer.count("phases/skipped")
        tracer.gauge("hop/skipped", "resolve")
        return

    def hop_loop(hop):
        def body(v):
            one = jax.tree.map(lambda x: x[0], v)

            def step(c, _):
                red = hierarchical_allreduce(one, [hop], op="sum")
                return jax.tree.map(jnp.add, c, red), ()

            z = jax.tree.map(lambda x: jnp.zeros(x.shape[1:], x.dtype), v)
            return jax.lax.scan(step, z, None, length=T)[0]

        return jax.jit(shard_map(
            body, mesh=r.mesh, in_specs=P(r.axis), out_specs=P(),
            check_rep=False,
        ))

    for hop in r.topology.hops:
        _fenced(
            tracer, f"hop/{hop.name}", hop_loop(hop), msgs,
            axes="+".join(hop.axes), steps=T,
        )

    # the deferred metric_mean completion: a (T,)-per-node pmean
    def stats_body(v):
        return mesh_allreduce(jnp.sum(v, axis=0), r.axis, op="mean")

    stats_prog = jax.jit(shard_map(
        stats_body, mesh=r.mesh, in_specs=P(r.axis), out_specs=P(),
        check_rep=False,
    ))
    K = strategy.num_nodes(data)
    _fenced(
        tracer, "phase/stats_completion", stats_prog,
        jnp.ones((K, T)), steps=T,
    )
