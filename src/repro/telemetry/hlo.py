"""HLO text analysis: collective-byte accounting + op census.

``cost_analysis()`` does not expose collective traffic, so we parse the
post-SPMD (per-device) HLO text and sum operand bytes of every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` op.  Shapes in HLO are per-device after partitioning,
so the sums are bytes moved per device — multiply by chip count for fleet
totals (the roofline uses per-device directly).

Per-axis attribution: every collective's ``replica_groups`` names which
devices talk to each other.  Given a device→pod map (``mesh_pod_map``),
``collective_stats(..., pod_of=...)`` classifies each collective as
``intra_pod`` (every group stays inside one pod) or ``inter_pod`` (some
group spans pods) — the measured counterpart of the ``CommLedger``'s
per-hop predicted split.
"""

from __future__ import annotations

import re
from collections import defaultdict

import numpy as np

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "bf16": 2,
    "f16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape literal like ``f32[128,1024]`` (or a tuple)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_RG_RE = re.compile(
    r"replica_groups="
    r"(\{\{[\d,\{\} ]*\}\}"  # explicit lists: {{0,1},{2,3}}
    r"|\[[\d,]+\]<=\[[\d,]+\](?:T\([\d,]+\))?)"  # iota form: [2,2]<=[4]T(1,0)
)


def parse_replica_groups(attr: str) -> list[list[int]] | None:
    """Decode one ``replica_groups=`` attribute value into device groups.

    Handles both the explicit-list form ``{{0,1},{2,3}}`` and the iota
    form ``[G,S]<=[d0,d1,...]T(p0,p1,...)`` (arange over ∏d, reshaped to
    (d…), transposed by the permutation, then regrouped as G rows of S).
    Returns None for strings in neither form.
    """
    attr = attr.strip()
    if attr.startswith("{{"):
        groups = []
        for grp in re.findall(r"\{([\d, ]*)\}", attr[1:-1]):
            ids = [int(t) for t in grp.replace(" ", "").split(",") if t]
            groups.append(ids)
        return groups
    m = re.match(r"\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?$", attr)
    if not m:
        return None
    gshape = [int(t) for t in m.group(1).split(",")]
    dims = [int(t) for t in m.group(2).split(",")]
    src = np.arange(int(np.prod(dims))).reshape(dims)
    if m.group(3):
        perm = [int(t) for t in m.group(3).split(",")]
        src = src.transpose(perm)
    return src.reshape(gshape).tolist()


def mesh_pod_map(mesh, pod_axes=("pod",)) -> dict:
    """Map each mesh device's FLAT index (the SPMD partition id) to its
    pod id, from the mesh axis coordinates — the ``pod_of`` input to
    ``collective_stats``.  Meshes without a pod axis map everything to
    pod 0 (every collective classifies as intra_pod)."""
    names = list(mesh.axis_names)
    shape = tuple(mesh.shape[a] for a in names)
    n = int(np.prod(shape))
    coords = np.unravel_index(np.arange(n), shape)
    pod = np.zeros(n, dtype=int)
    for a in pod_axes:
        if a in names:
            i = names.index(a)
            pod = pod * shape[i] + coords[i]
    return {i: int(p) for i, p in enumerate(pod)}


def _classify_groups(groups, pod_of) -> str:
    for grp in groups:
        pods = {pod_of.get(d, d) for d in grp}
        if len(pods) > 1:
            return "inter_pod"
    return "intra_pod"


def collective_stats(hlo_text: str, *, pod_of: dict | None = None) -> dict:
    """Per-collective-kind {count, bytes} from (per-device) HLO text.

    With ``pod_of`` (device index → pod id, see ``mesh_pod_map``) the
    result also carries ``by_tier``: the same bytes attributed to
    ``intra_pod`` / ``inter_pod`` links by each collective's
    ``replica_groups`` (collectives with unparseable groups land in
    ``unattributed``) — comparable against the ledger's per-hop split.
    """
    stats = defaultdict(lambda: {"count": 0, "bytes": 0})
    tiers = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        s = line.strip()
        # [ROOT] result-shape = opname(...) — match " = <shape> <op>(" forms
        m = re.match(
            r"^(?:ROOT\s+)?[%\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", s
        )
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op.startswith(c + "."):
                base = c
                break
        if base is None:
            continue
        nbytes = _shape_bytes(shape_str)
        stats[base]["count"] += 1
        stats[base]["bytes"] += nbytes
        if pod_of is not None:
            rg = _RG_RE.search(s)
            groups = parse_replica_groups(rg.group(1)) if rg else None
            tier = (
                _classify_groups(groups, pod_of)
                if groups is not None
                else "unattributed"
            )
            tiers[tier]["count"] += 1
            tiers[tier]["bytes"] += nbytes
    out = dict(stats)
    out["total_bytes"] = sum(v["bytes"] for v in stats.values())
    out["total_count"] = sum(v["count"] for v in stats.values())
    if pod_of is not None:
        out["by_tier"] = dict(tiers)
    return out


def op_census(hlo_text: str, ops=("fusion", "dot", "convolution", "custom-call")) -> dict:
    census = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.match(
            r"^\s*(?:ROOT\s+)?[%\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", line
        )
        if m and m.group(2) in ops:
            census[m.group(2)] += 1
    return dict(census)
