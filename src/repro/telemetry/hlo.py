"""HLO text analysis: collective-byte accounting + op census.

``cost_analysis()`` does not expose collective traffic, so we parse the
post-SPMD (per-device) HLO text and sum operand bytes of every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` op.  Shapes in HLO are per-device after partitioning,
so the sums are bytes moved per device — multiply by chip count for fleet
totals (the roofline uses per-device directly).
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "bf16": 2,
    "f16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape literal like ``f32[128,1024]`` (or a tuple)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-kind {count, bytes} from (per-device) HLO text."""
    stats = defaultdict(lambda: {"count": 0, "bytes": 0})
    for line in hlo_text.splitlines():
        s = line.strip()
        # [ROOT] result-shape = opname(...) — match " = <shape> <op>(" forms
        m = re.match(
            r"^(?:ROOT\s+)?[%\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", s
        )
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op.startswith(c + "."):
                base = c
                break
        if base is None:
            continue
        nbytes = _shape_bytes(shape_str)
        stats[base]["count"] += 1
        stats[base]["bytes"] += nbytes
    out = dict(stats)
    out["total_bytes"] = sum(v["bytes"] for v in stats.values())
    out["total_count"] = sum(v["count"] for v in stats.values())
    return out


def op_census(hlo_text: str, ops=("fusion", "dot", "convolution", "custom-call")) -> dict:
    census = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.match(
            r"^\s*(?:ROOT\s+)?[%\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", line
        )
        if m and m.group(2) in ops:
            census[m.group(2)] += 1
    return dict(census)
