from repro.telemetry import hlo, roofline, trace
from repro.telemetry.report import RunReport
from repro.telemetry.trace import Tracer

# NOTE: ``repro.telemetry.phases`` (the trace="phases" device probes) is
# jax-heavy and imported lazily by ``api.fit`` — everything here stays
# stdlib-only so ``api.executor`` can import ``trace`` at module load.

__all__ = ["hlo", "roofline", "trace", "Tracer", "RunReport"]
