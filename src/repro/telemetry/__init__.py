from repro.telemetry import hlo, roofline

__all__ = ["hlo", "roofline"]
