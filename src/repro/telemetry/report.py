"""``RunReport`` — one artifact answering "what did this run do?".

The run-level observability fragments each tell a slice of the story:
``CommLedger`` knows the bytes (per reduction hop), the ``Tracer`` knows
the wall time (per span), ``program_cache_stats()`` knows compile vs
warm dispatch, ``metrics["wire_kernel_hits"]`` knows Pallas kernel
coverage, and ``ServeMetrics`` knows latency percentiles.  ``RunReport``
joins them::

    tracer = Tracer()
    res = api.fit(strategy, data, executor="multipod",
                  wire="topk:0.1+ef", steps=100,
                  tracer=tracer, trace="phases")
    rep = RunReport.from_fit(res, tracer=tracer)
    rep.as_dict()       # one JSON-serializable dict
    print(rep.to_markdown())   # rendered tables

``from_serve(engine)`` builds the serving-side equivalent from a
``ServeEngine`` (batcher/predict/swap spans + ``ServeMetrics`` latency
summary + the inference ledger).  Benchmarks embed ``to_markdown()``
blocks in their ``BENCH_*.json`` sidecars so the perf trajectory carries
phase decomposition, not just wall times.
"""

from __future__ import annotations

import json
from typing import Any

__all__ = ["RunReport"]


def _fmt_bytes(n: float) -> str:
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} GiB"


def _fmt_s(s: float) -> str:
    return f"{1e3 * s:.2f} ms" if s < 1.0 else f"{s:.3f} s"


class RunReport:
    """One dict + markdown rendering of a run's time, bytes and caches.

    Construct via :meth:`from_fit` or :meth:`from_serve`; the joined
    data lives in ``.data`` (JSON-serializable — what :meth:`as_dict`
    returns).
    """

    def __init__(self, data: dict):
        self.data = data

    # -- constructors --------------------------------------------------------

    @classmethod
    def from_fit(cls, result, tracer=None) -> "RunReport":
        """Join a ``FitResult`` with the run's tracer (if any): config,
        per-hop ledger bytes, span wall times, program-cache state and
        wire kernel hits in one artifact.  ``tracer`` is the instance
        passed to ``fit(..., tracer=...)``; None reports bytes/caches
        only."""
        from repro.api.executor import program_cache_stats

        m = result.metrics
        ledger = result.ledger
        if isinstance(ledger, list):  # sweep: S per-scenario ledgers
            comm = {
                "scenarios": len(ledger),
                "per_scenario": ledger[0].summary() if ledger else {},
                "total_bytes": sum(l.total_bytes for l in ledger),
            }
        else:
            comm = ledger.summary()
        data = {
            "kind": "fit",
            "config": {
                "transport": m.get("transport"),
                "wire": m.get("wire"),
                "executor": m.get("executor"),
            },
            "comm": comm,
            "program_cache": program_cache_stats(),
        }
        if "faults" in m:  # the plan's spec (seed/dropout/straggler/quorum)
            data["faults"] = dict(m["faults"])
        if "wire_kernel_hits" in m:
            data["wire_kernel_hits"] = m["wire_kernel_hits"]
        cls._join_tracer(data, tracer)
        return cls(data)

    @classmethod
    def from_serve(cls, engine, tracer=None) -> "RunReport":
        """Join a serving engine's ``ServeMetrics`` summary (latency
        percentiles, pad fraction, inference bytes) with its tracer
        (defaults to the tracer the engine itself records into).
        Accepts a ``ServeEngine`` or a ``ContinuousLMEngine`` — the
        latter additionally contributes its decode-attention kernel plan
        and per-implementation token hits (the serve-side
        ``wire_kernel_hits``)."""
        data = {
            "kind": "serve",
            "serve": engine.stats(),
            "comm": engine.ledger.summary(),
        }
        hits = getattr(engine, "kernel_hits", None)
        if hits is not None:
            data["decode_kernel_hits"] = dict(hits)
            data["decode_kernel_plan"] = dict(
                getattr(engine, "kernel_plan", {}) or {}
            )
        cls._join_tracer(data, tracer if tracer is not None else engine.tracer)
        return cls(data)

    @staticmethod
    def _join_tracer(data: dict, tracer) -> None:
        if tracer is None:
            return
        data["spans"] = tracer.summary()
        if tracer.counters:
            data["counters"] = dict(tracer.counters)
        if tracer.gauges:
            data["gauges"] = dict(tracer.gauges)

    # -- rendering -----------------------------------------------------------

    def as_dict(self) -> dict:
        return self.data

    def to_json(self) -> str:
        return json.dumps(self.data, indent=2, default=str)

    def to_markdown(self) -> str:
        d = self.data
        lines = [f"## RunReport ({d['kind']})", ""]
        cfg = d.get("config")
        if cfg:
            lines.append(
                "- config: "
                + " × ".join(f"`{v}`" for v in cfg.values() if v)
            )
        faults = d.get("faults")
        if faults:
            lines.append(
                "- faults: "
                + ", ".join(f"{k}={v}" for k, v in faults.items()
                            if v not in (None, 0, 0.0))
            )
        comm = d.get("comm", {})
        if "total_bytes" in comm:
            lines.append(f"- comm total: {_fmt_bytes(comm['total_bytes'])}"
                         + (f" over {comm['rounds']} rounds"
                            if comm.get("rounds") else ""))
        if comm.get("scenarios"):
            lines.append(f"- scenarios: {comm['scenarios']} "
                         f"(per-scenario shown below)")
            comm = comm.get("per_scenario", {})
        by_hop = comm.get("by_hop")
        if by_hop:
            lines += ["", "| hop | uplink | downlink | price/byte |",
                      "|---|---|---|---|"]
            for name, h in by_hop.items():
                lines.append(
                    f"| {name} | {_fmt_bytes(h['uplink_bytes'])} "
                    f"| {_fmt_bytes(h['downlink_bytes'])} "
                    f"| {h['price_per_byte']:g} |"
                )
        spans = d.get("spans")
        if spans:
            lines += ["", "| span | count | total | mean |",
                      "|---|---|---|---|"]
            for name in sorted(spans):
                e = spans[name]
                lines.append(
                    f"| {name} | {e['count']} | {_fmt_s(e['total_s'])} "
                    f"| {_fmt_s(e['mean_s'])} |"
                )
        cache = d.get("program_cache")
        if cache:
            lines.append(
                f"\n- program cache: {cache['hits']} hits / "
                f"{cache['misses']} misses ({cache['size']} cached)"
            )
        hits = d.get("wire_kernel_hits")
        if hits:
            lines.append(f"- wire kernel hits: `{hits}`")
        dhits = d.get("decode_kernel_hits")
        if dhits:
            plan = d.get("decode_kernel_plan", {})
            via = f" via `{plan['path']}` ({plan['reason']})" if plan else ""
            lines.append(f"- decode kernel hits: `{dhits}`{via}")
        counters = d.get("counters")
        if counters:
            lines.append(
                "- counters: "
                + ", ".join(f"{k}={v:g}" if isinstance(v, float) else
                            f"{k}={v}" for k, v in sorted(counters.items()))
            )
        serve = d.get("serve")
        if serve:
            lines += [
                "",
                "| requests | req/s | p50 | p95 | p99 | pad |",
                "|---|---|---|---|---|---|",
                (
                    f"| {serve['requests']} "
                    f"| {serve['requests_per_s']:.0f} "
                    f"| {serve['p50_latency_ms']:.2f} ms "
                    f"| {serve['p95_latency_ms']:.2f} ms "
                    f"| {serve['p99_latency_ms']:.2f} ms "
                    f"| {100 * serve['pad_fraction']:.1f}% |"
                ),
            ]
            if serve.get("tokens"):
                lines += [
                    "",
                    "| tokens | tok/s | slot util | p50 token | p99 token |",
                    "|---|---|---|---|---|",
                    (
                        f"| {serve['tokens']} "
                        f"| {serve['tokens_per_s']:.0f} "
                        f"| {100 * serve['slot_utilization']:.1f}% "
                        f"| {serve['p50_token_ms']:.2f} ms "
                        f"| {serve['p99_token_ms']:.2f} ms |"
                    ),
                ]
        return "\n".join(lines) + "\n"
