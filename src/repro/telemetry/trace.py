"""Structured run tracing — spans, counters, and Perfetto export.

The repo grew five disjoint observability fragments (``CommLedger``,
``ServeMetrics``, ``program_cache_stats()``, ``wire_kernel_hits``,
``telemetry.hlo.collective_stats``) with no common timeline.  This module
is the timeline: a ``Tracer`` collects named, tagged **spans** (wall-time
intervals) plus monotonic **counters** and last-value **gauges**, and
exports them as Chrome trace-event JSON loadable in Perfetto
(https://ui.perfetto.dev) or ``chrome://tracing``::

    from repro.telemetry.trace import Tracer

    tracer = Tracer()
    with tracer.span("round", round=3, nodes=8):
        ...                              # any host-side work
    tracer.count("program_cache/hit")
    tracer.export_chrome("run.trace.json")

**Zero overhead when off.**  Tracing is opt-in twice over: nothing in the
hot paths allocates or formats unless a tracer is *installed* (the
ambient ``current_tracer()`` is None by default) and *enabled*.  The
instrumented call sites (``api.fit``, the executors' program dispatch,
``repro.serve``) guard every span behind a single ``is None`` check, and
all spans are HOST-side — no tracing call ever runs inside a jitted /
scanned / shard_map'd region, so traced and untraced fits execute the
same compiled program bit-for-bit (``tests/test_trace.py`` proves it).

Spans must be context-managed: ``with tracer.span(...)``.  The low-level
``span_begin``/``span_end`` pair exists only so the context manager has
something to wrap — ``tools/reprolint``'s ``span-discipline`` rule flags
any orphaned use in ``src/repro``.

Device time: a host span around a dispatch measures submission, not
execution.  Call sites that want device-complete timings fence with
``jax.block_until_ready`` before closing the span (the engine does this
for the loop span; ``telemetry.phases`` does it per phase), and
``device_trace(logdir)`` wraps ``jax.profiler.trace`` so a full XLA
device trace nests under the same run for Perfetto/TensorBoard.
"""

from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager, nullcontext

__all__ = [
    "Tracer",
    "activated",
    "current_tracer",
    "span",
]

#: monotonic clock in microseconds (the trace-event time unit)
def _now_us() -> float:
    return time.perf_counter_ns() / 1e3


class Tracer:
    """Collects spans/counters/gauges for one run.

    Thread-safe: serve-path spans arrive from batcher worker threads;
    each thread's spans carry its own ``tid`` so Perfetto renders one
    track per thread.

    ``enabled=False`` builds a permanently-off tracer: every ``span``
    returns a shared null context and counters are dropped — handy for
    keeping one code path when tracing is configuration-driven.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.spans: list = []  # dicts: name, ts (us), dur (us), tid, tags
        self.counters: dict = {}
        self.gauges: dict = {}
        self._lock = threading.Lock()
        self._tids: dict = {}  # thread ident -> small stable int
        self.t0_us = _now_us()

    # -- recording -----------------------------------------------------------

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def span_begin(self, name: str, **tags) -> dict:
        """Open a span record (low level — use ``with tracer.span(...)``;
        the reprolint ``span-discipline`` rule flags direct calls)."""
        rec = {
            "name": name,
            "ts": _now_us(),
            "dur": None,
            "tid": self._tid(),
            "tags": tags,
        }
        with self._lock:
            self.spans.append(rec)
        return rec

    def span_end(self, rec: dict) -> None:
        rec["dur"] = _now_us() - rec["ts"]

    @contextmanager
    def _span(self, name: str, tags: dict):
        rec = self.span_begin(name, **tags)
        try:
            yield rec
        finally:
            self.span_end(rec)

    def span(self, name: str, **tags):
        """Context manager timing the enclosed block::

            with tracer.span("aggregate", hop="inter_pod") as rec:
                ...
                rec["tags"]["bytes"] = nbytes   # tags may be added inside

        A disabled tracer returns a null context (no allocation beyond
        the call itself)."""
        if not self.enabled:
            return nullcontext()
        return self._span(name, tags)

    def count(self, name: str, n: float = 1) -> None:
        """Monotonic counter (cache hits, padded slots, …)."""
        if not self.enabled:
            return
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value) -> None:
        """Last-value-wins gauge (queue depth, cache size, …)."""
        if not self.enabled:
            return
        with self._lock:
            self.gauges[name] = value

    @contextmanager
    def device_trace(self, logdir: str):
        """Nest an XLA device trace (``jax.profiler.trace``) under a span
        of this run, so the host-side timeline and the device profile
        land in one place::

            with tracer.device_trace("/tmp/xla-trace"):
                api.fit(..., tracer=tracer)
        """
        import jax

        with self.span("device_trace", logdir=str(logdir)):
            with jax.profiler.trace(str(logdir)):
                yield

    # -- reading -------------------------------------------------------------

    def wall_s(self, name: str) -> float:
        """Total wall seconds across all closed spans named ``name``."""
        return sum(
            s["dur"] for s in self.spans
            if s["name"] == name and s["dur"] is not None
        ) / 1e6

    def summary(self) -> dict:
        """Per-span-name aggregate: count, total/mean/max wall seconds."""
        agg: dict = {}
        for s in self.spans:
            if s["dur"] is None:
                continue
            e = agg.setdefault(
                s["name"], {"count": 0, "total_s": 0.0, "max_s": 0.0}
            )
            e["count"] += 1
            e["total_s"] += s["dur"] / 1e6
            e["max_s"] = max(e["max_s"], s["dur"] / 1e6)
        for e in agg.values():
            e["mean_s"] = e["total_s"] / e["count"]
        return agg

    # -- export --------------------------------------------------------------

    def chrome_events(self) -> list:
        """The run as Chrome trace-event dicts: one complete (``"X"``)
        event per closed span, one counter (``"C"``) sample per counter
        and gauge.  Every event carries the schema keys ``ph`` / ``ts`` /
        ``pid`` / ``tid`` / ``name``."""
        pid = os.getpid()
        events = [
            {
                "ph": "M", "ts": 0, "pid": pid, "tid": 0,
                "name": "process_name",
                "args": {"name": "repro"},
            }
        ]
        for s in self.spans:
            if s["dur"] is None:
                continue  # still open (or orphaned) — not exportable
            events.append({
                "ph": "X",
                "ts": s["ts"],
                "dur": s["dur"],
                "pid": pid,
                "tid": s["tid"],
                "name": s["name"],
                "cat": s["name"].split("/")[0],
                "args": {k: _arg(v) for k, v in s["tags"].items()},
            })
        t_end = _now_us()
        for name, value in {**self.counters, **self.gauges}.items():
            events.append({
                "ph": "C", "ts": t_end, "pid": pid, "tid": 0,
                "name": name, "args": {"value": _arg(value)},
            })
        return events

    def export_chrome(self, path: str) -> str:
        """Write the trace-event JSON; returns ``path``.  Load it in
        Perfetto (https://ui.perfetto.dev) or summarize it with
        ``python tools/traceview.py <path>``."""
        payload = {
            "traceEvents": self.chrome_events(),
            "displayTimeUnit": "ms",
        }
        with open(path, "w") as f:
            json.dump(payload, f)
        return path


def _arg(v):
    """Trace-event args must be JSON: pass primitives, stringify the rest."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    try:  # numpy / 0-d jax scalars
        return v.item()
    except Exception:
        return str(v)


# ----------------------------------------------------------------------------
# Ambient tracer — how instrumented layers find the active run's tracer
# ----------------------------------------------------------------------------

_active = threading.local()


def current_tracer() -> Tracer | None:
    """The tracer installed for the current thread, or None (the
    zero-overhead default — instrumented call sites guard on this)."""
    t = getattr(_active, "value", None)
    if t is not None and not t.enabled:
        return None
    return t


@contextmanager
def activated(tracer: Tracer | None):
    """Install ``tracer`` as the ambient tracer for the enclosed block
    (``api.fit(..., tracer=...)`` wraps the whole run in this, so the
    executors' program-cache/dispatch spans land on the same timeline).
    ``None`` is a no-op install, keeping call sites unconditional."""
    prev = getattr(_active, "value", None)
    _active.value = tracer
    try:
        yield tracer
    finally:
        _active.value = prev


def span(name: str, **tags):
    """Span on the AMBIENT tracer — a null context when none is
    installed, so library code can trace unconditionally::

        from repro.telemetry import trace

        with trace.span("fit/ledger", scenarios=S):
            ...
    """
    t = current_tracer()
    if t is None:
        return nullcontext()
    return t._span(name, tags)
