"""Trip-count-correct cost extraction via probe lowering.

XLA's ``cost_analysis()`` counts each ``while``-loop body ONCE, so a model
compiled as scan-over-layers under-reports FLOPs/bytes by ~num_layers and
its HLO text under-counts collectives the same way.  Rather than parsing
loop trip counts out of optimized HLO, we lower small *unrolled* probe
variants and extrapolate:

* segments are unrolled (``cfg.scan_layers=False``) with per-segment
  repeats overridden to 1 (and 2, one segment at a time) →
  ``marginal_s = cost(rep_s=2) − cost(all 1)`` isolates one pattern-unit;
* time-scans (mamba chunks, mLSTM chunks) are collapsed to a single chunk
  (``cfg.unroll_time_scans=True``) so nothing hides in a loop.  The probes
  use a reduced batch so the single-chunk form fits host memory;
* costs are affine in batch (activation terms ∝ B, parameter terms const),
  so two batch probes (B₁, B₂) give exact linear extrapolation to the full
  global batch;
* the sLSTM time recurrence cannot be unrolled (T steps) and is added
  analytically (8·d·d_h + ~16·d FLOPs and ~12 (B,d) f32 array touches per
  token per sLSTM layer; no collectives inside the scan).

``full = base + Σ_s R_s·marginal_s`` evaluated at the production batch is
what feeds the §Roofline three-term model.  Approximation quality is
tracked by comparing probe totals against the (undercounted) full-compile
numbers in the dry-run JSON.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.launch import specs as S
from repro.launch.mesh import data_axis_size
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.sharding.rules import set_mesh_context
from repro.telemetry import hlo as hlo_lib


def _extract_costs(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = hlo_lib.collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll.get("total_bytes", 0)),
    }


def _lower_probe(cfg: ModelConfig, kind: str, mesh, B: int, S_len: int, *,
                 mla_absorb: bool, strategy: str = "tp") -> dict:
    set_mesh_context(S.make_mesh_context_for(mesh, cfg, B, strategy=strategy))
    try:
        jitted, args, _ = S.build_jitted(
            cfg, kind, mesh, B, S_len, mla_absorb=mla_absorb, strategy=strategy
        )
        compiled = jitted.lower(*args).compile()
        return _extract_costs(compiled)
    finally:
        set_mesh_context(None)


def _probe_variants(cfg: ModelConfig):
    """[(tag, probe_cfg, repeats_full)] — base (all segments ×1) first, then
    one variant per segment with that segment at ×2."""
    base_kw = dict(scan_layers=False, unroll_time_scans=True)
    if cfg.is_encoder_decoder:
        enc, dec = cfg.num_encoder_layers, cfg.num_layers
        variants = [
            ("base", cfg.replace(num_encoder_layers=1, num_layers=1, **base_kw)),
            ("enc", cfg.replace(num_encoder_layers=2, num_layers=1, **base_kw)),
            ("dec", cfg.replace(num_encoder_layers=1, num_layers=2, **base_kw)),
        ]
        repeats = [enc, dec]
        return variants, repeats
    segs = tf.segments(cfg)
    n = len(segs)
    ones = (1,) * n
    variants = [("base", cfg.replace(segment_repeats=ones, **base_kw))]
    for i in range(n):
        reps = tuple(2 if j == i else 1 for j in range(n))
        variants.append((f"seg{i}", cfg.replace(segment_repeats=reps, **base_kw)))
    repeats = [seg.repeats for seg in segs]
    return variants, repeats


def _slstm_correction(cfg: ModelConfig, kind: str, B: int, T: int) -> dict:
    """Analytic cost of the sLSTM per-token recurrence (see module doc)."""
    if cfg.xlstm is None:
        return {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
    n_slstm = sum(
        1 for s in tf.layer_specs(cfg) if s.mixer == "slstm"
    )
    if n_slstm == 0:
        return {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
    d = cfg.d_model
    dh = d // cfg.num_heads
    tokens = B * (T if kind != "decode" else 1)
    flops = tokens * n_slstm * (8.0 * d * dh + 16.0 * d)
    byts = tokens * n_slstm * (12.0 * d * 4.0)
    if kind == "train":  # backward ≈ 2× forward for the recurrence
        flops *= 3.0
        byts *= 3.0
    return {"flops": flops, "bytes": byts, "coll": 0.0}


def probe_costs(
    cfg: ModelConfig,
    kind: str,
    mesh,
    B_full: int,
    S_len: int,
    *,
    mla_absorb: bool = False,
    strategy: str = "tp",
) -> dict:
    """Trip-count-corrected per-device {flops, bytes, coll} at (B_full, S_len)."""
    if strategy in ("dp", "dp_fsdp"):
        dsize = mesh.size  # batch shards over every axis
    else:
        dsize = data_axis_size(mesh)
    if B_full <= dsize:
        b_probes = [B_full]  # long_500k etc.: probe the real batch directly
    else:
        b1 = dsize
        b2 = min(2 * dsize, B_full)
        b_probes = [b1] if b2 == b1 else [b1, b2]

    variants, repeats = _probe_variants(cfg)
    # measure: costs[tag][bi]
    costs = {}
    for tag, pcfg in variants:
        costs[tag] = [
            _lower_probe(
                pcfg, kind, mesh, b, S_len,
                mla_absorb=mla_absorb, strategy=strategy,
            )
            for b in b_probes
        ]

    def combine(bi: int) -> dict:
        base = costs["base"][bi]
        tags = [t for t, _ in variants[1:]]
        marg = {
            t: {k: costs[t][bi][k] - base[k] for k in base} for t in tags
        }
        out = dict(base)
        # base already contains one copy of every segment
        for t, r in zip(tags, repeats):
            for k in out:
                out[k] += marg[t][k] * (r - 1)
        return out

    full_at = [combine(i) for i in range(len(b_probes))]
    if len(b_probes) == 1:
        scale = B_full / b_probes[0]
        result = {k: v * scale for k, v in full_at[0].items()} if b_probes[0] != B_full else full_at[0]
    else:
        b1, b2 = b_probes
        result = {}
        for k in full_at[0]:
            slope = (full_at[1][k] - full_at[0][k]) / (b2 - b1)
            result[k] = full_at[0][k] + slope * (B_full - b1)

    corr = _slstm_correction(cfg, kind, B_full, S_len)
    # probes report per-device numbers for batch-sharded terms already; the
    # analytic sLSTM correction is global → divide by data-parallel size
    result = {
        "flops": result["flops"] + corr["flops"] / dsize,
        "bytes": result["bytes"] + corr["bytes"] / dsize,
        "coll": result["coll"] + corr["coll"] / dsize,
        "n_probes": len(variants) * len(b_probes),
    }
    return result
