"""Three-term roofline model for TPU v5e (DESIGN/EXPERIMENTS §Roofline).

    compute    = FLOPs_per_device / peak_FLOPs            [s]
    memory     = HBM_bytes_per_device / HBM_bw            [s]
    collective = collective_bytes_per_device / link_bw    [s]

Inputs come from the compiled dry-run artifact: ``cost_analysis()`` gives
per-device FLOPs and bytes accessed; ``telemetry.hlo.collective_stats``
gives per-device collective bytes.  The dominant term is the bottleneck;
MODEL_FLOPS/HLO_FLOPs measures how much compiled compute is "useful"
(catches remat recompute and dispatch overhead).
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    model_flops: float
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs × chips)
    chips: int

    def to_dict(self):
        return asdict(self)


def roofline(
    *,
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
    chips: int,
    model_flops: float = 0.0,
) -> Roofline:
    compute = flops_per_device / PEAK_FLOPS_BF16
    memory = bytes_per_device / HBM_BW
    coll = collective_bytes_per_device / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    total_flops = flops_per_device * chips
    useful = model_flops / total_flops if (model_flops and total_flops) else 0.0
    return Roofline(
        compute_s=compute,
        memory_s=memory,
        collective_s=coll,
        dominant=dominant,
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        collective_bytes_per_device=collective_bytes_per_device,
        model_flops=model_flops,
        useful_ratio=useful,
        chips=chips,
    )


def model_flops_train(n_params_active: float, tokens: float) -> float:
    """6·N·D — the standard dense training FLOP count (fwd+bwd)."""
    return 6.0 * n_params_active * tokens


def model_flops_decode(n_params_active: float, tokens: float) -> float:
    """2·N per generated token (forward only)."""
    return 2.0 * n_params_active * tokens
