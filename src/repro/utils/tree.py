"""Pytree arithmetic helpers used across the framework.

Every distributed-learning primitive in ``repro.core`` treats model
parameters as an arbitrary pytree; these helpers provide the small vector
algebra needed (axpy, dot, norms) without pulling in an optimizer library.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y, elementwise over matching pytrees."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a, b):
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, leaves, jnp.asarray(0.0))


def tree_norm(a):
    return jnp.sqrt(tree_dot(a, a))


def tree_size(a) -> int:
    """Total number of scalar elements in the pytree (static)."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    """Total bytes of the pytree's leaves (static)."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(a))


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    oks = jax.tree.map(
        lambda x, y: bool(jnp.allclose(x, y, rtol=rtol, atol=atol)), a, b
    )
    return all(jax.tree.leaves(oks))


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)
