from repro.utils import tree
from repro.utils.tree import (
    tree_add,
    tree_sub,
    tree_scale,
    tree_axpy,
    tree_zeros_like,
    tree_dot,
    tree_norm,
    tree_size,
    tree_bytes,
    tree_allclose,
    tree_cast,
)

__all__ = [
    "tree",
    "tree_add",
    "tree_sub",
    "tree_scale",
    "tree_axpy",
    "tree_zeros_like",
    "tree_dot",
    "tree_norm",
    "tree_size",
    "tree_bytes",
    "tree_allclose",
    "tree_cast",
]
