"""deepseek-v3-671b [moe] — DeepSeek-V3 [arXiv:2412.19437].

61L, d_model 7168, 128 heads (MLA), vocab 129280.  MoE: 256 routed experts
(d_ff 2048) top-8 + 1 shared expert, first 3 layers dense (d_ff 18432).
MLA: q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v_head 128.
MTP: 1 depth-1 multi-token-prediction module (predicts t+2, shared head).
"""

from repro.models.config import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,  # the 3 dense layers
    vocab_size=129280,
    mixer="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        d_ff_shared=2048,
        aux_loss_coef=0.001,
        capacity_factor=1.25,
        layer_mode="after_first_k",
        first_k_dense=3,
    ),
    num_mtp_layers=1,
    mtp_loss_coef=0.3,
    remat_policy="dots",
    source="arXiv:2412.19437",
)
