"""Architecture registry: the 10 assigned configs + input shapes."""

from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig

_ARCH_MODULES = {
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "whisper-base": "repro.configs.whisper_base",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
}

ARCHS = tuple(_ARCH_MODULES.keys())


def get_config(name: str) -> ModelConfig:
    import importlib

    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason) — the DESIGN.md §Arch-applicability skip table."""
    cfg = get_config(arch)
    if shape == "long_500k":
        if cfg.family == "audio":
            return False, "enc-dec ASR: 448-token decoder context by construction"
        if cfg.family in ("dense", "moe", "vlm"):
            return True, "sliding-window attention variant (window 8192)"
        return True, "sub-quadratic decode state (SSM/hybrid)"
    return True, ""
