"""minicpm3-4b [dense] — MiniCPM3 4B with MLA [hf:openbmb/MiniCPM3-4B].

62L, d_model 2560, 40 heads, d_ff 6400, vocab 73448.  Multi-head Latent
Attention: q_lora_rank 768, kv_lora_rank 256, qk_nope 64, qk_rope 32,
v_head 64 (model-card values).
"""

from repro.models.config import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    mixer="mla",
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    rope_theta=10000.0,
    tie_embeddings=True,
    source="hf:openbmb/MiniCPM3-4B",
)
