"""whisper-base [audio] — Whisper base enc-dec backbone [arXiv:2212.04356].

6 encoder + 6 decoder layers, d_model 512, 8 heads (MHA), d_ff 2048,
vocab 51865; encoder consumes 1500 stubbed mel/conv frame embeddings
(30 s at 50 Hz).  LayerNorm + GELU (not RMSNorm/SwiGLU), learned positions.
Decode shapes exercise the decoder self-attention cache; ``long_500k`` is
skipped (448-token decoder context by construction — DESIGN.md).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    is_encoder_decoder=True,
    num_encoder_layers=6,
    encoder_seq_len=1500,
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
