"""olmoe-1b-7b [moe] — OLMoE 1B active / 7B total [arXiv:2409.02060].

16L, d_model 2048, 16 heads (MHA kv=16), vocab 50304.  MoE on every layer:
64 experts top-8, expert d_ff 1024, no shared expert.
"""

from repro.models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    moe=MoEConfig(
        num_experts=64,
        top_k=8,
        d_ff_expert=1024,
        aux_loss_coef=0.01,
        capacity_factor=1.25,
        layer_mode="all",
    ),
    source="arXiv:2409.02060",
)
