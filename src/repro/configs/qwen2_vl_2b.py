"""qwen2-vl-2b [vlm] — Qwen2-VL 2B language backbone [arXiv:2409.12191].

28L, d_model 1536, 12 heads (GQA kv=2), d_ff 8960, vocab 151936, M-RoPE
(sections 16/24/24 over head_dim/2 = 64), QKV bias, tied embeddings.
The ViT vision encoder + projector is a stub: ``input_specs`` supplies
precomputed patch embeddings (dynamic-resolution token budget folded into
the sequence prefix).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    source="arXiv:2409.12191",
)
