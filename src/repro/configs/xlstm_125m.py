"""xlstm-125m [ssm] — xLSTM 125M-class stack [arXiv:2405.04517].

12 blocks, d_model 768, 4 heads, vocab 50304 (GPT-NeoX tokenizer size).
sLSTM at every 4th block (indices 3, 7, 11), mLSTM elsewhere — a periodic
approximation of the paper's [7:1] ratio that keeps the stack scannable.
No separate FFN (xLSTM blocks embed their projections).  Decode state is
O(1) → runs ``long_500k`` natively.
"""

from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_at=(3, 7, 11)),
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
