"""jamba-1.5-large-398b [hybrid] — Jamba 1.5 Large [arXiv:2403.19887].

72L, d_model 8192, 64 heads (GQA kv=8), vocab 65536.  Mamba:attention
1:7 interleave (attention at offset 4 of every 8-layer block, HF config
convention) + MoE every other layer: 16 experts top-2, d_ff 24576.
Mamba state is O(1) and attention layers are 1/8 of the stack → runs
``long_500k`` (KV cache sequence-sharded over the data axis).
"""

from repro.models.config import MoEConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    hybrid_pattern=(
        "mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba",
    ),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        d_ff_expert=24576,
        aux_loss_coef=0.001,
        capacity_factor=1.25,
        layer_mode="every_other",
    ),
    remat_policy="dots",
    source="arXiv:2403.19887",
)
