"""deepseek-67b [dense] — DeepSeek LLM 67B, llama-arch [arXiv:2401.02954].

95L, d_model 8192, 64 heads (GQA kv=8), d_ff 22016, vocab 102400.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=10000.0,
    remat_policy="dots",
    source="arXiv:2401.02954",
)
