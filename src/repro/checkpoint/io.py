"""Dependency-free sharding-aware checkpointing.

Pytrees are flattened to ``path -> np.ndarray`` and stored as one ``.npz``
per step with a JSON manifest of the treedef.  On restore, arrays are placed
back onto the caller-provided shardings with ``jax.device_put`` (each
process would read its own slice in a true multi-host setting; on one host
this degrades gracefully to a full read + placement).

Atomicity: writes go to a temp file and are ``os.replace``d into place, so a
killed run never leaves a half-written checkpoint visible.
"""

from __future__ import annotations

import json
import os
import re
import tempfile

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    meta = {"step": step, "keys": sorted(flat.keys())}
    with open(os.path.join(ckpt_dir, f"step_{step:08d}.json"), "w") as f:
        json.dump(meta, f)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for fn in os.listdir(ckpt_dir)
        if (m := re.match(r"step_(\d+)\.npz$", fn))
    ]
    return max(steps) if steps else None


def restore_dict(ckpt_dir: str, step: int):
    """Restore WITHOUT a template: rebuild nested dicts from the
    '/'-joined keys ``_flatten`` produced (a single '' key is a bare-array
    checkpoint).  Non-dict pytrees (NamedTuples, lists) flatten to
    positional/field keys and need ``restore(..., like=)`` instead."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    if set(flat) == {""}:
        return jax.numpy.asarray(flat[""])
    tree: dict = {}
    for key, arr in flat.items():
        node = tree
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jax.numpy.asarray(arr)
    return tree


def restore(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore into the structure of ``like``; optionally place leaves on
    ``shardings`` (matching pytree of NamedSharding)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}

    paths_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda s: hasattr(s, "spec")
        )
        if shardings is not None
        else None
    )
    for i, (path, leaf) in enumerate(paths_like[0]):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        arr = flat[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr.astype(leaf.dtype), shard_leaves[i]))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(paths_like[1], leaves)
