from repro.checkpoint.io import latest_step, restore, restore_dict, save

__all__ = ["latest_step", "restore", "restore_dict", "save"]
