"""``repro.api.fit`` — one entry point for every distributed trainer.

    fit(strategy, data, transport=..., wire=..., executor=..., schedule=...)

runs any (strategy × transport × wire) combination on a chosen executor
(`local` stacked scan / `mesh` shard_map placement / `multipod`
hierarchical pod placement with per-hop ledger pricing / `sweep` vmapped
scenario batch / composed `mesh+sweep` & `multipod+sweep` scenario vmaps
nested inside the shard placement — see ``repro.api.executor`` and
``docs/EXECUTORS.md``) inside one jit/scan-able engine and returns a
uniform ``FitResult``.  The engine owns what every
historical entry point used to reimplement by hand: the scan loop (via
the transport + executor), message encoding (via the wire), and
``CommLedger`` byte accounting (materialized here from the per-round
byte counts the transport/wire emitted).

``FitResult`` fields:

* ``theta``       — the final parameter (or model pytree, for strategies
  whose ``finalize`` builds one);
* ``trajectory``  — per-round trace: the handed-back θ for server
  transports, the strategy's ``round_metric`` for update transports, the
  residual history for admm_consensus;
* ``ledger``      — byte-accurate ``CommLedger`` under the paper's strict
  client-server cost model (a LIST of per-scenario ledgers under the
  sweep executor);
* ``metrics``     — the strategy's summary dict, plus engine extras:
  ``uplink_bytes_per_round`` / ``downlink_bytes_per_round`` (numpy),
  transport extras (e.g. the full ``ADMMResult``), and ``carry`` — an
  opaque resume token accepted by a later ``fit(..., carry=...)``.

Under the sweep executor every result field gains a leading S (scenario)
axis: ``theta`` is (S, …), ``trajectory`` is (S, T), the per-round byte
arrays are (S, T), and ``ledger`` is a list of S ``CommLedger``s.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import numpy as np

from repro.core.allreduce import CommLedger
from repro.api.executor import Executor, make_executor
from repro.api.faults import FaultPlan, make_fault_plan
from repro.api.strategy import Strategy
from repro.api.transport import Transport, make_transport
from repro.api.wire import Wire, make_wire
from repro.telemetry import trace as _trace

PyTree = Any


def _jsonable(v, _size_cap: int = 100_000):
    """Best-effort JSON conversion: primitives pass, arrays become lists
    (or a shape/dtype placeholder past ``_size_cap`` elements), anything
    else becomes ``"<TypeName>"``."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if hasattr(v, "dtype") and hasattr(v, "shape"):  # numpy / jax array
        arr = np.asarray(v)
        if arr.ndim == 0:
            return arr.item()
        if arr.size > _size_cap:
            return f"<ndarray shape={arr.shape} dtype={str(arr.dtype)}>"
        return arr.tolist()
    return f"<{type(v).__name__}>"


class FitResult(NamedTuple):
    theta: PyTree
    trajectory: PyTree
    ledger: CommLedger | list
    metrics: dict

    def metrics_json(self) -> dict:
        """``metrics`` as a JSON-serializable dict: drops the opaque
        ``"carry"`` resume token, converts arrays to lists, and strings
        anything non-serializable (e.g. ``"serve_engine"`` →
        ``"<ServeEngine>"``).  This is what ``benchmarks/`` writers and
        ``RunReport`` persist."""
        return {
            k: _jsonable(v) for k, v in self.metrics.items() if k != "carry"
        }


def _total(a: np.ndarray) -> int:
    """Exact byte total: int64 accumulation for integer counts, f64 for
    the (small) value-dependent traced counts."""
    if np.issubdtype(a.dtype, np.integer):
        return int(a.sum(dtype=np.int64))
    return int(round(float(a.sum(dtype=np.float64))))


def fit(
    strategy: Strategy,
    data: PyTree = None,
    *,
    transport: str | Transport = "sequential_server",
    wire: str | Wire = "dense",
    executor: str | Executor = "local",
    sweep: dict | None = None,
    schedule=None,
    steps: int | None = None,
    stream: PyTree = None,
    theta0: PyTree = None,
    carry=None,
    faults: FaultPlan | None = None,
    tag: str = "fit",
    tracer=None,
    trace: str | None = None,
    **transport_options,
) -> FitResult:
    """Train ``strategy`` on ``data`` under a transport, a wire and an
    executor.

    Args:
      strategy: the per-node learner F^(k) (see ``repro.api.strategy``).
      data: fixed sharded data (leading node axis), or None for stream- or
        closure-based strategies.
      transport: one of ``sequential_server`` / ``stale_server`` /
        ``delay_line`` / ``allreduce`` / ``admm_consensus``, or a
        ``Transport`` instance.
      wire: ``"dense"``, ``"topk:<f>[+ef]"``, ``"thresh:<τ>[+ef]"``,
        ``"int8[+ef]"``, the privacy wires ``"dp:<clip>,<sigma>"`` /
        ``"secagg"``, a ``">"``-chain of those
        (``"dp:1.0,0.5>topk:0.1+ef"``), or a ``Wire``.
      executor: ``"local"`` (stacked scan), ``"mesh"`` / ``"multipod"``
        (shard_map node placement; or a configured ``MeshExecutor(mesh)``
        / ``MultiPodExecutor(mesh, ...)``), an
        ``api.SweepExecutor({...}, inner=...)`` scenario batch, or the
        composed spec strings ``"sweep"`` / ``"mesh+sweep"`` /
        ``"multipod+sweep"`` whose scenario values arrive via ``sweep=``.
        See ``docs/EXECUTORS.md`` for the compatibility matrix.
      sweep: scenario parameters for the string sweep specs, e.g.
        ``fit(..., executor="mesh+sweep", sweep={"lr": jnp.asarray(
        [0.02, 0.1])})`` — same keys ``api.SweepExecutor`` accepts.
      schedule: int32 contact schedule (server transports; see
        ``repro.core.schedules``).
      steps: number of rounds (update/consensus transports).
      stream: optional pytree with a leading time axis scanned as the
        per-round batch (update transports).
      theta0: initial parameter; defaults to ``strategy.init_theta(data)``.
      carry: resume token from a previous ``FitResult.metrics["carry"]``.
      faults: optional ``repro.api.faults.FaultPlan`` — seeded per-round
        node dropout / straggler lag / quorum model threaded through the
        transport as masked participation (see ``docs/FAULTS.md``).
        ``sweep={"dropout_p": ...}`` sweeps the plan's threshold against
        its shared draws.
      tracer: optional ``repro.telemetry.trace.Tracer``.  Installed as
        the ambient tracer for the whole run, so the engine's loop /
        ledger spans, the executors' dispatch + program-cache spans, and
        (under ``executor="serve"``) the serving engine's spans all land
        on one timeline.  All spans are host-side: a traced fit runs the
        same compiled program and returns bit-identical results
        (``tests/test_trace.py``).  No tracer → zero overhead.
      trace: ``"phases"`` (requires ``tracer``) additionally recovers
        per-phase DEVICE timings — local-step, wire encode, per-hop
        collective, stats completion — by replaying standalone
        ``jax.block_until_ready``-fenced probe programs at the run's
        real shapes AFTER the fit completes.  The fit program itself is
        untouched, so ``trace="phases"`` is bit-exact by construction.
      transport_options: transport-specific (``staleness=...`` for
        delay_line; ``rho``/``g``/``g_lam`` for admm_consensus).
    """
    if trace not in (None, "phases"):
        raise ValueError(f"trace must be None or 'phases', got {trace!r}")
    if trace == "phases" and tracer is None:
        raise ValueError("trace='phases' requires a tracer=Tracer()")
    with _trace.activated(tracer):
        return _fit_traced(
            strategy, data, wire=wire, transport=transport,
            executor=executor, sweep=sweep, schedule=schedule, steps=steps,
            stream=stream, theta0=theta0, carry=carry, faults=faults,
            tag=tag, tracer=tracer, trace=trace,
            transport_options=transport_options,
        )


def _fit_traced(
    strategy, data, *, wire, transport, executor, sweep, schedule, steps,
    stream, theta0, carry, faults, tag, tracer, trace, transport_options,
) -> FitResult:
    w = make_wire(wire)
    tr = make_transport(transport, **transport_options)
    ex = make_executor(executor, sweep_params=sweep)
    plan = make_fault_plan(faults)
    with _trace.span(
        "fit/loop", transport=tr.name, wire=w.name, executor=ex.name, tag=tag
    ):
        raw = tr.run(
            strategy, data,
            wire=w, schedule=schedule, steps=steps, stream=stream,
            theta0=theta0, carry=carry, executor=ex, faults=plan,
        )
        if tracer is not None:
            # fence so the loop span covers device completion, not just
            # async dispatch — a pure wait, results unchanged
            jax.block_until_ready(raw.theta)

    if trace == "phases":
        from repro.telemetry import phases as _phases  # lazy: jax-heavy

        _phases.profile_phases(
            tracer, strategy, data,
            wire=w, transport=tr, executor=ex,
            schedule=schedule, steps=steps, stream=stream, theta0=theta0,
        )

    ups = np.asarray(raw.uplink)
    downs = np.asarray(raw.downlink)
    # topology-aware executors decompose the flat totals by reduction
    # tier (intra-pod vs inter-pod), priced per hop — same totals, now
    # attributed to the link each byte crossed
    hop_split = ex.ledger_hops(strategy, data)

    def materialize(u: np.ndarray, d: np.ndarray, suffix: str = "") -> CommLedger:
        led = CommLedger()
        if strategy.init_rounds and carry is None:
            K = strategy.num_nodes(data)
            theta_like = (
                ex.scenario_template(raw.theta) if theta0 is None else theta0
            )
            for _ in range(strategy.init_rounds):
                led.record_allreduce(theta_like, K, tag=f"{tag}/init")
        T = int(u.shape[0])
        up_tot, down_tot = _total(u), _total(d)
        led.uplink_bytes += up_tot
        led.downlink_bytes += down_tot
        led.rounds += raw.rounds_per_step * T
        led.events.append(
            (raw.event_kind, f"{tag}{suffix}[0:{T}]", up_tot + down_tot)
        )
        if hop_split:
            led.attribute_hops(hop_split)
        return led

    S = ex.num_scenarios
    with _trace.span("fit/ledger", scenarios=S):
        if S is None:
            ledger = materialize(ups, downs)
        else:
            ledger = [
                materialize(ups[s], downs[s], f"/s{s}") for s in range(S)
            ]
    with _trace.span("fit/metrics"):
        if S is None:
            metrics = dict(strategy.summary(raw.theta, data))
        else:
            try:
                batched = jax.vmap(lambda th: strategy.summary(th, data))(
                    raw.theta
                )
                metrics = {k: np.asarray(v) for k, v in batched.items()}
            except Exception:  # summaries need not be vmappable — skip
                metrics = {}
    metrics.update(raw.extras)
    metrics["uplink_bytes_per_round"] = ups
    metrics["downlink_bytes_per_round"] = downs
    metrics["transport"] = tr.name
    metrics["wire"] = w.name
    metrics["executor"] = ex.name
    metrics["carry"] = raw.carry
    if hasattr(w, "kernel_report"):
        # which leaves the wire's Pallas kernels actually covered vs the
        # <256/non-f32 fallback — no more silent fallbacks
        metrics["wire_kernel_hits"] = w.kernel_report(
            ex.scenario_template(raw.theta)
        )
    metrics.update(ex.extra_metrics())  # e.g. ServingExecutor's live engine
    return FitResult(
        theta=raw.theta, trajectory=raw.trajectory, ledger=ledger, metrics=metrics
    )
