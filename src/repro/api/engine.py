"""``repro.api.fit`` — one entry point for every distributed trainer.

    fit(strategy, data, transport=..., wire=..., schedule=...)

runs any (strategy × transport × wire) combination inside one
jit/scan-able engine and returns a uniform ``FitResult``.  The engine
owns what every historical entry point used to reimplement by hand:
the scan loop (via the transport), message encoding (via the wire), and
``CommLedger`` byte accounting (materialized here from the per-round
byte counts the transport/wire emitted).

``FitResult`` fields:

* ``theta``       — the final parameter (or model pytree, for strategies
  whose ``finalize`` builds one);
* ``trajectory``  — per-round trace: the handed-back θ for server
  transports, the strategy's ``round_metric`` for update transports, the
  residual history for admm_consensus;
* ``ledger``      — byte-accurate ``CommLedger`` under the paper's strict
  client-server cost model;
* ``metrics``     — the strategy's summary dict, plus engine extras:
  ``uplink_bytes_per_round`` / ``downlink_bytes_per_round`` (numpy),
  transport extras (e.g. the full ``ADMMResult``), and ``carry`` — an
  opaque resume token accepted by a later ``fit(..., carry=...)``.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import numpy as np

from repro.core.allreduce import CommLedger
from repro.api.strategy import Strategy
from repro.api.transport import Transport, make_transport
from repro.api.wire import Wire, make_wire

PyTree = Any


class FitResult(NamedTuple):
    theta: PyTree
    trajectory: PyTree
    ledger: CommLedger
    metrics: dict


def fit(
    strategy: Strategy,
    data: PyTree = None,
    *,
    transport: str | Transport = "sequential_server",
    wire: str | Wire = "dense",
    schedule=None,
    steps: int | None = None,
    stream: PyTree = None,
    theta0: PyTree = None,
    carry=None,
    tag: str = "fit",
    **transport_options,
) -> FitResult:
    """Train ``strategy`` on ``data`` under a transport and a wire.

    Args:
      strategy: the per-node learner F^(k) (see ``repro.api.strategy``).
      data: fixed sharded data (leading node axis), or None for stream- or
        closure-based strategies.
      transport: one of ``sequential_server`` / ``stale_server`` /
        ``delay_line`` / ``allreduce`` / ``admm_consensus``, or a
        ``Transport`` instance.
      wire: ``"dense"``, ``"topk:<f>[+ef]"``, ``"int8[+ef]"`` or a ``Wire``.
      schedule: int32 contact schedule (server transports; see
        ``repro.core.schedules``).
      steps: number of rounds (update/consensus transports).
      stream: optional pytree with a leading time axis scanned as the
        per-round batch (update transports).
      theta0: initial parameter; defaults to ``strategy.init_theta(data)``.
      carry: resume token from a previous ``FitResult.metrics["carry"]``.
      transport_options: transport-specific (``staleness=...`` for
        delay_line; ``rho``/``g``/``g_lam`` for admm_consensus).
    """
    w = make_wire(wire)
    tr = make_transport(transport, **transport_options)
    raw = tr.run(
        strategy, data,
        wire=w, schedule=schedule, steps=steps, stream=stream,
        theta0=theta0, carry=carry,
    )

    ledger = CommLedger()
    if strategy.init_rounds and carry is None:
        K = strategy.num_nodes(data)
        theta_like = raw.theta if theta0 is None else theta0
        for _ in range(strategy.init_rounds):
            ledger.record_allreduce(theta_like, K, tag=f"{tag}/init")
    ups = np.asarray(raw.uplink)
    downs = np.asarray(raw.downlink)
    for t in range(ups.shape[0]):
        up, down = int(ups[t]), int(downs[t])
        ledger.uplink_bytes += up
        ledger.downlink_bytes += down
        ledger.rounds += raw.rounds_per_step
        ledger.events.append((raw.event_kind, f"{tag}[{t}]", up + down))

    metrics = dict(strategy.summary(raw.theta, data))
    metrics.update(raw.extras)
    metrics["uplink_bytes_per_round"] = ups
    metrics["downlink_bytes_per_round"] = downs
    metrics["transport"] = tr.name
    metrics["wire"] = w.name
    metrics["carry"] = raw.carry
    return FitResult(
        theta=raw.theta, trajectory=raw.trajectory, ledger=ledger, metrics=metrics
    )
