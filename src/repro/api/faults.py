"""Fault layer — client-fleet realism for the §5 deployment story.

The paper's motivating clients are phones in a hospital study: they drop
out, they straggle, and a server round proceeds once enough of them have
responded.  Every ``repro.api.fit`` used to assume K reliable identical
nodes; a :class:`FaultPlan` restores the fleet model as a *seeded,
declarative, per-round* schedule the engine threads through the existing
transports:

* **dropout** — each round, each node independently fails to respond
  with probability ``dropout_p``.  A dropped node's message is masked out
  of the aggregate (participation masking through the stock
  ``aggregate``/``mask_to_root`` machinery), its wire state (e.g. EF
  residuals, DP noise counters) is frozen, and it costs zero uplink
  bytes — the ledger meters only surviving participants.
* **straggler** — each node draws an integer lag in ``[0, straggler]``
  per round; the round's effective staleness is the max lag over the
  *surviving* nodes (the round completes when the slowest live node
  responds), riding ``core.staleness.delay_push_read`` on a delay line
  deepened by ``straggler`` slots.
* **quorum** — a round commits only when at least ``quorum`` nodes
  responded.  Below quorum the round aborts: θ, strategy state, wire
  state and the delay line all roll back (the server discards the round);
  survivors' uplink bytes are still metered (their pushes crossed the
  wire) but no downlink happens.

Determinism and placement: all draws are host-side numpy arrays generated
from ``seed`` (counter-addressed, so resuming from a carry mid-plan
replays the identical schedule) and enter the compiled step as jit
*arguments* — per-round participation masks are data, like PR 9's block
tables, so round-varying faults never retrace, and the mask logic is
replicated across shards, keeping local / mesh / multipod placements
consistent.  ``dropout_p`` itself is a plain attribute, which makes it
sweepable: the sweep executor rebinds it per scenario against the SHARED
uniform draws (inverse-CDF coupling), so S dropout levels ride one
executable — ``fit(..., faults=FaultPlan(seed=0), executor="mesh+sweep",
sweep={"dropout_p": jnp.asarray([0.0, 0.2, 0.5])})``.

See ``docs/FAULTS.md`` for the full semantics and the compat matrix.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import numpy as np

PyTree = Any

#: numpy SeedSequence stream tags — keep draw families independent
_STREAM_UNIFORM = 1
_STREAM_LAG = 2


class FaultDraws(NamedTuple):
    """Host-side per-round draws for a window of rounds (jit arguments).

    ``u`` are uniforms in [0, 1): node (t, k) drops iff ``u[t, k] <
    dropout_p``, so participation is a pure comparison against a (possibly
    swept, traced) scalar.  ``lag`` are integer straggler lags in
    ``[0, straggler]``.
    """

    u: np.ndarray  # (T, K) float32
    lag: np.ndarray  # (T, K) int32


class FaultCarry(NamedTuple):
    """Resume token for a faulted fit: the transport's own carry plus the
    plan round offset, so ``fit(..., carry=...)`` replays the draw stream
    from where the previous run stopped — mid-plan resume is bit-exact
    with the uninterrupted run."""

    inner: Any
    next_round: int


class FaultPlan:
    """Seeded declarative fault model for one fit (see module docstring).

    Args:
      seed: base seed for all draws (dropout uniforms, straggler lags).
      dropout_p: per-round per-node drop probability in [0, 1].  A plain
        attribute — the sweep executor rebinds it per scenario
        (``sweep={"dropout_p": ...}``) against shared draws.
      straggler: max per-node integer lag per round (0 = no stragglers).
        Update transports deepen their delay line by this many slots and
        read at ``base_staleness + max(live lags)``.
      quorum: minimum surviving responders for a round to commit, or
        None to commit every round regardless of survivors.
    """

    def __init__(
        self,
        seed: int,
        *,
        dropout_p: float = 0.0,
        straggler: int = 0,
        quorum: int | None = None,
    ):
        if not 0.0 <= float(dropout_p) <= 1.0:
            raise ValueError(f"dropout_p must be in [0, 1], got {dropout_p}")
        if int(straggler) < 0:
            raise ValueError(f"straggler must be >= 0, got {straggler}")
        if quorum is not None and int(quorum) < 1:
            raise ValueError(f"quorum must be >= 1 (or None), got {quorum}")
        self.seed = int(seed)
        self.dropout_p = float(dropout_p)
        self.straggler = int(straggler)
        self.quorum = None if quorum is None else int(quorum)

    def draws(self, start_round: int, rounds: int, num_nodes: int) -> FaultDraws:
        """Per-round draws for rounds ``[start_round, start_round+rounds)``.

        Counter-addressed: the draws for round t are identical whether the
        window starts at 0 or resumes at t, so a carry-resumed fit sees
        the same schedule the uninterrupted fit would have.
        """
        stop = start_round + rounds
        rng_u = np.random.default_rng([self.seed, _STREAM_UNIFORM])
        u = rng_u.random((stop, num_nodes), dtype=np.float32)[start_round:]
        rng_l = np.random.default_rng([self.seed, _STREAM_LAG])
        lag = rng_l.integers(
            0, self.straggler + 1, size=(stop, num_nodes), dtype=np.int32
        )[start_round:]
        return FaultDraws(u=u, lag=lag)

    def cache_token(self, *, dropout_swept: bool = False):
        """Fingerprint of everything this plan bakes into a traced step.

        The draws themselves are jit arguments (never baked); what shapes
        the trace is the dropout threshold (unless swept — then it is a
        traced per-scenario value), the straggler depth and the quorum
        gate.  The seed deliberately does NOT key the program cache:
        plans differing only in seed share one compiled program.
        """
        return (
            "faults",
            None if dropout_swept else self.dropout_p,
            self.straggler,
            self.quorum,
        )

    def describe(self) -> dict:
        return {
            "seed": self.seed,
            "dropout_p": self.dropout_p,
            "straggler": self.straggler,
            "quorum": self.quorum,
        }

    def __repr__(self) -> str:
        return (
            f"FaultPlan(seed={self.seed}, dropout_p={self.dropout_p}, "
            f"straggler={self.straggler}, quorum={self.quorum})"
        )


def make_fault_plan(spec: "FaultPlan | None") -> "FaultPlan | None":
    """Engine-side resolution hook (mirrors ``make_wire``/``make_transport``)."""
    if spec is None or isinstance(spec, FaultPlan):
        return spec
    raise TypeError(
        f"faults= takes a repro.api.faults.FaultPlan or None, got {type(spec)!r}"
    )
