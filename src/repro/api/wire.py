"""Wire layer — WHAT crosses the network and what it costs.

The paper's recurring evaluation axis is communication overhead; its §5
cites Li et al.'s parameter server [37] whose key mechanism is *filtering*
pushed updates.  In the unified API the wire is an orthogonal protocol:
a ``Wire`` decides how a push is encoded (dense, top-k sparsified, int8
quantized, each optionally wrapped in error feedback) and reports the
byte cost of every message, so ``CommLedger`` accounting no longer has to
be threaded by hand at each call site — the engine collects the per-round
byte counts emitted here and materializes the ledger.

Two encode entry points, one per transport family:

* ``encode_push`` — server transports (§5 protocol).  The node pushes the
  *delta* it computed on top of the handed-off parameter; the server
  reconstructs θ_push = θ_start + decode(Δ).  The dense wire passes the
  new θ through untouched (bit-exact with ``core.server.run_protocol``).
* ``encode_updates`` — update transports (allreduce / delay line).  The
  per-node messages (gradients, statistics) are encoded before
  aggregation; error-feedback residuals are carried per node.

Compressed wires assume messages are shaped like θ (true for gradient and
delta pushes); strategies with semantic compression (e.g. the cascade
SVM's SVs-only push) override the byte accounting hooks instead.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.compression import (
    Compressed,
    _kernel_eligible,
    _leaf_topk_mask,
    int8_compress,
    kernel_plan,
    threshold_compress,
    topk_compress,
)
from repro.utils.tree import tree_add, tree_bytes, tree_sub

PyTree = Any


class Wire:
    """Base wire: dense — push exactly what the strategy produced.

    A wire is stateless Python configuration + a per-run pytree state
    (``init_state``); the encode entry points run INSIDE the executor's
    placed program, so under a mesh executor compression executes per
    shard and under a sweep a rebindable attribute (``ThresholdWire.tau``)
    can differ per scenario within one executable::

        res = api.fit(strategy, data, transport="allreduce", steps=100,
                      wire="topk:0.1+ef")
        res.ledger.uplink_bytes    # metered through the wire, not by hand
    """

    name = "dense"
    #: capability flag: True when encode is the identity (no information
    #: loss).  Transports whose algorithm would CHANGE under compression
    #: (e.g. admm_consensus) gate on this instead of the wire's type/name.
    lossless = True

    def init_state(self, theta: PyTree, num_nodes: int, *, stacked: bool = True):
        """Per-run wire state (e.g. error-feedback residuals); () if none."""
        return ()

    def measure(self, tree: PyTree) -> int:
        """Dense byte size of ``tree`` — the cost of an uncompressed copy."""
        return tree_bytes(tree)

    def push_bytes(self, theta: PyTree) -> int | None:
        """Static per-push byte cost for θ-shaped messages, or None when the
        cost is value-dependent.  Transports use a static cost to keep byte
        counters out of the (float32) scan so the ledger stays exact for
        arbitrarily large models."""
        return self.measure(theta)

    def encode_push(self, wstate, k, theta_start: PyTree, theta_new: PyTree):
        """Encode one §5 contact push.  Returns (wstate, θ_push, up_bytes)."""
        return wstate, theta_new, jnp.asarray(float(self.measure(theta_new)))

    def encode_updates(self, wstate, msgs: PyTree, *, stacked: bool = True):
        """Encode the per-round update messages.  Returns
        (wstate, msgs_hat, up_bytes) where ``up_bytes`` sums all nodes."""
        return wstate, msgs, jnp.asarray(float(tree_bytes(msgs)))

    def cache_token(self):
        """Hashable fingerprint of everything that shapes this wire's
        traced encode, for the executor program cache.  Subclasses whose
        trace depends on more than the name (thresholds, kernel gating)
        must extend it."""
        return (type(self).__name__, self.name)


class DenseWire(Wire):
    pass


class CompressedWire(Wire):
    """Compression stack from ``core.compression`` + optional error feedback.

    ``compressor`` maps a pytree to a ``Compressed`` (decoded tree + wire
    bytes).  With ``error_feedback`` the residual of whatever the
    compressor dropped is carried per node and added to the next push —
    the EF-SGD construction that preserves the non-distributed rate::

        wire = api.make_wire("topk:0.05+ef")   # or int8[+ef], thresh:<τ>[+ef]
        wire = api.CompressedWire(my_codec, error_feedback=True, name="mine")
    """

    lossless = False

    def __init__(
        self,
        compressor: Callable[[PyTree], Compressed],
        *,
        error_feedback: bool = False,
        name: str = "compressed",
    ):
        self.compressor = compressor
        self.error_feedback = error_feedback
        self.name = name
        self._pb_cache: dict = {}

    def init_state(self, theta: PyTree, num_nodes: int, *, stacked: bool = True):
        if not self.error_feedback:
            return ()
        if stacked:
            return jax.tree.map(
                lambda p: jnp.zeros((num_nodes,) + p.shape, dtype=p.dtype), theta
            )
        return jax.tree.map(jnp.zeros_like, theta)

    def push_bytes(self, theta: PyTree) -> int | None:
        # Both built-in codecs (top-k fraction, int8) price a push from
        # shapes alone, so one eager evaluation on zeros gives the exact
        # static cost — memoized per leaf signature so repeated fits on
        # the same model don't re-run the codec eagerly every call.
        key = tuple((str(x.dtype), tuple(x.shape)) for x in jax.tree.leaves(theta))
        if key not in self._pb_cache:
            zeros = jax.tree.map(jnp.zeros_like, theta)
            self._pb_cache[key] = int(float(self.compressor(zeros).wire_bytes))
        return self._pb_cache[key]

    def encode_push(self, wstate, k, theta_start, theta_new):
        delta = tree_sub(theta_new, theta_start)
        if self.error_feedback:
            r_k = jax.tree.map(lambda b: b[k], wstate)
            corrected = tree_add(delta, r_k)
            comp = self.compressor(corrected)
            wstate = jax.tree.map(
                lambda b, c, d: b.at[k].set(c - d), wstate, corrected, comp.tree
            )
        else:
            comp = self.compressor(delta)
        theta_push = tree_add(theta_start, comp.tree)
        return wstate, theta_push, comp.wire_bytes

    def encode_updates(self, wstate, msgs, *, stacked: bool = True):
        if not stacked:
            if self.error_feedback:
                corrected = tree_add(msgs, wstate)
                comp = self.compressor(corrected)
                return tree_sub(corrected, comp.tree), comp.tree, comp.wire_bytes
            comp = self.compressor(msgs)
            return wstate, comp.tree, comp.wire_bytes
        if self.error_feedback:

            def one(r, m):
                corrected = tree_add(m, r)
                comp = self.compressor(corrected)
                return tree_sub(corrected, comp.tree), comp.tree, comp.wire_bytes

            new_res, msgs_hat, nb = jax.vmap(one)(wstate, msgs)
            return new_res, msgs_hat, jnp.sum(nb)
        comp = jax.vmap(self.compressor)(msgs)
        return wstate, comp.tree, jnp.sum(comp.wire_bytes)


class ThresholdWire(CompressedWire):
    """Magnitude-threshold sparsifier: keep entries with ``|x| ≥ tau``.

    The kept COUNT is value-dependent but every compiled shape is static
    (dense-with-zeros on device; only the metered byte count traces), so
    — unlike ``topk:<f>``, whose k is baked into compiled shapes — the
    compression ratio is sweepable: ``tau`` is a plain attribute the
    sweep executor rebinds per scenario
    (``SweepExecutor({"tau": jnp.asarray([...])})``), and S thresholds
    share ONE executable.  The per-push byte cost is data-dependent, so
    the ledger takes the traced per-round counts instead of a static
    price.
    """

    def __init__(self, tau: float, *, error_feedback: bool = False):
        super().__init__(
            self._compress,
            error_feedback=error_feedback,
            name=f"thresh:{tau}" + ("+ef" if error_feedback else ""),
        )
        self.tau = tau

    def _compress(self, tree):
        # reads self.tau at trace time, so a swept (traced) threshold
        # flows straight into the codec
        return threshold_compress(tree, self.tau)

    def push_bytes(self, theta: PyTree) -> int | None:
        return None  # value-dependent — no static per-push cost

    def cache_token(self):
        # tau is a plain attribute users may mutate between fits; the
        # non-swept value is baked into the trace, so it must key the cache
        return (type(self).__name__, self.name, float(self.tau))


class _FusedWire(CompressedWire):
    """Compressed wire with a fused Pallas encode path.

    ``use_kernel`` is tri-state: ``"auto"`` flips the kernel path on only
    when the default backend is TPU (interpret-mode Pallas on CPU is
    correct but slower than jnp); ``True``/``False`` force it — tests
    force ``True`` to exercise the kernels off-TPU.  The kernel and
    reference paths are bit-equal by construction (same formulas, and the
    per-leaf <256/non-f32 fallback IS the reference), so flipping the
    knob never changes a fit result, only the pass structure.
    ``kernel_report(theta)`` says which leaves take which path — the
    engine surfaces it as ``FitResult.metrics["wire_kernel_hits"]`` so a
    benchmark claiming kernel speed can't silently be on the fallback.
    """

    def __init__(self, compressor, *, error_feedback, name, use_kernel="auto"):
        super().__init__(compressor, error_feedback=error_feedback, name=name)
        self.use_kernel = use_kernel

    def _kernel_active(self) -> bool:
        if self.use_kernel == "auto":
            return jax.default_backend() == "tpu"
        return bool(self.use_kernel)

    def kernel_report(self, theta: PyTree) -> dict:
        plan = kernel_plan(theta)
        plan["active"] = self._kernel_active()
        plan["wire"] = self.name
        return plan

    def cache_token(self):
        return (type(self).__name__, self.name, self._kernel_active())

    def _encode_leaf(self, m, r):
        """One leaf for one node → (encoded, new_residual | None)."""
        raise NotImplementedError

    def _encode_tree(self, m, r):
        """One node's whole tree → (msgs_hat, new_residual | None)."""
        treedef = jax.tree.structure(m)
        leaves_m = jax.tree.leaves(m)
        leaves_r = jax.tree.leaves(r) if r is not None else [None] * len(leaves_m)
        outs = [self._encode_leaf(mm, rr) for mm, rr in zip(leaves_m, leaves_r)]
        hat = treedef.unflatten([o[0] for o in outs])
        if r is None:
            return hat, None
        return hat, treedef.unflatten([o[1] for o in outs])

    def _per_push_bytes(self, tree: PyTree) -> float:
        """Static byte cost of one node's push (mirrors the codec)."""
        raise NotImplementedError

    def encode_updates(self, wstate, msgs, *, stacked: bool = True):
        if not self._kernel_active():
            return super().encode_updates(wstate, msgs, stacked=stacked)
        if not stacked:
            res = wstate if self.error_feedback else None
            hat, new_res = self._encode_tree(msgs, res)
            nb = jnp.asarray(float(self._per_push_bytes(msgs)))
            return (new_res if self.error_feedback else wstate), hat, nb
        # Per-node encode via scan (not vmap): each iteration IS the
        # single-node program, so the Pallas calls run un-batched and the
        # stacked result matches the vmapped reference row-for-row.
        K = jax.tree.leaves(msgs)[0].shape[0]
        per = jnp.asarray(float(self._per_push_bytes(jax.tree.map(lambda x: x[0], msgs))))
        up = jnp.sum(jnp.full((K,), per))  # same reduce as the vmapped sum
        if self.error_feedback:

            def body(_, rm):
                r, m = rm
                hat, new_r = self._encode_tree(m, r)
                return (), (new_r, hat)

            _, (new_res, msgs_hat) = jax.lax.scan(body, (), (wstate, msgs))
            return new_res, msgs_hat, up

        def body(_, m):
            return (), self._encode_tree(m, None)[0]

        _, msgs_hat = jax.lax.scan(body, (), msgs)
        return wstate, msgs_hat, up


class TopKWire(_FusedWire):
    """Top-k wire whose encode (threshold select + mask + EF residual +
    survivor count) runs as ONE fused Pallas pass per eligible leaf."""

    def __init__(self, fraction: float, *, error_feedback: bool = False,
                 use_kernel="auto"):
        super().__init__(
            partial(topk_compress, fraction=fraction),
            error_feedback=error_feedback,
            name=f"topk:{fraction}" + ("+ef" if error_feedback else ""),
            use_kernel=use_kernel,
        )
        self.fraction = fraction

    def _encode_leaf(self, m, r):
        k = max(1, int(round(self.fraction * m.size)))
        if _kernel_eligible(m):
            from repro.kernels.topk_compress import ops as tk_ops

            out, res, _count = tk_ops.topk_encode(m, r, k=k)
            return out, res
        # reference fallback — identical formulas, so mixed kernel /
        # fallback leaves stay bit-equal to the all-reference path
        c = m if r is None else m + r
        o = c * _leaf_topk_mask(c, k)
        return o, (None if r is None else c - o)

    def _per_push_bytes(self, tree):
        return float(sum(
            max(1, int(round(self.fraction * x.size))) * (4 + x.dtype.itemsize)
            for x in jax.tree.leaves(tree)
        ))


class Int8Wire(_FusedWire):
    """Int8 wire: fused absmax + quantize→dequantize kernels per eligible
    leaf, instead of three fp32 jnp passes."""

    def __init__(self, *, error_feedback: bool = False, use_kernel="auto"):
        super().__init__(
            int8_compress,
            error_feedback=error_feedback,
            name="int8" + ("+ef" if error_feedback else ""),
            use_kernel=use_kernel,
        )

    def _encode_leaf(self, m, r):
        c = m if r is None else m + r
        if _kernel_eligible(c):
            from repro.kernels.int8_quant import ops as q8_ops

            out = q8_ops.int8_roundtrip(c)[0]
        else:
            scale = jnp.maximum(jnp.max(jnp.abs(c)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
            out = q.astype(c.dtype) * scale
        return out, (None if r is None else c - out)

    def _per_push_bytes(self, tree):
        return float(sum(x.size * 1 + 4 for x in jax.tree.leaves(tree)))


def make_wire(spec: str | Wire | None) -> Wire:
    """Resolve a wire spec.

    Accepts a ``Wire`` instance, ``None``/"dense", or a string of the form
    ``"<codec>[+ef]"`` with codecs ``topk:<fraction>``, ``thresh:<tau>``
    and ``int8`` — e.g. ``"topk:0.05+ef"`` is top-5% magnitude
    sparsification with error feedback; ``"thresh:0.01"`` keeps entries
    with magnitude ≥ 0.01 (value-dependent ratio, sweepable).
    """
    if spec is None:
        return DenseWire()
    if isinstance(spec, Wire):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"wire spec must be a Wire or str, got {type(spec)!r}")
    if spec == "dense":
        return DenseWire()
    ef = spec.endswith("+ef")
    base = spec[:-3] if ef else spec
    if base.startswith("thresh:"):
        return ThresholdWire(float(base.split(":", 1)[1]), error_feedback=ef)
    if base.startswith("topk:"):
        return TopKWire(float(base.split(":", 1)[1]), error_feedback=ef)
    if base == "int8":
        return Int8Wire(error_feedback=ef)
    raise ValueError(
        f"unknown wire spec {spec!r} — expected 'dense', 'topk:<f>[+ef]', "
        "'thresh:<tau>[+ef]' or 'int8[+ef]'"
    )
