"""Wire layer — WHAT crosses the network and what it costs.

The paper's recurring evaluation axis is communication overhead; its §5
cites Li et al.'s parameter server [37] whose key mechanism is *filtering*
pushed updates.  In the unified API the wire is an orthogonal protocol:
a ``Wire`` decides how a push is encoded (dense, top-k sparsified, int8
quantized, each optionally wrapped in error feedback) and reports the
byte cost of every message, so ``CommLedger`` accounting no longer has to
be threaded by hand at each call site — the engine collects the per-round
byte counts emitted here and materializes the ledger.

Two encode entry points, one per transport family:

* ``encode_push`` — server transports (§5 protocol).  The node pushes the
  *delta* it computed on top of the handed-off parameter; the server
  reconstructs θ_push = θ_start + decode(Δ).  The dense wire passes the
  new θ through untouched (bit-exact with ``core.server.run_protocol``).
* ``encode_updates`` — update transports (allreduce / delay line).  The
  per-node messages (gradients, statistics) are encoded before
  aggregation; error-feedback residuals are carried per node.

Compressed wires assume messages are shaped like θ (true for gradient and
delta pushes); strategies with semantic compression (e.g. the cascade
SVM's SVs-only push) override the byte accounting hooks instead.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.compression import (
    Compressed,
    _kernel_eligible,
    _leaf_topk_mask,
    int8_compress,
    kernel_plan,
    threshold_compress,
    topk_compress,
)
from repro.utils.tree import tree_add, tree_bytes, tree_sub

PyTree = Any


class Wire:
    """Base wire: dense — push exactly what the strategy produced.

    A wire is stateless Python configuration + a per-run pytree state
    (``init_state``); the encode entry points run INSIDE the executor's
    placed program, so under a mesh executor compression executes per
    shard and under a sweep a rebindable attribute (``ThresholdWire.tau``)
    can differ per scenario within one executable::

        res = api.fit(strategy, data, transport="allreduce", steps=100,
                      wire="topk:0.1+ef")
        res.ledger.uplink_bytes    # metered through the wire, not by hand
    """

    name = "dense"
    #: capability flag: True when encode is the identity (no information
    #: loss).  Transports whose algorithm would CHANGE under compression
    #: (e.g. admm_consensus) gate on this instead of the wire's type/name.
    lossless = True
    #: True when this wire re-encodes a payload without changing its size
    #: (secure aggregation masks).  A ``ChainWire`` then keeps the
    #: previous stage's byte count — the masked payload crossing the wire
    #: is exactly as large as what it wraps.
    preserves_bytes = False

    def init_state(self, theta: PyTree, num_nodes: int, *, stacked: bool = True):
        """Per-run wire state (e.g. error-feedback residuals); () if none."""
        return ()

    def measure(self, tree: PyTree) -> int:
        """Dense byte size of ``tree`` — the cost of an uncompressed copy."""
        return tree_bytes(tree)

    def push_bytes(self, theta: PyTree) -> int | None:
        """Static per-push byte cost for θ-shaped messages, or None when the
        cost is value-dependent.  Transports use a static cost to keep byte
        counters out of the (float32) scan so the ledger stays exact for
        arbitrarily large models."""
        return self.measure(theta)

    def encode_push(self, wstate, k, theta_start: PyTree, theta_new: PyTree):
        """Encode one §5 contact push.  Returns (wstate, θ_push, up_bytes)."""
        return wstate, theta_new, jnp.asarray(float(self.measure(theta_new)))

    def encode_updates(self, wstate, msgs: PyTree, *, stacked: bool = True):
        """Encode the per-round update messages.  Returns
        (wstate, msgs_hat, up_bytes) where ``up_bytes`` sums all nodes."""
        return wstate, msgs, jnp.asarray(float(tree_bytes(msgs)))

    def cache_token(self):
        """Hashable fingerprint of everything that shapes this wire's
        traced encode, for the executor program cache.  Subclasses whose
        trace depends on more than the name (thresholds, kernel gating)
        must extend it."""
        return (type(self).__name__, self.name)


class DenseWire(Wire):
    pass


class CompressedWire(Wire):
    """Compression stack from ``core.compression`` + optional error feedback.

    ``compressor`` maps a pytree to a ``Compressed`` (decoded tree + wire
    bytes).  With ``error_feedback`` the residual of whatever the
    compressor dropped is carried per node and added to the next push —
    the EF-SGD construction that preserves the non-distributed rate::

        wire = api.make_wire("topk:0.05+ef")   # or int8[+ef], thresh:<τ>[+ef]
        wire = api.CompressedWire(my_codec, error_feedback=True, name="mine")
    """

    lossless = False

    def __init__(
        self,
        compressor: Callable[[PyTree], Compressed],
        *,
        error_feedback: bool = False,
        name: str = "compressed",
    ):
        self.compressor = compressor
        self.error_feedback = error_feedback
        self.name = name
        self._pb_cache: dict = {}

    def init_state(self, theta: PyTree, num_nodes: int, *, stacked: bool = True):
        if not self.error_feedback:
            return ()
        if stacked:
            return jax.tree.map(
                lambda p: jnp.zeros((num_nodes,) + p.shape, dtype=p.dtype), theta
            )
        return jax.tree.map(jnp.zeros_like, theta)

    def push_bytes(self, theta: PyTree) -> int | None:
        # Both built-in codecs (top-k fraction, int8) price a push from
        # shapes alone, so one eager evaluation on zeros gives the exact
        # static cost — memoized per leaf signature so repeated fits on
        # the same model don't re-run the codec eagerly every call.
        key = tuple((str(x.dtype), tuple(x.shape)) for x in jax.tree.leaves(theta))
        if key not in self._pb_cache:
            zeros = jax.tree.map(jnp.zeros_like, theta)
            self._pb_cache[key] = int(float(self.compressor(zeros).wire_bytes))
        return self._pb_cache[key]

    def encode_push(self, wstate, k, theta_start, theta_new):
        delta = tree_sub(theta_new, theta_start)
        if self.error_feedback:
            r_k = jax.tree.map(lambda b: b[k], wstate)
            corrected = tree_add(delta, r_k)
            comp = self.compressor(corrected)
            wstate = jax.tree.map(
                lambda b, c, d: b.at[k].set(c - d), wstate, corrected, comp.tree
            )
        else:
            comp = self.compressor(delta)
        theta_push = tree_add(theta_start, comp.tree)
        return wstate, theta_push, comp.wire_bytes

    def encode_updates(self, wstate, msgs, *, stacked: bool = True):
        if not stacked:
            if self.error_feedback:
                corrected = tree_add(msgs, wstate)
                comp = self.compressor(corrected)
                return tree_sub(corrected, comp.tree), comp.tree, comp.wire_bytes
            comp = self.compressor(msgs)
            return wstate, comp.tree, comp.wire_bytes
        if self.error_feedback:

            def one(r, m):
                corrected = tree_add(m, r)
                comp = self.compressor(corrected)
                return tree_sub(corrected, comp.tree), comp.tree, comp.wire_bytes

            new_res, msgs_hat, nb = jax.vmap(one)(wstate, msgs)
            return new_res, msgs_hat, jnp.sum(nb)
        comp = jax.vmap(self.compressor)(msgs)
        return wstate, comp.tree, jnp.sum(comp.wire_bytes)


class ThresholdWire(CompressedWire):
    """Magnitude-threshold sparsifier: keep entries with ``|x| ≥ tau``.

    The kept COUNT is value-dependent but every compiled shape is static
    (dense-with-zeros on device; only the metered byte count traces), so
    — unlike ``topk:<f>``, whose k is baked into compiled shapes — the
    compression ratio is sweepable: ``tau`` is a plain attribute the
    sweep executor rebinds per scenario
    (``SweepExecutor({"tau": jnp.asarray([...])})``), and S thresholds
    share ONE executable.  The per-push byte cost is data-dependent, so
    the ledger takes the traced per-round counts instead of a static
    price.
    """

    def __init__(self, tau: float, *, error_feedback: bool = False):
        super().__init__(
            self._compress,
            error_feedback=error_feedback,
            name=f"thresh:{tau}" + ("+ef" if error_feedback else ""),
        )
        self.tau = tau

    def _compress(self, tree):
        # reads self.tau at trace time, so a swept (traced) threshold
        # flows straight into the codec
        return threshold_compress(tree, self.tau)

    def push_bytes(self, theta: PyTree) -> int | None:
        return None  # value-dependent — no static per-push cost

    def cache_token(self):
        # tau is a plain attribute users may mutate between fits; the
        # non-swept value is baked into the trace, so it must key the cache
        return (type(self).__name__, self.name, float(self.tau))


class _FusedWire(CompressedWire):
    """Compressed wire with a fused Pallas encode path.

    ``use_kernel`` is tri-state: ``"auto"`` flips the kernel path on only
    when the default backend is TPU (interpret-mode Pallas on CPU is
    correct but slower than jnp); ``True``/``False`` force it — tests
    force ``True`` to exercise the kernels off-TPU.  The kernel and
    reference paths are bit-equal by construction (same formulas, and the
    per-leaf <256/non-f32 fallback IS the reference), so flipping the
    knob never changes a fit result, only the pass structure.
    ``kernel_report(theta)`` says which leaves take which path — the
    engine surfaces it as ``FitResult.metrics["wire_kernel_hits"]`` so a
    benchmark claiming kernel speed can't silently be on the fallback.
    """

    def __init__(self, compressor, *, error_feedback, name, use_kernel="auto"):
        super().__init__(compressor, error_feedback=error_feedback, name=name)
        self.use_kernel = use_kernel

    def _kernel_active(self) -> bool:
        if self.use_kernel == "auto":
            return jax.default_backend() == "tpu"
        return bool(self.use_kernel)

    def kernel_report(self, theta: PyTree) -> dict:
        plan = kernel_plan(theta)
        plan["active"] = self._kernel_active()
        plan["wire"] = self.name
        return plan

    def cache_token(self):
        return (type(self).__name__, self.name, self._kernel_active())

    def _encode_leaf(self, m, r):
        """One leaf for one node → (encoded, new_residual | None)."""
        raise NotImplementedError

    def _encode_tree(self, m, r):
        """One node's whole tree → (msgs_hat, new_residual | None)."""
        treedef = jax.tree.structure(m)
        leaves_m = jax.tree.leaves(m)
        leaves_r = jax.tree.leaves(r) if r is not None else [None] * len(leaves_m)
        outs = [self._encode_leaf(mm, rr) for mm, rr in zip(leaves_m, leaves_r)]
        hat = treedef.unflatten([o[0] for o in outs])
        if r is None:
            return hat, None
        return hat, treedef.unflatten([o[1] for o in outs])

    def _per_push_bytes(self, tree: PyTree) -> float:
        """Static byte cost of one node's push (mirrors the codec)."""
        raise NotImplementedError

    def encode_updates(self, wstate, msgs, *, stacked: bool = True):
        if not self._kernel_active():
            return super().encode_updates(wstate, msgs, stacked=stacked)
        if not stacked:
            res = wstate if self.error_feedback else None
            hat, new_res = self._encode_tree(msgs, res)
            nb = jnp.asarray(float(self._per_push_bytes(msgs)))
            return (new_res if self.error_feedback else wstate), hat, nb
        # Per-node encode via scan (not vmap): each iteration IS the
        # single-node program, so the Pallas calls run un-batched and the
        # stacked result matches the vmapped reference row-for-row.
        K = jax.tree.leaves(msgs)[0].shape[0]
        per = jnp.asarray(float(self._per_push_bytes(jax.tree.map(lambda x: x[0], msgs))))
        up = jnp.sum(jnp.full((K,), per))  # same reduce as the vmapped sum
        if self.error_feedback:

            def body(_, rm):
                r, m = rm
                hat, new_r = self._encode_tree(m, r)
                return (), (new_r, hat)

            _, (new_res, msgs_hat) = jax.lax.scan(body, (), (wstate, msgs))
            return new_res, msgs_hat, up

        def body(_, m):
            return (), self._encode_tree(m, None)[0]

        _, msgs_hat = jax.lax.scan(body, (), msgs)
        return wstate, msgs_hat, up


class TopKWire(_FusedWire):
    """Top-k wire whose encode (threshold select + mask + EF residual +
    survivor count) runs as ONE fused Pallas pass per eligible leaf."""

    def __init__(self, fraction: float, *, error_feedback: bool = False,
                 use_kernel="auto"):
        super().__init__(
            partial(topk_compress, fraction=fraction),
            error_feedback=error_feedback,
            name=f"topk:{fraction}" + ("+ef" if error_feedback else ""),
            use_kernel=use_kernel,
        )
        self.fraction = fraction

    def _encode_leaf(self, m, r):
        k = max(1, int(round(self.fraction * m.size)))
        if _kernel_eligible(m):
            from repro.kernels.topk_compress import ops as tk_ops

            out, res, _count = tk_ops.topk_encode(m, r, k=k)
            return out, res
        # reference fallback — identical formulas, so mixed kernel /
        # fallback leaves stay bit-equal to the all-reference path
        c = m if r is None else m + r
        o = c * _leaf_topk_mask(c, k)
        return o, (None if r is None else c - o)

    def _per_push_bytes(self, tree):
        return float(sum(
            max(1, int(round(self.fraction * x.size))) * (4 + x.dtype.itemsize)
            for x in jax.tree.leaves(tree)
        ))


class Int8Wire(_FusedWire):
    """Int8 wire: fused absmax + quantize→dequantize kernels per eligible
    leaf, instead of three fp32 jnp passes."""

    def __init__(self, *, error_feedback: bool = False, use_kernel="auto"):
        super().__init__(
            int8_compress,
            error_feedback=error_feedback,
            name="int8" + ("+ef" if error_feedback else ""),
            use_kernel=use_kernel,
        )

    def _encode_leaf(self, m, r):
        c = m if r is None else m + r
        if _kernel_eligible(c):
            from repro.kernels.int8_quant import ops as q8_ops

            out = q8_ops.int8_roundtrip(c)[0]
        else:
            scale = jnp.maximum(jnp.max(jnp.abs(c)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
            out = q.astype(c.dtype) * scale
        return out, (None if r is None else c - out)

    def _per_push_bytes(self, tree):
        return float(sum(x.size * 1 + 4 for x in jax.tree.leaves(tree)))


class DPWire(Wire):
    """Differentially-private uplink: per-node L2 clip + Gaussian noise.

    The Gaussian mechanism on each node's message: the whole-tree update
    is scaled to L2 norm ≤ ``dp_clip`` and perturbed with
    ``N(0, (dp_sigma · dp_clip)²)`` noise per coordinate before it leaves
    the node — the server/aggregate only ever sees the privatized
    message.  Noise keys chain ``fold_in(seed → round counter → GLOBAL
    node index → leaf index)``, so the draw for node k at round t is one
    fixed function of (seed, t, k): placement-invariant (local ≡ mesh ≡
    multipod run the same chain via ``node_global_index``) and
    occupancy-invariant (dead rows under a ``FaultPlan`` don't shift
    anyone else's stream).

    ``dp_clip`` and ``dp_sigma`` are plain attributes, so both are
    sweepable per scenario (``sweep={"dp_sigma": jnp.asarray([...])}``)
    within one executable.  The payload is dense (same shape/dtype as the
    message — noise does not compress), so the ledger meters dense bytes;
    compose with a sparsifier (``"dp:1.0,0.5>topk:0.1+ef"``) to trade
    bytes too.
    """

    lossless = False

    def __init__(self, clip: float, sigma: float, *, seed: int = 0):
        if float(clip) <= 0.0:
            raise ValueError(f"dp clip must be > 0, got {clip}")
        if float(sigma) < 0.0:
            raise ValueError(f"dp sigma must be >= 0, got {sigma}")
        self.dp_clip = float(clip)
        self.dp_sigma = float(sigma)
        self.seed = int(seed)
        self.name = f"dp:{self.dp_clip},{self.dp_sigma}"

    def init_state(self, theta, num_nodes, *, stacked: bool = True):
        # per-node round counters — the only state is WHERE each node is
        # in its noise stream, so resume-from-carry continues the stream
        if stacked:
            return jnp.zeros((num_nodes,), jnp.int32)
        return jnp.asarray(0, jnp.int32)

    def _privatize(self, msg, cnt, gidx):
        """Clip + noise one node's whole-tree message (one (cnt, gidx))."""
        leaves, treedef = jax.tree.flatten(msg)
        sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
        nrm = jnp.sqrt(sq)
        clip = jnp.asarray(self.dp_clip, jnp.float32)
        scale = jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12))
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(self.seed), cnt), gidx
        )
        out = []
        for i, x in enumerate(leaves):
            noise = jax.random.normal(
                jax.random.fold_in(key, i), x.shape, dtype=jnp.float32
            )
            y = x.astype(jnp.float32) * scale + self.dp_sigma * clip * noise
            out.append(y.astype(x.dtype))
        return treedef.unflatten(out)

    def encode_push(self, wstate, k, theta_start, theta_new):
        delta = tree_sub(theta_new, theta_start)
        priv = self._privatize(delta, wstate[k], node_global_index_fn(k))
        theta_push = tree_add(theta_start, priv)
        nb = jnp.asarray(float(self.measure(theta_new)))
        return wstate.at[k].add(1), theta_push, nb

    def encode_updates(self, wstate, msgs, *, stacked: bool = True):
        nb = jnp.asarray(float(tree_bytes(msgs)))
        if not stacked:
            gidx = node_global_index_fn(jnp.asarray(0, jnp.int32))
            return wstate + 1, self._privatize(msgs, wstate, gidx), nb
        k_local = jax.tree.leaves(msgs)[0].shape[0]
        gidx = node_global_index_fn(jnp.arange(k_local, dtype=jnp.int32))
        hat = jax.vmap(self._privatize)(msgs, wstate, gidx)
        return wstate + 1, hat, nb

    def cache_token(self):
        # clip/sigma are baked into the trace when not swept; the seed is
        # baked always (it parameterizes jax.random.key inside the step)
        return (
            type(self).__name__, self.name,
            float(self.dp_clip), float(self.dp_sigma), self.seed,
        )


class SecAggWire(Wire):
    """Secure-aggregation simulation: pairwise antisymmetric uplink masks.

    Bonawitz-style masking: nodes g < j share a seeded pairwise mask
    m_{gj} (keyed ``fold_in(seed → round counter → g → j → leaf)``); node
    g uploads ``x_g + Σ_{j>g} m_{gj} − Σ_{j<g} m_{jg}``.  Summed over all
    K nodes every mask appears once with each sign, so the aggregate
    equals Σ x_g exactly while no individual uplink reveals x_g.

    The real protocol cancels in modular integer arithmetic, where the
    cancellation is EXACT.  Floating-point summation cannot represent
    that (masks would perturb rounding), so this wire simulates the
    protocol algebraically: ``encode_updates`` passes the messages to the
    aggregate unchanged — the bitwise-identical-to-unmasked guarantee is
    by construction, mirroring the exact ℤ_M cancellation — while
    :meth:`uplink_payloads` materializes what each uplink actually
    carries (masked, metered dense).  Tests assert per-node payloads
    differ from the raw messages AND that the payload sum still recovers
    the aggregate to fp tolerance.

    Under a ``FaultPlan`` a dropped node's counter freezes with the rest
    of its wire row; pairwise masks between nodes whose counters
    diverged no longer cancel — which is exactly the real secagg dropout
    problem (Bonawitz et al. solve it with mask-share recovery; this
    simulation documents rather than hides it, see docs/FAULTS.md).
    """

    lossless = True
    preserves_bytes = True

    def __init__(self, *, seed: int = 0):
        self.seed = int(seed)
        self.name = "secagg"

    def init_state(self, theta, num_nodes, *, stacked: bool = True):
        if stacked:
            return jnp.zeros((num_nodes,), jnp.int32)
        return jnp.asarray(0, jnp.int32)

    def _masked(self, msg, cnt, gidx, num_global: int):
        """One node's masked uplink payload (O(K) mask draws per node)."""
        leaves, treedef = jax.tree.flatten(msg)
        kc = jax.random.fold_in(jax.random.key(self.seed), cnt)
        out = []
        for i, x in enumerate(leaves):
            total = jnp.zeros(x.shape, jnp.float32)
            for j in range(num_global):
                lo = jnp.minimum(gidx, j)
                hi = jnp.maximum(gidx, j)
                kp = jax.random.fold_in(
                    jax.random.fold_in(jax.random.fold_in(kc, lo), hi), i
                )
                m = jax.random.normal(kp, x.shape, dtype=jnp.float32)
                sign = jnp.where(
                    gidx < j, 1.0, jnp.where(gidx > j, -1.0, 0.0)
                )
                total = total + sign * m
            out.append((x.astype(jnp.float32) + total).astype(x.dtype))
        return treedef.unflatten(out)

    def uplink_payloads(self, wstate, msgs, *, stacked: bool = True):
        """What each uplink actually carries at the CURRENT round counter
        (the payload ``encode_updates`` meters): message + pairwise mask.
        Same size as the raw message — masking never compresses."""
        if not stacked:
            return self._masked(msgs, wstate, jnp.asarray(0, jnp.int32), 1)
        k_local = jax.tree.leaves(msgs)[0].shape[0]
        num_global = k_local * num_node_shards_fn()
        gidx = node_global_index_fn(jnp.arange(k_local, dtype=jnp.int32))
        return jax.vmap(
            lambda m, c, g: self._masked(m, c, g, num_global)
        )(msgs, wstate, gidx)

    def encode_push(self, wstate, k, theta_start, theta_new):
        raise NotImplementedError(
            "secagg masks only cancel inside an aggregate — use an update "
            "transport (allreduce/delay line); a §5 server contact has "
            "nothing to cancel against"
        )

    def encode_updates(self, wstate, msgs, *, stacked: bool = True):
        # algebraic exact-cancellation: the aggregate-path value IS the
        # unmasked message (see class docstring); the wire crossing is
        # the masked payload, dense-sized, metered here
        nb = jnp.asarray(float(tree_bytes(msgs)))
        return wstate + 1, msgs, nb

    def cache_token(self):
        return (type(self).__name__, self.name, self.seed)


class ChainWire(Wire):
    """Composition of wire stages applied left to right (``"a>b"``).

    Canonical chains: ``"dp:1.0,0.5>topk:0.1+ef"`` (privatize, THEN
    sparsify the private message — EF recycles only already-noised
    residue) and ``"topk:0.1+ef>secagg"`` (sparsify, then mask the
    compressed payload).  Byte metering: each stage re-prices the payload
    except ``preserves_bytes`` stages (secagg), which keep the previous
    stage's count — the chain's cost is the LAST re-pricing stage's.
    """

    def __init__(self, stages):
        stages = tuple(stages)
        if len(stages) < 2:
            raise ValueError("a wire chain needs at least two stages")
        for s in stages:
            if isinstance(s, ChainWire):
                raise ValueError("wire chains do not nest")
        self.stages = stages
        self.name = ">".join(s.name for s in stages)
        self.lossless = all(s.lossless for s in stages)
        self.preserves_bytes = all(s.preserves_bytes for s in stages)

    def init_state(self, theta, num_nodes, *, stacked: bool = True):
        return tuple(
            s.init_state(theta, num_nodes, stacked=stacked)
            for s in self.stages
        )

    def push_bytes(self, theta):
        pb: int | None = self.measure(theta)
        for s in self.stages:
            if not s.preserves_bytes:
                pb = s.push_bytes(theta)  # None propagates: value-dependent
        return pb

    def encode_push(self, wstate, k, theta_start, theta_new):
        new_states = []
        theta, nb = theta_new, jnp.asarray(float(self.measure(theta_new)))
        for s, st in zip(self.stages, wstate):
            st, theta, b = s.encode_push(st, k, theta_start, theta)
            new_states.append(st)
            if not s.preserves_bytes:
                nb = b
        return tuple(new_states), theta, nb

    def encode_updates(self, wstate, msgs, *, stacked: bool = True):
        new_states = []
        nb = jnp.asarray(float(tree_bytes(msgs)))
        for s, st in zip(self.stages, wstate):
            st, msgs, b = s.encode_updates(st, msgs, stacked=stacked)
            new_states.append(st)
            if not s.preserves_bytes:
                nb = b
        return tuple(new_states), msgs, nb

    def cache_token(self):
        return (type(self).__name__,) + tuple(
            s.cache_token() for s in self.stages
        )


def node_global_index_fn(k_local):
    """Late-bound ``executor.node_global_index`` (import cycle guard —
    executor imports nothing from wire, but keeping the edge one-way at
    module import time lets either load first)."""
    from repro.api.executor import node_global_index

    return node_global_index(k_local)


def num_node_shards_fn() -> int:
    from repro.api.executor import num_node_shards

    return num_node_shards()


def make_wire(spec: str | Wire | None) -> Wire:
    """Resolve a wire spec.

    Accepts a ``Wire`` instance, ``None``/"dense", or a string of the form
    ``"<codec>[+ef]"`` with codecs ``topk:<fraction>``, ``thresh:<tau>``,
    ``int8``, ``dp:<clip>,<sigma>`` (L2 clip + Gaussian noise) and
    ``secagg`` (pairwise-mask secure aggregation) — e.g. ``"topk:0.05+ef"``
    is top-5% magnitude sparsification with error feedback;
    ``"thresh:0.01"`` keeps entries with magnitude ≥ 0.01
    (value-dependent ratio, sweepable).  Stages compose left to right
    with ``>``: ``"dp:1.0,0.5>topk:0.1+ef"``.
    """
    if spec is None:
        return DenseWire()
    if isinstance(spec, Wire):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"wire spec must be a Wire or str, got {type(spec)!r}")
    if ">" in spec:
        return ChainWire([make_wire(part) for part in spec.split(">")])
    if spec == "dense":
        return DenseWire()
    ef = spec.endswith("+ef")
    base = spec[:-3] if ef else spec
    if base.startswith("thresh:"):
        return ThresholdWire(float(base.split(":", 1)[1]), error_feedback=ef)
    if base.startswith("topk:"):
        return TopKWire(float(base.split(":", 1)[1]), error_feedback=ef)
    if base == "int8":
        return Int8Wire(error_feedback=ef)
    if base.startswith("dp:"):
        if ef:
            raise ValueError(
                "dp takes no +ef (noise is not a compression residual); "
                "chain it with a sparsifier instead: 'dp:<c>,<s>>topk:<f>+ef'"
            )
        parts = base.split(":", 1)[1].split(",")
        if len(parts) != 2:
            raise ValueError(f"dp wire spec must be 'dp:<clip>,<sigma>', got {spec!r}")
        return DPWire(float(parts[0]), float(parts[1]))
    if base == "secagg":
        if ef:
            raise ValueError("secagg takes no +ef (masking is lossless)")
        return SecAggWire()
    raise ValueError(
        f"unknown wire spec {spec!r} — expected 'dense', 'topk:<f>[+ef]', "
        "'thresh:<tau>[+ef]', 'int8[+ef]', 'dp:<clip>,<sigma>', 'secagg', "
        "or a '>'-chain of those"
    )
