"""Unified distributed-training facade (the paper's §5 thesis as an API).

One client-server protocol — push θ, receive a (possibly stale) handoff —
subsumes sync mini-batch GD, async SGD, and consensus methods.  This
package factors that observation into three orthogonal protocols:

* ``Strategy``  — the per-node learner F^(k) (``repro.api.strategy``);
* ``Transport`` — who talks to whom and when (``repro.api.transport``):
  ``sequential_server`` · ``stale_server`` · ``delay_line`` ·
  ``allreduce`` · ``admm_consensus``;
* ``Wire``      — what crosses the network and what it costs
  (``repro.api.wire``): dense · top-k · int8, each ± error feedback,
  plus the privacy wires dp (clip + Gaussian noise) and secagg
  (pairwise-mask secure aggregation), composable via ``"a>b"`` chains;
* ``FaultPlan`` — seeded client-fleet realism (``repro.api.faults``):
  per-round node dropout, straggler lag, and quorum rounds threaded
  through any update/server transport via ``fit(..., faults=...)``;
* ``Executor``  — WHERE the fit runs (``repro.api.executor``):
  ``local`` stacked scan · ``mesh`` shard_map node placement ·
  ``multipod`` hierarchical ``("pod", "data")`` placement with per-hop
  ``CommLedger`` pricing · ``sweep`` vmapped scenario batch · ``serve``
  local fit handed straight to a ``repro.serve.ServeEngine``
  (train→serve as an executor swap) · composed ``mesh+sweep`` /
  ``multipod+sweep`` — the scenario vmap nested inside the shard
  placement (see ``docs/EXECUTORS.md``).

The single entry point::

    from repro import api
    result = api.fit(strategy, data, transport="stale_server",
                     wire="topk:0.1+ef", schedule=sched)
    result.theta, result.trajectory, result.ledger, result.metrics

runs any (strategy × transport × wire × executor) combination in one
jit/scan-able engine.  See ``docs/API.md`` for the protocol table and the
migration guide from the historical per-algorithm entry points.
"""

from repro.api.engine import FitResult, fit
from repro.api.faults import FaultCarry, FaultPlan, make_fault_plan
from repro.api.executor import (
    COMPOSED_EXECUTORS,
    EXECUTORS,
    Executor,
    LocalExecutor,
    MeshExecutor,
    MultiPodExecutor,
    ServingExecutor,
    SweepExecutor,
    make_executor,
)
from repro.api.strategy import (
    FunctionStrategy,
    GradientDescent,
    LBFGS,
    OptimizerStrategy,
    ProxStrategy,
    Strategy,
)
from repro.api.transport import (
    TRANSPORTS,
    AdmmTransport,
    ServerTransport,
    Transport,
    UpdateTransport,
    make_transport,
)
from repro.api.wire import (
    ChainWire,
    CompressedWire,
    DenseWire,
    DPWire,
    SecAggWire,
    ThresholdWire,
    Wire,
    make_wire,
)

__all__ = [
    "fit",
    "FitResult",
    "Strategy",
    "FunctionStrategy",
    "GradientDescent",
    "LBFGS",
    "ProxStrategy",
    "OptimizerStrategy",
    "Transport",
    "ServerTransport",
    "UpdateTransport",
    "AdmmTransport",
    "TRANSPORTS",
    "make_transport",
    "Wire",
    "DenseWire",
    "CompressedWire",
    "ThresholdWire",
    "DPWire",
    "SecAggWire",
    "ChainWire",
    "make_wire",
    "FaultPlan",
    "FaultCarry",
    "make_fault_plan",
    "Executor",
    "LocalExecutor",
    "MeshExecutor",
    "MultiPodExecutor",
    "ServingExecutor",
    "SweepExecutor",
    "EXECUTORS",
    "COMPOSED_EXECUTORS",
    "make_executor",
]
