"""Transport layer — WHO talks to whom, and when.

Each transport is one client-server topology from the paper, wrapping the
corresponding ``repro.core`` primitive:

* ``sequential_server`` — the §5 central information server with the
  sequential handoff (round-robin ≡ mini-batch GD equivalence); wraps
  ``core.server``.
* ``stale_server``      — the literal §5 protocol text: the pusher
  receives θ_{t-1}; wraps ``core.server``.
* ``allreduce``         — the two-phase central-server Allreduce of §3.1
  ([47]/[5]); wraps ``core.allreduce``.
* ``delay_line``        — the §5 algorithm mapped to SPMD: the aggregated
  update is applied D steps late; wraps ``core.staleness``.
* ``admm_consensus``    — global-variable-consensus ADMM (three-stage
  Douglas-Rachford, two Allreduces per iteration); wraps ``core.admm``.

A transport's ``run`` builds the per-round step; it calls back into the
strategy for local computation, into the wire for message encoding and
byte metering, and into the executor-provided primitive set
(``repro.api.executor``: ``aggregate`` / ``broadcast`` / ``metric_mean`` /
``sum_bytes`` — and, for the server family, ``local_node`` /
``from_owner`` / ``commit_owner``) for everything that depends on WHERE
the nodes live — the executor owns the loop placement (stacked scan,
``shard_map``'d scan, vmapped scenario sweep, or shard_map(vmap(scan))
for the composed ``mesh+sweep``) and returns what the transport wraps
into a ``RawRun`` for the engine.  See ``docs/EXECUTORS.md`` for the
Transport × Executor compatibility matrix.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import executor as _exec
from repro.api.faults import FaultCarry
from repro.api.strategy import Strategy
from repro.core.admm import consensus_admm
from repro.core.server import contact, init_server
from repro.core.staleness import (
    DelayLine,
    delay_init,
    delay_push_pop,
    delay_push_read,
)

PyTree = Any


class RawRun(NamedTuple):
    theta: PyTree
    state: Any
    trajectory: PyTree
    uplink: jnp.ndarray  # (T,) per-round uplink bytes
    downlink: jnp.ndarray  # (T,) per-round downlink bytes
    rounds_per_step: int  # ledger rounds charged per loop step
    event_kind: str  # ledger event tag ("contact" / "allreduce" / ...)
    extras: dict
    carry: Any  # opaque resume state


class Transport:
    name = "transport"

    def run(
        self, strategy, data, *, wire, schedule, steps, stream, theta0, carry,
        executor, faults=None,
    ) -> RawRun:
        raise NotImplementedError


def _resolve_theta0(strategy, data, theta0):
    return strategy.init_theta(data) if theta0 is None else theta0


def _unwrap_fault_carry(carry, faults, name):
    """Split a resume carry into (inner carry, plan round offset) —
    faulted fits wrap their carry in a :class:`FaultCarry` so the draw
    stream resumes where it stopped; mixing faulted and fault-free
    carries is a usage error, not something to guess through."""
    if faults is None:
        if isinstance(carry, FaultCarry):
            raise ValueError(
                f"transport {name!r}: carry= comes from a faults= fit — "
                "pass the same FaultPlan to resume it"
            )
        return carry, 0
    if carry is None:
        return None, 0
    if not isinstance(carry, FaultCarry):
        raise ValueError(
            f"transport {name!r}: resuming under faults= needs the carry "
            "of a faulted fit (a FaultCarry); this one is from a "
            "fault-free fit"
        )
    return carry.inner, int(carry.next_round)


class ServerTransport(Transport):
    """The §5 central information server under a contact schedule.

    The per-contact step is written against the executor primitive set,
    so the same program places anywhere ``run_server`` can put it: on
    the local executor ``local_node``/``from_owner``/``commit_owner``
    are identities and the walk is the historical sequential scan
    (bit-exact with ``core.server.run_protocol``); on a mesh executor
    the schedule stays sequential but each contact's ``local_step``
    runs on the shard OWNING the contacted node — every shard traces
    the step masked, the owner's push is replicated by one ``psum``,
    and per-node wire state commits owner-only::

        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (Xs, ys),
                      transport="sequential_server",
                      schedule=schedules.round_robin(K, rounds),
                      executor="mesh")     # local ≡ mesh, bit-exact
    """

    def __init__(self, handoff: str):
        if handoff not in ("sequential", "stale"):
            raise ValueError(f"unknown handoff {handoff!r}")
        self.handoff = handoff
        self.name = (
            "sequential_server" if handoff == "sequential" else "stale_server"
        )

    def run(self, strategy, data, *, wire, schedule, steps, stream, theta0, carry,
            executor, faults=None):
        if schedule is None:
            raise ValueError(
                f"transport {self.name!r} needs a contact schedule= "
                "(see repro.core.schedules)"
            )
        K = strategy.num_nodes(data)
        carry, t0 = _unwrap_fault_carry(carry, faults, self.name)
        if faults is not None:
            if faults.straggler > 0 or faults.quorum is not None:
                raise ValueError(
                    f"transport {self.name!r} contacts ONE node per round — "
                    "straggler/quorum fault modes only apply to update "
                    "transports (allreduce/delay_line); use dropout_p alone"
                )
        if carry is None:
            theta0 = _resolve_theta0(strategy, data, theta0)
            carry = (
                init_server(theta0),
                strategy.init_state(theta0, data),
                wire.init_state(theta0, K, stacked=True),
            )
        theta_template = carry[0].theta
        handoff = self.handoff
        down_const = wire.measure(theta_template)  # dense θ handed back
        static_up = wire.push_bytes(theta_template)
        if faults is not None and static_up is None:
            raise ValueError(
                f"faults= with wire {wire.name!r}: per-contact survivor "
                "accounting needs a shape-static push cost "
                "(wire.push_bytes); value-dependent wires (thresh) are "
                "not supported under faults"
            )
        # shape-static push cost → the per-contact owner-select psum on the
        # byte scalar is pure overhead; emit a placeholder instead (replaced
        # by exact integer accounting below)
        skip_up = static_up is not None
        T = len(schedule)
        if faults is not None:
            draws = faults.draws(t0, T, K)
            xs = (np.asarray(schedule), draws.u)
        else:
            xs = schedule

        def make_step(shard_data):
            """Per-contact step over whatever node slice the executor
            placed here (the full stack locally, a shard under a mesh)."""

            def step(c, xt):
                server, sstate, wstate = c
                if faults is not None:
                    k, u_t = xt
                    # contacted node answers iff its uniform clears the
                    # (possibly swept/traced) dropout threshold
                    alive = u_t[k] >= faults.dropout_p
                else:
                    k = xt
                theta_start = (
                    server.theta if handoff == "sequential"
                    else server.theta_prev
                )
                k_loc, mine = _exec.local_node(k)
                # masked compute: every shard traces the pusher's local
                # run at its own (clamped) slice index; only the owner's
                # result is real.  The strategy state stays replicated
                # (see MeshExecutor.run_server), so it is NOT selected.
                theta_new, sstate_new = strategy.local_step(
                    k_loc, theta_start, sstate, shard_data
                )
                wstate_new, theta_push, up = wire.encode_push(
                    wstate, k_loc, theta_start, theta_new
                )
                theta_push = _exec.from_owner(theta_push, mine)
                up = jnp.zeros(()) if skip_up else _exec.from_owner(up, mine)
                if faults is not None:
                    # dead contact: the round is a no-op — the server keeps
                    # its state, the node's wire state does not commit, and
                    # the trajectory records the unchanged θ
                    server_new, received_new = contact(
                        server, theta_push, handoff=handoff
                    )
                    received = jax.tree.map(
                        lambda n, o: jnp.where(alive, n, o),
                        received_new, server.theta,
                    )
                    server = jax.tree.map(
                        lambda n, o: jnp.where(alive, n, o), server_new, server
                    )
                    sstate = jax.tree.map(
                        lambda n, o: jnp.where(alive, n, o), sstate_new, sstate
                    )
                    wstate = _exec.commit_owner(wstate_new, wstate, mine & alive)
                else:
                    sstate = sstate_new
                    wstate = _exec.commit_owner(wstate_new, wstate, mine)
                    server, received = contact(
                        server, theta_push, handoff=handoff
                    )
                return (server, sstate, wstate), (received, up)

            return step

        st_tok = strategy.cache_token()
        cache_key = None
        if st_tok is not None:
            cache_key = (
                "server", handoff, st_tok, wire.cache_token(), skip_up,
                strategy.num_nodes(data),
            )
            if faults is not None:
                cache_key += (faults.cache_token(),)
        (server, sstate, wstate), (traj, ups) = executor.run_server(
            strategy=strategy, data=data, carry=carry, make_step=make_step,
            schedule=xs, wire=wire, cache_key=cache_key,
        )
        theta = executor.finalize(strategy, server.theta, sstate, data)
        if faults is not None:
            # exact host-side survivor accounting: the draws and schedule
            # are host arrays, so the per-contact byte stream never enters
            # the compiled step — a dropped contact costs nothing up or down
            alive_np = (
                draws.u[np.arange(T), np.asarray(schedule)]
                >= faults.dropout_p
            )
            ups = alive_np.astype(np.int64) * static_up
            downs = alive_np.astype(np.int64) * down_const
        else:
            if static_up is not None:
                # exact integer accounting — large models overflow f32
                # mantissas
                ups = np.full((T,), static_up, dtype=np.int64)
            downs = np.full((T,), down_const, dtype=np.int64)
        out_carry = (server, sstate, wstate)
        if faults is not None:
            out_carry = FaultCarry(inner=out_carry, next_round=t0 + T)
        return RawRun(
            theta=theta,
            state=sstate,
            trajectory=traj,
            uplink=ups,
            downlink=downs,
            rounds_per_step=1,
            event_kind="contact",
            extras={"faults": faults.describe()} if faults is not None else {},
            carry=out_carry,
        )


class UpdateTransport(Transport):
    """Synchronous Allreduce (staleness=0) or the bounded-staleness delay
    line (staleness=D>0): every round all nodes push an update message;
    the aggregate is applied — possibly D rounds late.

    Every round all nodes work, so the loop places on EVERY executor:
    the stacked scan, the mesh/multipod shard_map, the scenario sweep,
    and the composed ``mesh+sweep`` (a swept ``"staleness"`` supersedes
    the transport's own D — one depth-max(D) delay line shared by all
    scenarios, read at a batched per-scenario index)::

        api.fit(strategy, data, transport="allreduce", steps=100)
        api.fit(strategy, data, transport="delay_line", staleness=2,
                steps=100, executor="mesh")
    """

    def __init__(self, staleness: int = 0):
        if staleness < 0:
            raise ValueError("staleness must be >= 0")
        self.staleness = staleness
        self.name = "allreduce" if staleness == 0 else "delay_line"

    def run(self, strategy, data, *, wire, schedule, steps, stream, theta0, carry,
            executor, faults=None):
        K = strategy.num_nodes(data)
        if stream is not None:
            T = jax.tree.leaves(stream)[0].shape[0]
        elif steps is not None:
            T = steps
        else:
            raise ValueError(
                f"transport {self.name!r} needs steps= or a stream= with a "
                "leading time axis"
            )
        carry, t0 = _unwrap_fault_carry(carry, faults, self.name)
        p_sweep = executor.swept("dropout_p")
        if faults is None:
            if p_sweep is not None:
                raise ValueError(
                    "sweep={'dropout_p': ...} needs faults=FaultPlan(...) — "
                    "the plan supplies the shared per-round draws the swept "
                    "thresholds compare against"
                )
            draws = None
        else:
            if faults.quorum is not None and faults.quorum > K:
                raise ValueError(
                    f"quorum={faults.quorum} can never be met by K={K} nodes"
                )
            if strategy.aggregate_op != "sum" or (
                type(strategy).aggregate is not Strategy.aggregate
                and not getattr(strategy, "fault_maskable", False)
            ):
                raise ValueError(
                    f"faults= masks dropped nodes out of a SUM aggregate; "
                    f"{type(strategy).__name__} declares "
                    f"aggregate_op={strategy.aggregate_op!r}"
                    + (
                        " with an aggregate() override (set fault_maskable"
                        " = True only if the override is linear, so a"
                        " zeroed message drops out of it like a sum term)"
                        if type(strategy).aggregate is not Strategy.aggregate
                        else ""
                    )
                )
            if (
                type(strategy).uplink_bytes is not Strategy.uplink_bytes
                or type(strategy).downlink_bytes is not Strategy.downlink_bytes
            ):
                raise ValueError(
                    f"faults= meters survivors host-side from the plan's "
                    f"draws; {type(strategy).__name__}'s byte-accounting "
                    "overrides would disagree with it"
                )
            # the draws ride the scan as jit arguments (masks are data,
            # so round-varying faults never retrace the step)
            draws = faults.draws(t0, T, K)
        # a swept "staleness" supersedes the transport's own D: one delay
        # line of depth max(D_s) is shared, read at a per-scenario index;
        # stragglers deepen whatever line that leaves by their max lag
        stal_sweep = executor.swept("staleness")
        if stal_sweep is not None:
            D_buf = max(1, int(np.max(np.asarray(stal_sweep))))
        else:
            D_buf = self.staleness
        straggler = 0 if faults is None else faults.straggler
        D_buf += straggler
        resolved0 = None
        if carry is None and executor.swept("theta0") is None:
            resolved0 = _resolve_theta0(strategy, data, theta0)

        def make_carry(theta0=resolved0):
            th0 = (
                theta0 if theta0 is not None
                else _resolve_theta0(strategy, data, None)
            )
            delay = (
                delay_init(jax.tree.map(jnp.zeros_like, th0), D_buf)
                if D_buf > 0
                else ()
            )
            return (
                th0,
                strategy.init_state(th0, data),
                wire.init_state(th0, K, stacked=strategy.stacked_msgs),
                delay,
            )

        if carry is not None:
            theta_template = executor.scenario_template(carry[0])
        elif resolved0 is not None:
            theta_template = resolved0
        else:
            theta_template = executor.scenario_template(
                executor.swept("theta0")
            )
        # static byte accounting where possible (see Wire.push_bytes)
        up_is_static = (
            type(strategy).uplink_bytes is Strategy.uplink_bytes
            and wire.push_bytes(theta_template) is not None
        )
        down_is_static = type(strategy).downlink_bytes is Strategy.downlink_bytes
        if faults is not None and not up_is_static:
            raise ValueError(
                f"faults= with wire {wire.name!r}: per-survivor byte "
                "accounting needs a shape-static push cost "
                "(wire.push_bytes); value-dependent wires (thresh) are "
                "not supported under faults"
            )

        # per-step scalar stats (metric pmean, byte psum) defer to one
        # post-loop reduction on the stacked (T,) outputs — bitwise
        # identical, and it removes two tiny collectives from every round
        defer_ok = bool(getattr(strategy, "defer_stats", True))
        stats = _exec.StatsDeferral()
        # comm/compute overlap: a delay-tolerant transport (D >= 1) may
        # split each round's aggregate — every hop but the outermost runs
        # in-round, the outermost (inter-pod, expensive) completes at the
        # START of the next round so XLA overlaps it with that round's
        # local compute.  Bit-exact: the completed value is the same psum,
        # applied at the same (delayed) step it would have been anyway.
        # Sum-reductions only, and only the default aggregate/uplink paths
        # (overrides may inspect the aggregate mid-round).
        overlap_active = (
            bool(getattr(executor, "overlap", False))
            and D_buf >= 1
            and stal_sweep is None
            and executor.num_scenarios is None
            and strategy.stacked_msgs
            and strategy.aggregate_op == "sum"
            and type(strategy).aggregate is Strategy.aggregate
            and type(strategy).uplink_bytes is Strategy.uplink_bytes
            # a quorum abort would have to recall an in-flight partial;
            # keep faulted rounds on the plain aggregate path
            and faults is None
        )

        def make_step(shard_data, sweep_delay):
            """Per-round step against the executor's primitive set.

            ``shard_data`` is whatever node slice the executor placed here
            (the full stack locally, a shard under the mesh); ``sweep_delay``
            is the per-scenario staleness index under a sweep, else None.
            """

            def step(c, xt):
                if faults is not None:
                    (u_t, lag_t), batch = xt
                else:
                    batch = xt
                c0 = c  # pre-round carry — the quorum rollback target
                theta, sstate, wstate, delay = c
                if overlap_active:
                    buf2, pending, step0 = delay
                    # complete LAST round's outermost hop first, so the
                    # collective overlaps the local compute traced below
                    agg_done = _exec.aggregate_complete(pending)
                msgs, sstate = strategy.local_updates(
                    theta, sstate, shard_data, batch
                )
                wstate_new, msgs_hat, up = wire.encode_updates(
                    wstate, msgs, stacked=strategy.stacked_msgs
                )
                if faults is not None:
                    # participation: node k answers iff u_t[k] clears the
                    # (possibly swept, traced) threshold.  The global mask
                    # is replicated data; each shard masks only its own
                    # message rows, so the sum aggregate sees zeros for the
                    # dead and the result is placement-invariant.  Dead
                    # nodes' wire state freezes (they neither encoded nor
                    # sent — EF residuals must not absorb a discarded push).
                    alive = u_t >= faults.dropout_p
                    live = jnp.sum(alive.astype(jnp.int32))
                    if strategy.stacked_msgs:
                        alive_loc = _exec.local_rows(alive)

                        def _rows(sel, n, o):
                            return jnp.where(
                                sel.reshape(sel.shape + (1,) * (n.ndim - 1)),
                                n, o,
                            )

                        msgs_hat = jax.tree.map(
                            lambda x: _rows(alive_loc, x, jnp.zeros_like(x)),
                            msgs_hat,
                        )
                        wstate = jax.tree.map(
                            lambda n, o: _rows(alive_loc, n, o),
                            wstate_new, wstate,
                        )
                    else:
                        alive0 = alive[0]
                        msgs_hat = jax.tree.map(
                            lambda x: jnp.where(alive0, x, jnp.zeros_like(x)),
                            msgs_hat,
                        )
                        wstate = jax.tree.map(
                            lambda n, o: jnp.where(alive0, n, o),
                            wstate_new, wstate,
                        )
                else:
                    wstate = wstate_new
                up_override = strategy.uplink_bytes(msgs_hat, shard_data)
                if up_override is not None:
                    up = up_override
                elif up_is_static:
                    # replaced by exact integer accounting after the run
                    up = jnp.zeros(())
                else:
                    with _exec.deferring(stats if defer_ok else None):
                        up = _exec.sum_bytes(up)  # shard-local cost → global
                if overlap_active:
                    pending_new = _exec.aggregate_partial(msgs_hat)
                    if D_buf > 1:
                        buf2, agg = delay_push_pop(buf2, agg_done)
                    else:
                        agg = agg_done
                    delay = (buf2, pending_new, step0)
                else:
                    agg = _exec.broadcast(strategy.aggregate(msgs_hat))
                    if straggler > 0:
                        # the round completes when its slowest LIVE node
                        # responds: read the delay line at base + max lag
                        base = (
                            sweep_delay if sweep_delay is not None
                            else jnp.asarray(self.staleness, jnp.int32)
                        )
                        lag_eff = jnp.max(jnp.where(alive, lag_t, 0))
                        delay, agg = delay_push_read(
                            delay, agg, base + lag_eff
                        )
                    elif sweep_delay is not None:
                        delay, agg = delay_push_read(delay, agg, sweep_delay)
                    elif D_buf > 0:
                        delay, agg = delay_push_pop(delay, agg)
                theta_new, sstate = strategy.apply_update(
                    theta, agg, sstate, shard_data
                )
                if down_is_static:
                    down = jnp.zeros(())  # replaced after the run
                else:
                    down = strategy.downlink_bytes(theta_new, shard_data)
                    if down is None:
                        down = jnp.asarray(float(K * wire.measure(theta_new)))
                new_c = (theta_new, sstate, wstate, delay)
                if faults is not None and faults.quorum is not None:
                    # below quorum the server discards the round: the whole
                    # carry (θ, strategy state, wire state, delay line)
                    # rolls back to the pre-round value
                    proceed = live >= faults.quorum
                    new_c = jax.tree.map(
                        lambda n, o: jnp.where(proceed, n, o), new_c, c0
                    )
                with _exec.deferring(stats if defer_ok else None):
                    m = strategy.round_metric(new_c[0], new_c[1], shard_data)
                return new_c, (m, up, down)

            return step

        def enter_loop(c):
            # standard carry → overlapped carry: the delay line's NEWEST
            # slot becomes the in-flight partial (masked to the outer
            # hop's root shards, so the completing psum reproduces the
            # replicated value exactly); older slots stay a depth-(D-1)
            # line.  This keeps resume carries interchangeable between
            # overlap on/off.
            theta, sstate, wstate, delay = c
            newest = jax.tree.map(lambda b: b[D_buf - 1], delay.buffer)
            pending = _exec.mask_to_root(newest)
            if D_buf > 1:
                buf2 = DelayLine(
                    buffer=jax.tree.map(
                        lambda b: b[: D_buf - 1], delay.buffer
                    ),
                    step=delay.step,
                )
            else:
                buf2 = ()
            return (theta, sstate, wstate, (buf2, pending, delay.step))

        def exit_loop(c, ys):
            m, up, down = ys
            if overlap_active:
                # overlapped carry → standard carry: complete the last
                # round's pending hop and re-append it as the newest slot
                theta, sstate, wstate, (buf2, pending, step0) = c
                done = _exec.aggregate_complete(pending)
                if D_buf > 1:
                    delay = DelayLine(
                        buffer=jax.tree.map(
                            lambda b, d: jnp.concatenate(
                                [b, d[None]], axis=0
                            ),
                            buf2.buffer, done,
                        ),
                        step=buf2.step,
                    )
                else:
                    delay = DelayLine(
                        buffer=jax.tree.map(lambda d: d[None], done),
                        step=step0 + jnp.asarray(T, jnp.int32),
                    )
                c = (theta, sstate, wstate, delay)
            if stats.metric:
                m = _exec.metric_mean(m)
            if stats.bytes:
                up = _exec.sum_bytes(up)
            return c, (m, up, down)

        st_tok = strategy.cache_token()
        cache_key = None
        if st_tok is not None:
            cache_key = (
                "update", st_tok, wire.cache_token(), D_buf,
                stal_sweep is None, overlap_active, defer_ok,
                up_is_static, down_is_static, strategy.stacked_msgs, K,
            )
            if faults is not None:
                # the plan's seed is NOT in the token: draws are data, so
                # plans differing only in seed share one compiled program
                cache_key += (
                    faults.cache_token(dropout_swept=p_sweep is not None),
                )

        xs = stream if stream is not None else None
        if faults is not None:
            xs = ((draws.u, draws.lag), xs)
        carry, (traj, ups, downs) = executor.run_update(
            strategy=strategy, data=data, carry=carry,
            make_carry=make_carry, make_step=make_step, xs=xs, length=T,
            wire=wire, cache_key=cache_key,
            enter_loop=enter_loop if overlap_active else None,
            exit_loop=exit_loop if (overlap_active or defer_ok) else None,
            sweep_targets=(faults,) + tuple(getattr(wire, "stages", ())),
        )
        theta, sstate = carry[0], carry[1]
        theta = executor.finalize(strategy, theta, sstate, data)
        if faults is not None:
            # exact host-side survivor accounting from the same draws the
            # step masked with: uplink charges only live pushes; downlink
            # hands θ back to survivors, and only when quorum committed
            p_vals = (
                np.asarray(p_sweep, dtype=np.float64).reshape(-1)
                if p_sweep is not None
                else np.asarray([faults.dropout_p])
            )
            alive_np = draws.u[None, :, :] >= p_vals[:, None, None]
            live_np = alive_np.sum(axis=2).astype(np.int64)  # (S|1, T)
            ups = live_np * int(wire.push_bytes(theta_template))
            commit_np = (
                live_np >= faults.quorum
                if faults.quorum is not None
                else np.ones_like(live_np, dtype=bool)
            )
            downs = (
                np.where(commit_np, live_np, 0)
                * int(wire.measure(theta_template))
            )
            if p_sweep is None:
                ups, downs = ups[0], downs[0]
        else:
            if up_is_static:
                per_round = wire.push_bytes(theta_template) * (
                    K if strategy.stacked_msgs else 1
                )
                ups = np.full((T,), per_round, dtype=np.int64)
            if down_is_static:
                downs = np.full(
                    (T,), K * wire.measure(theta_template), dtype=np.int64
                )
        S = executor.num_scenarios
        if S is not None:
            ups = np.asarray(ups)
            downs = np.asarray(downs)
            if ups.ndim == 1:  # static costs are scenario-invariant
                ups = np.broadcast_to(ups, (S, T)).copy()
            if downs.ndim == 1:
                downs = np.broadcast_to(downs, (S, T)).copy()
        out_carry = carry
        if faults is not None:
            out_carry = FaultCarry(inner=carry, next_round=t0 + T)
        return RawRun(
            theta=theta,
            state=sstate,
            trajectory=traj,
            uplink=ups,
            downlink=downs,
            rounds_per_step=1,
            event_kind="allreduce",
            extras={"faults": faults.describe()} if faults is not None else {},
            carry=out_carry,
        )


class AdmmTransport(Transport):
    """Global-variable-consensus ADMM: the strategy supplies the per-node
    prox; every iteration costs two Allreduces of the consensus variable
    (z-update mean + residual norms), which is what the ledger charges.

    Wraps ``core.admm.consensus_admm``'s own three-stage loop rather
    than the executor step protocol, so runs are one-shot (no
    ``theta0=``/``carry=``), need a LOSSLESS wire (``Wire.lossless`` —
    compressing consensus pushes would change the algorithm), and run
    on the local executor only.
    """

    name = "admm_consensus"

    def __init__(self, *, rho: float = 1.0, g: str = "none", g_lam: float = 0.0):
        self.rho = rho
        self.g = g
        self.g_lam = g_lam

    def run(self, strategy, data, *, wire, schedule, steps, stream, theta0, carry,
            executor, faults=None):
        if faults is not None:
            raise ValueError(
                "admm_consensus wraps core.admm's own synchronous loop — "
                "consensus ADMM has no masked-participation form here; "
                "faults= applies to server/allreduce/delay_line transports"
            )
        if steps is None:
            raise ValueError("transport 'admm_consensus' needs steps= (iterations)")
        if theta0 is not None or carry is not None:
            raise ValueError(
                "admm_consensus runs are one-shot: warm-start (theta0=) and "
                "resume (carry=) are not supported — rerun with more steps"
            )
        if not wire.lossless:
            raise ValueError(
                "admm_consensus needs a lossless wire (dense) — compressing "
                "the consensus pushes would change the algorithm"
            )
        from repro.api.executor import LocalExecutor

        if not isinstance(executor, LocalExecutor):
            raise ValueError(
                "admm_consensus wraps core.admm's own inner loop — it runs "
                f"on the local executor only, not {executor.name!r}"
            )
        local_prox = strategy.make_local_prox(data)
        K = strategy.num_nodes(data)
        dim = strategy.dim(data)
        res = consensus_admm(
            local_prox, K, dim,
            rho=self.rho, g=self.g, g_lam=self.g_lam, iters=steps,
        )
        theta = executor.finalize(strategy, res.z, res.state, data)
        # two Allreduces of the (dim,) consensus variable per iteration
        per_iter = 2 * K * wire.measure(res.z)
        ups = np.full((steps,), per_iter, dtype=np.int64)
        return RawRun(
            theta=theta,
            state=res.state,
            trajectory=res.history,
            uplink=ups,
            downlink=ups,
            rounds_per_step=2,
            event_kind="allreduce",
            extras={"admm": res},
            carry=res.state,
        )


TRANSPORTS = (
    "sequential_server",
    "stale_server",
    "delay_line",
    "allreduce",
    "admm_consensus",
)


def make_transport(spec: str | Transport, **options) -> Transport:
    """Resolve a transport spec; ``options`` are transport-specific
    (``staleness`` for delay_line; ``rho``/``g``/``g_lam`` for
    admm_consensus)."""
    if isinstance(spec, Transport):
        if options:
            raise ValueError("transport options only apply to string specs")
        return spec
    if spec == "sequential_server":
        _expect(options, ())
        return ServerTransport("sequential")
    if spec == "stale_server":
        _expect(options, ())
        return ServerTransport("stale")
    if spec == "allreduce":
        _expect(options, ())
        return UpdateTransport(staleness=0)
    if spec == "delay_line":
        _expect(options, ("staleness",))
        return UpdateTransport(staleness=options.get("staleness", 1))
    if spec == "admm_consensus":
        _expect(options, ("rho", "g", "g_lam"))
        return AdmmTransport(**options)
    raise ValueError(f"unknown transport {spec!r} — one of {TRANSPORTS}")


def _expect(options: dict, allowed: tuple):
    unknown = set(options) - set(allowed)
    if unknown:
        raise TypeError(f"unexpected transport options: {sorted(unknown)}")
