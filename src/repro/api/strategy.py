"""Strategy layer — the per-node learner F^(k).

The paper's §5 observation is that ANY local learning method F^(k) can sit
behind the client-server protocol; a ``Strategy`` is exactly that method,
written once and runnable under every transport.  Three method families,
one per transport family:

* server family (``local_step``)       — F^(k): θ → θ', used by the
  ``sequential_server`` / ``stale_server`` transports;
* update family (``local_updates`` / ``aggregate`` / ``apply_update``) —
  per-node messages + one aggregation + a global apply, used by the
  ``allreduce`` / ``delay_line`` transports;
* consensus family (``make_local_prox``) — the per-node proximity operator
  of consensus ADMM, used by the ``admm_consensus`` transport.

A strategy implements the families that make sense for it and raises a
clear error otherwise.  Generic strategies live here; algorithm-specific
ones (cascade SVM, k-windows) live next to their algorithms in ``ml/``
and plug into the same engine.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.api import executor as _exec
from repro.core.allreduce import server_allreduce

PyTree = Any


class Strategy:
    """Base strategy.  Subclasses override the families they support."""

    #: messages from ``local_updates`` carry a leading node axis
    stacked_msgs: bool = True
    #: communication rounds charged before the loop (e.g. an initial
    #: gradient Allreduce) — the engine adds them to the ledger
    init_rounds: int = 0
    #: reduction applied over the node axis by the base ``aggregate``
    #: ("sum" / "mean" / "max" / "any" — ``any`` is the psum-of-bools set
    #: union, e.g. the cascade SVM's SV-mask union).  Executors that
    #: place nodes on a mesh complete this op with the native collective
    #: — strategies that instead *override* ``aggregate`` with arbitrary
    #: Python stay local/sweep-only.
    aggregate_op: str = "sum"
    #: mesh placement: False (default) shards the data's leading node
    #: axis across devices; True replicates the FULL data on every shard
    #: — for strategies whose per-node computation reads the whole
    #: dataset (cascade SVM's shared SV pool).  Replicating strategies
    #: reconstruct their node slice from ``executor.node_shard_index()``.
    replicate_data: bool = False
    #: whether ``predict`` is a pure jittable function of (θ, X).  The
    #: serve engine compiles jittable predicts once per request shape;
    #: strategies whose predict drives its own Python loop (LM decode)
    #: set this False and are called eagerly.
    predict_jit: bool = True

    # -- setup ---------------------------------------------------------------
    def init_theta(self, data) -> PyTree:
        raise NotImplementedError(
            f"{type(self).__name__} cannot derive θ_0 from data; pass theta0="
        )

    def init_state(self, theta: PyTree, data):
        return ()

    def num_nodes(self, data) -> int:
        if data is None:
            raise ValueError(
                f"{type(self).__name__}.num_nodes needs data with a leading "
                "node axis (or override num_nodes)"
            )
        return jax.tree.leaves(data)[0].shape[0]

    # -- server family -------------------------------------------------------
    def local_step(self, k, theta: PyTree, state, data):
        """F^(k): one local run on node ``k``'s shard.  Returns (θ', state)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support server transports"
        )

    # -- update family -------------------------------------------------------
    def local_updates(self, theta: PyTree, state, data, batch):
        """All nodes' messages for this round (stacked on axis 0 when
        ``stacked_msgs``).  ``batch`` is the per-round stream element, or
        None for fixed shard data.  Returns (msgs, state)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support update transports"
        )

    def aggregate(self, msgs: PyTree) -> PyTree:
        return _exec.aggregate(msgs, op=self.aggregate_op)

    def apply_update(self, theta: PyTree, agg: PyTree, state, data):
        """Apply the aggregated message.  Returns (θ', state)."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support update transports"
        )

    # -- consensus family ----------------------------------------------------
    def make_local_prox(self, data) -> Callable:
        raise NotImplementedError(
            f"{type(self).__name__} does not support the admm_consensus "
            "transport"
        )

    def dim(self, data) -> int:
        """Consensus-variable dimension for admm_consensus."""
        raise NotImplementedError

    # -- diagnostics & wire-cost hooks ---------------------------------------
    def round_metric(self, theta: PyTree, state, data):
        """Per-round scalar (or small pytree) stacked into the trajectory
        by update transports."""
        return jnp.zeros(())

    def summary(self, theta: PyTree, data) -> dict:
        """Final metrics dict merged into ``FitResult.metrics``."""
        return {}

    def finalize(self, theta: PyTree, state, data) -> PyTree:
        return theta

    # -- serving ------------------------------------------------------------
    def predict(self, theta: PyTree, X: PyTree) -> PyTree:
        """Answer a batch of inference requests with the trained model.

        ``theta`` is a FINALIZED parameter (what ``FitResult.theta``
        holds); ``X`` carries a leading request/batch axis and every
        request must be independent — the serve batcher relies on
        row-independence to pad batches without changing any answer.
        """
        raise NotImplementedError(
            f"{type(self).__name__} does not implement predict() and "
            "cannot be served"
        )

    def uplink_bytes(self, msgs_hat: PyTree, data):
        """Override to report semantic (data-dependent) push cost; None →
        the wire layer's measurement is used."""
        return None

    def downlink_bytes(self, theta: PyTree, data):
        """Override the broadcast cost; None → K dense copies of θ."""
        return None

    # -- executor performance hooks ------------------------------------------
    #: every ``_exec.metric_mean``/``_exec.sum_bytes`` call in this
    #: strategy's ``round_metric`` is the OUTERMOST op of its expression,
    #: so the transport may defer the tiny per-step collective and
    #: complete it once on the stacked trajectory (bitwise identical).
    #: Set False if a metric post-processes the completed mean.
    defer_stats: bool = True

    def cache_token(self):
        """Hashable fingerprint of every configuration value that shapes
        this strategy's traced step, or None to opt out of the executor
        program cache (the safe default: strategies with closures or
        derived state the base class cannot see run uncached, exactly as
        before)."""
        return None


# ----------------------------------------------------------------------------
# Generic strategies
# ----------------------------------------------------------------------------


class FunctionStrategy(Strategy):
    """Wrap a bare update function ``F(k, θ) -> θ'`` (the paper's notation)
    as a server-family strategy — the 3-line path from ``run_protocol``::

        strategy = api.FunctionStrategy(F, num_nodes=K)
        res = api.fit(strategy, transport="sequential_server",
                      schedule=schedules.round_robin(K, 50), theta0=theta0)

    ``F`` closes over its data, so this strategy has nothing for a mesh
    executor to shard — server runs stay on ``executor="local"``.
    """

    def __init__(self, F: Callable, *, num_nodes: int, metric: Callable | None = None):
        self._F = F
        self._num_nodes = num_nodes
        self._metric = metric

    def num_nodes(self, data) -> int:
        return self._num_nodes

    def local_step(self, k, theta, state, data):
        return self._F(k, theta), state

    def round_metric(self, theta, state, data):
        if self._metric is None:
            return jnp.zeros(())
        return self._metric(theta)

    def summary(self, theta, data) -> dict:
        if self._metric is None:
            return {}
        return {"final_metric": self._metric(theta)}


class GradientDescent(Strategy):
    """Full-batch distributed GD on sharded ``data = (Xs, ys)``.

    Under ``allreduce`` this is the [47]/[5] pattern (push local gradient,
    receive the global aggregate) — bit-identical to the historical
    ``ml.linear.distributed_gd``.  Under the server transports each contact
    is one local gradient step (the §5 quickstart learner)::

        res = api.fit(api.GradientDescent(lsq_loss, lr=0.1), (Xs, ys),
                      transport="allreduce", steps=100)
        res.metrics["loss"]            # final mean loss over all nodes

    Placement-oblivious by construction: the per-node weights normalize
    by the GLOBAL node count (``num_node_shards``) and the round metric
    completes across shards (``metric_mean``), so the same instance runs
    under every executor, server transports included.
    """

    def __init__(
        self,
        loss: Callable,
        *,
        lr: float = 0.1,
        l2: float = 0.0,
    ):
        self.loss = loss
        self.lr = lr
        self.l2 = l2
        self._grad_local = jax.vmap(jax.grad(loss), in_axes=(None, 0, 0))

    def init_theta(self, data):
        Xs, _ = data
        return jnp.zeros((Xs.shape[-1],))

    def _weights(self, data):
        # data may be the shard-local slice of the node axis (mesh
        # executor); the weights must still normalize by the GLOBAL count
        Xs, _ = data
        K_local, Nk = Xs.shape[0], Xs.shape[1]
        K = K_local * _exec.num_node_shards()
        return jnp.full((K_local,), Nk / (K * Nk))

    def local_step(self, k, theta, state, data):
        Xs, ys = data
        g = jax.grad(self.loss)(theta, Xs[k], ys[k])
        return theta - self.lr * (g + self.l2 * theta), state

    def local_updates(self, theta, state, data, batch):
        Xs, ys = data
        gs = self._grad_local(theta, Xs, ys)
        return gs * self._weights(data)[:, None], state

    def apply_update(self, theta, agg, state, data):
        g = agg + self.l2 * theta
        return theta - self.lr * g, state

    def cache_token(self):
        # id(loss) pins the traced computation; the cached program keeps
        # the strategy (and so the loss) alive, so ids are not recycled
        # while the cache entry lives
        return ("gd", id(self.loss), float(self.lr), float(self.l2))

    def round_metric(self, theta, state, data):
        Xs, ys = data
        return _exec.metric_mean(
            jnp.mean(jax.vmap(self.loss, in_axes=(None, 0, 0))(theta, Xs, ys))
        )

    def summary(self, theta, data) -> dict:
        return {"loss": self.round_metric(theta, (), data)}

    def predict(self, theta, X):
        """Linear score X @ θ — regression values (lsq) or logits
        (logistic; threshold at 0 for labels)."""
        return X @ theta


class _LBFGSState(NamedTuple):
    g: jnp.ndarray
    S: jnp.ndarray
    Y: jnp.ndarray
    rho: jnp.ndarray
    valid: jnp.ndarray
    it: jnp.ndarray
    theta_prop: jnp.ndarray


def _two_loop(g, S, Y, rho, valid):
    """Standard L-BFGS two-loop recursion with a validity mask."""

    def bwd(carry, inp):
        (q,) = carry
        s, yv, r, v = inp
        alpha = jnp.where(v > 0, r * jnp.dot(s, q), 0.0)
        q = q - alpha * yv * jnp.where(v > 0, 1.0, 0.0)
        return (q,), alpha

    (q,), alphas = jax.lax.scan(
        bwd, (g,), (S[::-1], Y[::-1], rho[::-1], valid[::-1])
    )
    num = jnp.sum(S * Y, axis=1)
    den = jnp.sum(Y * Y, axis=1)
    gamma = jnp.where(
        jnp.any(valid > 0),
        jnp.sum(jnp.where(valid > 0, num, 0.0))
        / jnp.maximum(jnp.sum(jnp.where(valid > 0, den, 0.0)), 1e-12),
        1.0,
    )
    r_vec = gamma * q

    def fwd(carry, inp):
        (r_v,) = carry
        s, yv, r, v, alpha = inp
        beta = jnp.where(v > 0, r * jnp.dot(yv, r_v), 0.0)
        r_v = r_v + (alpha - beta) * s * jnp.where(v > 0, 1.0, 0.0)
        return (r_v,), None

    (r_vec,), _ = jax.lax.scan(fwd, (r_vec,), (S, Y, rho, valid, alphas[::-1]))
    return r_vec


class LBFGS(Strategy):
    """[5]'s distributed L-BFGS: ONE gradient Allreduce per iteration; the
    (s, y) rank-1 history and the two-loop recursion run locally — and
    deterministically identically — on every node.

    ``aggregate_op = "mean"`` declares the reduction, so mesh executors
    complete it with a native ``pmean`` instead of a Python override;
    ``init_rounds = 1`` charges the initial global gradient to the
    ledger::

        res = api.fit(api.LBFGS(lsq_loss), (Xs, ys),
                      transport="allreduce", steps=25, executor="mesh")
    """

    init_rounds = 1  # the initial global gradient
    aggregate_op = "mean"

    def __init__(
        self,
        loss: Callable,
        *,
        history: int = 8,
        lr: float = 1.0,
        l2: float = 1e-4,
    ):
        self.loss = loss
        self.history = history
        self.lr = lr
        self.l2 = l2
        self._grad_local = jax.vmap(jax.grad(loss), in_axes=(None, 0, 0))

    def init_theta(self, data):
        Xs, _ = data
        return jnp.zeros((Xs.shape[-1],))

    def init_state(self, theta, data):
        Xs, ys = data
        n, m = theta.shape[0], self.history
        g0 = server_allreduce(
            self._grad_local(theta, Xs, ys), op="mean"
        ) + self.l2 * theta
        return _LBFGSState(
            g=g0,
            S=jnp.zeros((m, n)),
            Y=jnp.zeros((m, n)),
            rho=jnp.zeros((m,)),
            valid=jnp.zeros((m,)),
            it=jnp.asarray(0),
            theta_prop=theta,
        )

    def local_updates(self, theta, state, data, batch):
        Xs, ys = data
        d = -_two_loop(state.g, state.S, state.Y, state.rho, state.valid)
        theta_prop = theta + self.lr * d
        msgs = self._grad_local(theta_prop, Xs, ys)
        return msgs, state._replace(theta_prop=theta_prop)

    def apply_update(self, theta, agg, state, data):
        theta_new = state.theta_prop
        g_new = agg + self.l2 * theta_new
        s = theta_new - theta
        yv = g_new - state.g
        sy = jnp.dot(s, yv)
        ok = sy > 1e-10  # curvature condition
        S = jnp.where(ok, jnp.roll(state.S, -1, axis=0).at[-1].set(s), state.S)
        Y = jnp.where(ok, jnp.roll(state.Y, -1, axis=0).at[-1].set(yv), state.Y)
        rho = jnp.where(
            ok,
            jnp.roll(state.rho, -1).at[-1].set(1.0 / jnp.maximum(sy, 1e-12)),
            state.rho,
        )
        valid = jnp.where(ok, jnp.roll(state.valid, -1).at[-1].set(1.0), state.valid)
        new_state = _LBFGSState(
            g=g_new, S=S, Y=Y, rho=rho, valid=valid,
            it=state.it + 1, theta_prop=state.theta_prop,
        )
        return theta_new, new_state

    def cache_token(self):
        return (
            "lbfgs", id(self.loss),
            int(self.history), float(self.lr), float(self.l2),
        )

    def round_metric(self, theta, state, data):
        Xs, ys = data
        return _exec.metric_mean(
            jnp.mean(jax.vmap(self.loss, in_axes=(None, 0, 0))(theta, Xs, ys))
        )

    def summary(self, theta, data) -> dict:
        return {"loss": self.round_metric(theta, (), data)}

    def predict(self, theta, X):
        return X @ theta


class ProxStrategy(Strategy):
    """Consensus-family strategy: per-node proximity operators for the
    ``admm_consensus`` transport (the paper's Douglas-Rachford three-stage
    algorithm).  ``make_prox(data)`` builds the vectorized local prox
    ``(v, u, rho) -> (K, n)`` — closed form or inner gradient loop::

        res = api.fit(api.ProxStrategy(lasso_prox_builder), (Xs, ys),
                      transport="admm_consensus", steps=50,
                      g="l1", g_lam=0.1)

    Consensus runs wrap ``core.admm``'s own loop, so they are one-shot
    (no warm start / resume), require a lossless wire, and run on the
    local executor only.
    """

    def __init__(self, make_prox: Callable, *, dim: int | None = None):
        self._make_prox = make_prox
        self._dim = dim

    def make_local_prox(self, data):
        return self._make_prox(data)

    def dim(self, data) -> int:
        if self._dim is not None:
            return self._dim
        Xs = data[0] if isinstance(data, tuple) else data
        return Xs.shape[-1]


class OptimizerStrategy(Strategy):
    """Single-stream optimizer training (the ``launch/train.py`` workload):
    one logical push per step whose message is the gradient of ``loss_fn``
    on the per-round batch, applied through a ``repro.optim`` optimizer.
    Compose with ``delay_line`` for §5 bounded staleness and a compressed
    wire for the low-communication push::

        strategy = api.OptimizerStrategy(loss_fn, adam(3e-4))
        res = api.fit(strategy, None, transport="delay_line", staleness=1,
                      wire="topk:0.05+ef", stream=batches, theta0=params)

    One logical node (``num_nodes == 1``, ``stacked_msgs = False``), so
    mesh executors do not apply; a swept ``{"staleness": ...}`` does —
    including under a multipod ``MeshContext``, where the activation
    sharding nests inside the scenario vmap
    (``launch/train.py --sweep-staleness --multipod``).
    """

    stacked_msgs = False
    #: the aggregate() override below is the identity on ONE message — a
    #: zeroed (fault-masked) message passes through exactly like a sum
    #: term dropping out, so faults= may mask through it (a dead round
    #: applies a zero gradient)
    fault_maskable = True

    def __init__(
        self,
        loss_fn: Callable,
        optimizer,
        *,
        has_aux: bool = False,
        predict_fn: Callable | None = None,
        predict_jit: bool = False,
    ):
        self.loss_fn = loss_fn
        self.optimizer = optimizer
        self.has_aux = has_aux
        self.predict_fn = predict_fn
        # servability is injected per instance, so jittability rides
        # along: False fits loop-driving decodes (LM prefill+decode);
        # pass True for a predict_fn that is a pure jittable function
        self.predict_jit = predict_jit

    def num_nodes(self, data) -> int:
        return 1

    def init_state(self, theta, data):
        return (self.optimizer.init(theta), jnp.zeros(()))

    def local_updates(self, theta, state, data, batch):
        if self.has_aux:
            (l, _), grads = jax.value_and_grad(self.loss_fn, has_aux=True)(
                theta, batch
            )
        else:
            l, grads = jax.value_and_grad(self.loss_fn)(theta, batch)
        return grads, (state[0], l)

    def aggregate(self, msgs):
        return msgs  # one logical node — nothing to reduce

    def apply_update(self, theta, agg, state, data):
        from repro.optim.optimizers import apply_updates

        updates, opt_state = self.optimizer.update(agg, state[0], theta)
        return apply_updates(theta, updates), (opt_state, state[1])

    def round_metric(self, theta, state, data):
        return state[1]  # loss on the round's batch (pre-update)

    def predict(self, theta, X):
        """Serving for optimizer-trained models is workload-specific
        (`launch/serve.prefill_and_decode` for LMs) — inject it as
        ``predict_fn(θ, X)``; e.g. a closure over the model config that
        decodes prompt batches."""
        if self.predict_fn is None:
            raise NotImplementedError(
                "OptimizerStrategy needs predict_fn= to be served (e.g. a "
                "prefill_and_decode closure from repro.launch.serve)"
            )
        return self.predict_fn(theta, X)
