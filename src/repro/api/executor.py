"""Executor layer — WHERE a fit runs.

The Strategy/Transport/Wire decomposition (see ``docs/API.md``) says what
is learned, who talks to whom, and what crosses the network.  The
*executor* owns the remaining axis: where the per-round program is
placed.  The paper's §3.1 observation — the central-server Allreduce is
the two-phase simulation of what ``jax.lax.psum`` does natively — becomes
a pure placement choice: the same transport step runs

* ``local``  — K logical nodes stacked on one host (the classical
  simulation; bit-exact with the pre-executor engine);
* ``mesh``   — nodes placed on the data axis of a ``jax.sharding.Mesh``
  via ``shard_map``; aggregation is ``psum``/``pmean`` over the mesh axis
  and the wire's encode/decode (including the Pallas ``topk_compress``
  kernel) runs per shard, on the real hot path;
* ``multipod`` — the ``("pod", "data")`` production placement: the same
  shard_map'd step, but the ledger decomposes by reduction tier —
  intra-pod psum (cheap) vs inter-pod allreduce (the paper's expensive
  client↔server link), priced per hop;
* ``sweep``  — a vmapped leading *scenario* axis: S configurations
  (step sizes, regularizers, staleness levels, initial points) compile to
  ONE executable and return a batched ``FitResult`` with per-scenario
  ``CommLedger``s.

Executors COMPOSE: ``SweepExecutor(params, inner=MeshExecutor(...))``
(spec strings ``"mesh+sweep"`` / ``"multipod+sweep"`` with the scenario
values passed as ``fit(..., sweep={...})``) runs the scenario vmap
*inside* the shard_map body — S scenarios train per shard in one
executable, saturating the mesh, with per-scenario ``CommLedger``s (and,
under a multipod inner, the per-hop decomposition preserved per
scenario).  And the §5 *server* transports, which walk one sequential
contact schedule, now place on the mesh executors too: each contact's
``local_step`` runs masked on the shard owning the contacted node and
the push is replicated to every shard with one ``psum``
(``local_node`` / ``from_owner`` below) — local ≡ mesh bit-exact.

Transports do not hard-code stacked-axis arithmetic anymore; they express
their step against the executor-provided primitive set below —
``aggregate`` / ``broadcast`` / ``node_axis`` (+ the ``metric_mean`` /
``sum_bytes`` / ``num_node_shards`` / ``node_shard_index`` /
``node_global_index`` / ``local_node`` / ``from_owner`` /
``commit_owner`` helpers).  The primitives are ambient (a trace-time
context installed by the running executor) and resolve against the
context's ``core.topology.Topology``: a flat topology reduces every node
axis in one hop (today's behavior, bit-exact), a hierarchical one stages
the reduction intra-pod first and inter-pod last.  Under the local
executor every primitive degrades to the identity / the stacked
``server_allreduce``, keeping historical results bit-exact.  See
``docs/EXECUTORS.md`` for the full guide and the Transport × Executor
compatibility matrix.
"""

from __future__ import annotations

import os
import threading
from collections import OrderedDict
from contextlib import contextmanager
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.allreduce import (
    complete_allreduce,
    hierarchical_allreduce,
    mesh_allreduce,
    partial_allreduce,
    server_allreduce,
)
from repro.core.topology import Topology
from repro.launch.mesh import batch_axes, make_multipod_mesh, make_node_mesh
from repro.sharding.rules import current_mesh_context
from repro.telemetry import trace as _trace

PyTree = Any

# ----------------------------------------------------------------------------
# Ambient execution context + the primitive set
# ----------------------------------------------------------------------------

_ctx = threading.local()


class ExecContext(NamedTuple):
    """Trace-time placement info installed by the running executor."""

    node_axis: Any  # mesh axis name (or tuple) carrying nodes; None = stacked
    num_shards: int  # how many shards the node axis is split over
    #: reduction topology the primitives resolve against (None = single
    #: joint collective over ``node_axis``)
    topology: Any = None
    #: per-axis shard counts in ``node_axis`` order (for shard indexing)
    axis_sizes: Any = None
    #: logical nodes hosted per shard (K / num_shards); None locally
    nodes_per_shard: int | None = None
    #: stage the innermost hop as reduce-scatter → reduce → all-gather so
    #: each device reduces 1/K of the tree (set by the mesh executors'
    #: ``reduce_scatter`` knob; bit-exact with the staged psum path)
    reduce_scatter: bool = False


def current_exec_context() -> ExecContext | None:
    return getattr(_ctx, "value", None)


@contextmanager
def executing(ctx: ExecContext | None):
    prev = current_exec_context()
    _ctx.value = ctx
    try:
        yield
    finally:
        _ctx.value = prev


def node_axis():
    """The mesh axis name(s) carrying the node dimension, or None when the
    nodes are stacked locally."""
    ctx = current_exec_context()
    return None if ctx is None else ctx.node_axis


def num_node_shards() -> int:
    """How many shards the leading node axis is split over (1 locally).
    Strategies that derive per-node weights from ``data.shape[0]`` must
    multiply by this to recover the GLOBAL node count."""
    ctx = current_exec_context()
    return 1 if ctx is None else ctx.num_shards


def node_shard_index():
    """This shard's linear index along the node axis (0 locally) — the
    row-major position matching how ``P(node_axis)`` lays node slices out,
    so a strategy running on REPLICATED data can reconstruct which global
    nodes it owns (``shard * K_local + arange(K_local)``)."""
    ctx = current_exec_context()
    if ctx is None or ctx.node_axis is None:
        return jnp.asarray(0, jnp.int32)
    axes = (
        (ctx.node_axis,) if isinstance(ctx.node_axis, str) else ctx.node_axis
    )
    sizes = ctx.axis_sizes
    if sizes is None:
        if len(axes) > 1:
            raise ValueError(
                "node_shard_index over a multi-axis node placement needs "
                "ExecContext.axis_sizes (set by the mesh executors)"
            )
        sizes = (1,)  # single axis: the multiplier never applies
    idx = jnp.asarray(0, jnp.int32)
    for a, s in zip(axes, sizes):
        idx = idx * s + jax.lax.axis_index(a)
    return idx


def node_global_index(k_local):
    """Global node index of shard-local node ``k_local`` (identity
    locally).  Server-family strategies that index REPLICATED per-node
    structures — a pooled θ slot block, a stacked per-node RNG key array
    — recover the global position with this while still reading their
    data shard at the local index (the k-windows strategy is the
    canonical user)::

        def local_step(self, k, theta, state, data):
            kg = _exec.node_global_index(k)      # slot into replicated pools
            win = kwindows(state[kg], data[k], ...)
    """
    ctx = current_exec_context()
    if ctx is None or ctx.node_axis is None:
        return k_local
    return node_shard_index() * ctx.nodes_per_shard + k_local


def local_rows(x):
    """This shard's slice of a REPLICATED leading-node-axis array
    (identity locally).  The fault layer's per-round participation masks
    are global ``(K,)`` jit arguments replicated to every shard; each
    shard masks only the message rows it owns, so the masked aggregate
    is placement-invariant."""
    ctx = current_exec_context()
    if ctx is None or ctx.node_axis is None:
        return x
    Kl = ctx.nodes_per_shard
    return jax.lax.dynamic_slice_in_dim(
        x, node_shard_index() * Kl, Kl, axis=0
    )


def local_node(k):
    """Resolve a GLOBAL node index against this shard: returns
    ``(k_local, mine)`` where ``k_local`` indexes the shard's node slice
    (clamped into range, so non-owners can still trace the computation)
    and ``mine`` is True on exactly the shard hosting node ``k``.
    Locally this is the identity ``(k, True)``.

    This is how the §5 *sequential* schedule places on a mesh: a
    ``lax.switch`` over shards is not expressible inside ``shard_map``
    (every shard runs the same program), so each shard computes the
    contacted node's ``local_step`` masked — only the owner's result is
    real — and ``from_owner`` replicates it with one ``psum``.
    """
    ctx = current_exec_context()
    if ctx is None or ctx.node_axis is None:
        return k, jnp.asarray(True)
    Kl = ctx.nodes_per_shard
    off = k - node_shard_index() * Kl
    mine = (off >= 0) & (off < Kl)
    return jnp.clip(off, 0, Kl - 1), mine


def from_owner(tree: PyTree, mine) -> PyTree:
    """Replicate the owning shard's value to every shard (identity
    locally).  ``mine`` must be True on exactly one shard along the node
    axis; everyone else's contribution is zeroed, so the ``psum`` is an
    exact (fp-addition-with-zeros) broadcast of the owner's ``tree``."""
    ctx = current_exec_context()
    if ctx is None or ctx.node_axis is None:
        return tree

    def sel(x):
        if x.dtype == jnp.bool_:
            masked = jnp.where(mine, x, False)
            return jax.lax.psum(masked.astype(jnp.int32), ctx.node_axis) > 0
        return jax.lax.psum(
            jnp.where(mine, x, jnp.zeros_like(x)), ctx.node_axis
        )

    return jax.tree.map(sel, tree)


def commit_owner(new: PyTree, old: PyTree, mine) -> PyTree:
    """Commit a shard-LOCAL state update only on the owning shard: the
    owner keeps ``new``, everyone else keeps ``old`` (locally: ``new``).
    This is how per-node wire state (error-feedback residuals) stays
    correct under a mesh-placed server transport — non-owner shards
    trace the same encode but must not corrupt their rows."""
    ctx = current_exec_context()
    if ctx is None or ctx.node_axis is None:
        return new
    return jax.tree.map(lambda n, o: jnp.where(mine, n, o), new, old)


def aggregate(stacked: PyTree, op: str = "sum") -> PyTree:
    """Reduce per-node messages over the node axis, wherever it lives:
    the (shard-local) stacked axis 0, then — under a mesh placement — the
    native collective across shards, staged hop by hop through the
    ambient ``Topology`` (intra-pod psum first, inter-pod allreduce
    last; a flat topology is one joint collective).  Locally this IS
    ``server_allreduce`` (bit-exact with the pre-executor engine)."""
    reduced = server_allreduce(stacked, op=op)
    ctx = current_exec_context()
    if ctx is not None and ctx.node_axis is not None:
        if ctx.topology is not None:
            reduced = hierarchical_allreduce(
                reduced, ctx.topology.hops, op=op,
                reduce_scatter=ctx.reduce_scatter,
                axis_sizes=_ctx_size_map(ctx),
            )
        else:
            reduced = mesh_allreduce(reduced, ctx.node_axis, op=op)
    return reduced


def _ctx_size_map(ctx: ExecContext):
    """axis → shard count mapping for the ambient placement (None when the
    executor did not record sizes)."""
    if ctx.axis_sizes is None:
        return None
    axes = (
        (ctx.node_axis,) if isinstance(ctx.node_axis, str) else ctx.node_axis
    )
    return dict(zip(axes, ctx.axis_sizes))


def _overlap_hops(ctx: ExecContext):
    """The hop list the overlap split is defined over: the topology's
    hops, or the whole node axis as one hop (flat meshes)."""
    if ctx.topology is not None:
        return ctx.topology.hops
    return (ctx.node_axis,)


def aggregate_partial(stacked: PyTree, op: str = "sum") -> PyTree:
    """First half of the comm/compute-overlap split of ``aggregate``:
    the shard-local stack sum plus every hop EXCEPT the outermost
    (intra-pod under multipod; nothing extra on a flat mesh).  The
    outermost (expensive, inter-pod) hop is deferred — apply
    ``aggregate_complete`` one round later, so XLA can overlap the slow
    collective with the next round's local compute.  Sum-only: splitting
    a mean's final divide across rounds would break bit-exactness."""
    if op != "sum":
        raise ValueError(
            f"aggregate_partial only supports op='sum' (got {op!r}) — the "
            "overlap split defers the outermost hop, and a mean's final "
            "divide cannot move across rounds bit-exactly"
        )
    reduced = server_allreduce(stacked, op="sum")
    ctx = current_exec_context()
    if ctx is not None and ctx.node_axis is not None:
        reduced = partial_allreduce(reduced, _overlap_hops(ctx))
    return reduced


def aggregate_complete(pending: PyTree) -> PyTree:
    """Second half of the overlap split: the outermost hop's psum over a
    round-old ``aggregate_partial`` result.  Identity locally."""
    ctx = current_exec_context()
    if ctx is not None and ctx.node_axis is not None:
        return complete_allreduce(pending, _overlap_hops(ctx))
    return pending


def mask_to_root(tree: PyTree) -> PyTree:
    """Zero ``tree`` everywhere except the shards at index 0 of the
    OUTERMOST hop's axes.  Converts an already-complete (replicated)
    value into valid ``aggregate_complete`` input: the completing psum
    re-adds one real copy plus zeros — exact in fp — so a standard delay
    buffer slot can enter the overlapped schedule bit-exactly.  Identity
    locally."""
    ctx = current_exec_context()
    if ctx is None or ctx.node_axis is None:
        return tree
    outer = _overlap_hops(ctx)[-1]
    axes = getattr(outer, "axes", outer)
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    keep = None
    for a in axes:
        at_root = jax.lax.axis_index(a) == 0
        keep = at_root if keep is None else jnp.logical_and(keep, at_root)
    return jax.tree.map(
        lambda x: jnp.where(keep, x, jnp.zeros_like(x)), tree
    )


def broadcast(tree: PyTree) -> PyTree:
    """Phase 2 of the §3.1 two-step protocol: hand the aggregate back to
    every node.  ``aggregate`` already returns a replicated value under
    every placement, so this is the identity — it exists so transports can
    mark the downlink point explicitly (and future executors with
    non-replicating collectives have a hook)."""
    return tree


class StatsDeferral:
    """Trace-time flags for deferred statistics collectives.

    Per-step scalar stats (``metric_mean``'s pmean, ``sum_bytes``'s psum)
    each launch a tiny collective INSIDE the scan — pure per-round
    latency.  Both are elementwise across steps, so reducing the stacked
    ``(T,)`` outputs once after the loop is bitwise identical.  The
    transport allocates one of these, installs it with ``deferring``
    while tracing the step, and completes whatever got deferred in its
    ``exit_loop`` hook.  Valid only when the stat call is the OUTERMOST
    op of its expression (true for every in-repo ``round_metric``) —
    strategies that post-process the completed mean opt out via
    ``Strategy.defer_stats = False``.
    """

    __slots__ = ("metric", "bytes")

    def __init__(self):
        self.metric = False
        self.bytes = False


_defer = threading.local()


@contextmanager
def deferring(stats: StatsDeferral | None):
    """Route ``metric_mean``/``sum_bytes`` calls into deferred mode for
    the enclosed trace: they record the need on ``stats`` and return
    their input unchanged; the caller completes them post-loop."""
    prev = getattr(_defer, "value", None)
    _defer.value = stats
    try:
        yield
    finally:
        _defer.value = prev


def metric_mean(x: PyTree) -> PyTree:
    """Complete a node-mean statistic across shards (``pmean``); identity
    locally.  Strategies whose ``round_metric`` is a mean over the (local)
    node axis wrap it in this so the metric stays global under the mesh
    executor."""
    ctx = current_exec_context()
    if ctx is not None and ctx.node_axis is not None:
        stats = getattr(_defer, "value", None)
        if stats is not None:
            stats.metric = True
            return x
        return jax.tree.map(lambda v: jax.lax.pmean(v, ctx.node_axis), x)
    return x


def sum_bytes(x):
    """Total a shard-local byte count across shards (``psum``); identity
    locally."""
    ctx = current_exec_context()
    if ctx is not None and ctx.node_axis is not None:
        stats = getattr(_defer, "value", None)
        if stats is not None:
            stats.bytes = True
            return x
        return jax.lax.psum(x, ctx.node_axis)
    return x


# ----------------------------------------------------------------------------
# Program cache
# ----------------------------------------------------------------------------
#
# Profiling (ROADMAP "Make mesh actually fast") showed the mesh gap was
# never the collectives: an EAGER shard_map re-traces and re-lowers the
# whole scan on every fit call (~0.2s for the benchmark program, ~8 pjit
# compiles), while local fits ride jit's C++ dispatch cache.  The fix is
# the same cache, held explicitly: executors jit their placed program and
# memoize it by a config fingerprint, so repeated fits with the same
# strategy/transport/wire configuration skip straight to execution.
# Opt-in: a program is cached only when the transport hands the executor a
# ``cache_key`` (built from ``Strategy.cache_token()`` — strategies with
# unfingerprintable config return None and run uncached, exactly as
# before).  Data, carries and sweep values are jit ARGUMENTS, never baked.

_PROGRAM_CACHE: OrderedDict = OrderedDict()
_PROGRAM_CACHE_CAP = 128
_PROGRAM_CACHE_STATS = {"hits": 0, "misses": 0}


def _cache_enabled() -> bool:
    return os.environ.get("REPRO_PROGRAM_CACHE", "1") != "0"


def cached_program(key, build):
    """``build()`` → a jitted program, memoized under ``key`` (LRU).
    ``key=None`` (or ``REPRO_PROGRAM_CACHE=0``) bypasses the cache."""
    if key is None or not _cache_enabled():
        return build()
    try:
        fn = _PROGRAM_CACHE[key]
        _PROGRAM_CACHE.move_to_end(key)
        _PROGRAM_CACHE_STATS["hits"] += 1
        return fn
    except KeyError:
        pass
    _PROGRAM_CACHE_STATS["misses"] += 1
    fn = build()
    _PROGRAM_CACHE[key] = fn
    while len(_PROGRAM_CACHE) > _PROGRAM_CACHE_CAP:
        _PROGRAM_CACHE.popitem(last=False)
    return fn


def program_cache_stats() -> dict:
    return {"size": len(_PROGRAM_CACHE), **_PROGRAM_CACHE_STATS}


def clear_program_cache() -> None:
    _PROGRAM_CACHE.clear()
    _PROGRAM_CACHE_STATS["hits"] = _PROGRAM_CACHE_STATS["misses"] = 0


def dispatch(key, build, label, *args):
    """``cached_program(key, build)(*args)`` with observability: when a
    tracer is ambient (``fit(..., tracer=...)``), the call is wrapped in
    a ``dispatch/<label>`` span tagged with the cache outcome (``hit`` =
    warm executable, ``miss`` = compile, ``uncached`` = no cache key) and
    fenced with ``jax.block_until_ready`` so the span covers device
    completion.  ``program_cache/{hit,miss,uncached}`` counters
    accumulate alongside.  With no tracer this is byte-for-byte the old
    ``cached_program(key, build)(*args)`` path — the fence is a pure
    wait either way, so traced dispatch stays bit-exact."""
    t = _trace.current_tracer()
    if t is None:
        return cached_program(key, build)(*args)
    if key is None or not _cache_enabled():
        state = "uncached"
        program = cached_program(key, build)
    else:
        hits_before = _PROGRAM_CACHE_STATS["hits"]
        program = cached_program(key, build)
        state = "hit" if _PROGRAM_CACHE_STATS["hits"] > hits_before else "miss"
    t.count(f"program_cache/{state}")
    with t.span(f"dispatch/{label}", cache=state):
        out = program(*args)
        jax.block_until_ready(out)
    return out


# ----------------------------------------------------------------------------
# Executors
# ----------------------------------------------------------------------------


class Executor:
    """Owns where a fit's per-round loop runs.

    Transports hand the executor ``make_carry`` / ``make_step`` factories
    plus the scan inputs; the executor decides placement (stacked scan,
    shard_map'd scan, vmapped scan — or a nesting of those) and installs
    the ambient primitive context the step body's
    ``aggregate``/``metric_mean``/… calls resolve against.  Two run
    hooks, one per transport family:

    * ``run_update(make_carry, make_step, …)`` — update-family
      transports (``allreduce`` / ``delay_line``): every round all nodes
      step, so the loop places anywhere (sharded, vmapped, or both).
      ``make_step(shard_data, sweep_delay)`` builds the per-round step
      against whatever node slice the executor placed here.
    * ``run_server(make_step, schedule, …)`` — server-family transports
      (``sequential_server`` / ``stale_server``): ONE node steps per
      contact.  Local and mesh executors place this (the mesh masks the
      pusher's compute onto its own shard); batching executors raise.
    """

    name = "executor"
    #: number of scenarios for batched executors; None = unbatched
    num_scenarios: int | None = None
    #: capability flag: True when this executor wants the transport to
    #: dispatch the outermost (inter-pod) hop asynchronously against the
    #: next round's local compute (delay-tolerant transports only; the
    #: mesh executors' ``overlap=`` knob)
    overlap: bool = False

    def swept(self, key: str):
        """The per-scenario values swept for ``key`` (None when not swept)."""
        return None

    def scenario_template(self, tree: PyTree) -> PyTree:
        """An unbatched representative of a possibly scenario-batched tree
        (used for shape-static byte accounting)."""
        return tree

    def finalize(self, strategy, theta, state, data):
        """Strategy finalize under this executor's batching (vmapped per
        scenario by the sweep executor; the serving executor additionally
        stands the result up behind an engine)."""
        return strategy.finalize(theta, state, data)

    def extra_metrics(self) -> dict:
        """Executor-specific entries merged into ``FitResult.metrics``
        (e.g. the serving executor's live engine)."""
        return {}

    def ledger_hops(self, strategy, data):
        """Per-tier decomposition of the per-round node messages —
        ``[(tier, messages, price_per_byte), ...]`` summing to K — or
        None for flat (single-tier) ledger accounting.  The engine uses
        this to attribute the materialized ledger's byte totals by hop."""
        return None

    def run_update(
        self, *, strategy, data, carry, make_carry, make_step, xs, length,
        wire=None, cache_key=None, enter_loop=None, exit_loop=None,
        sweep_targets=(),
    ):
        """Place and run the update loop.  ``cache_key`` (optional) keys
        the jitted program cache; ``enter_loop(carry)`` /
        ``exit_loop(carry, ys)`` are transport hooks running INSIDE the
        placed program (ambient context installed) immediately before /
        after the scan — the overlap schedule's carry conversions and the
        deferred-stats completion live there.  ``sweep_targets`` are
        extra objects (fault plans, chain-wire stages) whose attributes
        the sweep executor may rebind per scenario; non-sweep executors
        ignore them."""
        raise NotImplementedError

    def run_server(self, *, strategy, data, carry, make_step, schedule,
                   wire=None, cache_key=None):
        raise ValueError(
            "server transports walk one contact schedule sequentially — "
            f"executor {self.name!r} cannot place them; use "
            "executor='local' (or 'mesh'/'multipod' to run each contact's "
            "local_step on the shard owning the contacted node)"
        )


class LocalExecutor(Executor):
    """K logical nodes stacked on one host, one ``lax.scan``.

    No ambient context is installed, so every primitive is the stacked
    identity and results are bit-exact with the historical loops::

        res = api.fit(strategy, data, transport="allreduce", steps=100)
        # executor="local" is the default — these are the same run
        res = api.fit(strategy, data, transport="allreduce", steps=100,
                      executor="local")
    """

    name = "local"

    def run_update(
        self, *, strategy, data, carry, make_carry, make_step, xs, length,
        wire=None, cache_key=None, enter_loop=None, exit_loop=None,
        sweep_targets=(),
    ):
        if carry is None:
            carry = make_carry()

        def build():
            def prog(c, d, x):
                if enter_loop is not None:
                    c = enter_loop(c)
                c, ys = jax.lax.scan(make_step(d, None), c, x, length=length)
                if exit_loop is not None:
                    c, ys = exit_loop(c, ys)
                return c, ys

            return jax.jit(prog)

        key = (
            None if cache_key is None
            else ("local-update", cache_key, xs is None, length)
        )
        return dispatch(key, build, f"{self.name}-update", carry, data, xs)

    def run_server(self, *, strategy, data, carry, make_step, schedule,
                   wire=None, cache_key=None):
        def build():
            return jax.jit(
                lambda c, d, s: jax.lax.scan(make_step(d), c, s)
            )

        key = None if cache_key is None else ("local-server", cache_key)
        return dispatch(key, build, f"{self.name}-server", carry, data, schedule)


class ServingExecutor(LocalExecutor):
    """Train exactly like ``local``, then stand the finalized model up
    behind a ``repro.serve.ServeEngine`` — the ROADMAP's train→serve
    executor swap.  ``fit(..., executor="serve")`` returns a ``FitResult``
    whose ``metrics["serve_engine"]`` already answers requests (and, with
    ``registry=``/``publish_as=``, has been published first):

        res = api.fit(strategy, data, transport="allreduce", steps=400,
                      executor=api.ServingExecutor(mesh=mesh))
        y = res.metrics["serve_engine"].predict(Xq)
    """

    name = "serve"

    def __init__(
        self, *, mesh=None, registry=None, publish_as: str | None = None,
        **engine_kw,
    ):
        if (registry is None) != (publish_as is None):
            raise ValueError(
                "publishing needs both registry= and publish_as="
            )
        self._mesh = mesh
        self._registry = registry
        self._publish_as = publish_as
        self._engine_kw = engine_kw
        self.engine = None

    def finalize(self, strategy, theta, state, data):
        from repro.serve.engine import ServeEngine

        final = super().finalize(strategy, theta, state, data)
        if self._registry is not None:
            self._registry.publish(self._publish_as, final)
        self.engine = ServeEngine(
            strategy, final, mesh=self._mesh, **self._engine_kw
        )
        return final

    def extra_metrics(self) -> dict:
        return {} if self.engine is None else {"serve_engine": self.engine}


class ResolvedPlacement(NamedTuple):
    """A mesh executor's resolved placement."""

    mesh: Mesh
    axes: tuple  # ordered node axes
    axis: Any  # squashed spec entry: the tuple, or the single axis name
    num_shards: int
    topology: Topology


class MeshExecutor(Executor):
    """Place the K nodes on the data axis of a ``jax.sharding.Mesh``.

    For update transports the whole scan runs inside one ``shard_map``:
    each device hosts K/ndev nodes of the data (and the wire's per-node
    state, e.g. EF residuals), θ and the strategy state stay
    replicated, and ``aggregate`` completes shard-local reductions with
    ``psum``/``pmean`` over the mesh axes — the §3.1 equivalence run in
    the native direction, staged hop by hop through the mesh's implied
    ``Topology`` (pod meshes reduce intra-pod first, then inter-pod;
    1-D meshes keep the single-collective behavior bit-exact).  Wire
    encode/decode executes per shard, so a compressed wire's kernels
    (Pallas ``topk_compress``) sit on the real per-device hot path.
    Server transports place too (``run_server``): the sequential
    schedule walks unchanged, with each contact's local_step masked
    onto the shard owning the contacted node — bit-exact with local.
    A ``SweepExecutor(..., inner=MeshExecutor(...))`` nests its
    scenario vmap inside the shard_map body via ``place_update``.

    ::

        res = api.fit(strategy, data, transport="allreduce", steps=100,
                      executor="mesh")            # all local devices
        res = api.fit(strategy, data, transport="allreduce", steps=100,
                      executor=api.MeshExecutor(mesh))   # explicit mesh

    Strategies with ``replicate_data=True`` (the cascade SVM, whose
    per-node training sets overlap through the shared global-SV pool)
    receive the FULL data on every shard and reconstruct their node
    slice from ``node_shard_index()`` instead (update transports only).

    Mesh resolution order: explicit ``mesh=`` → the active
    ``sharding.rules.MeshContext`` (its ``node_axes``) → a fresh 1-D
    ``("data",)`` mesh over all local devices (``launch.mesh``).  See
    ``docs/EXECUTORS.md``.
    """

    name = "mesh"

    def __init__(
        self,
        mesh: Mesh | None = None,
        *,
        reduce_scatter: bool | str = "auto",
        overlap: bool = True,
    ):
        self._mesh = mesh
        #: "auto" stages the innermost hop as reduce-scatter → all-gather
        #: only on TPU (on CPU the ring passes cost more than they save);
        #: True/False force it.  Either way bit-exact with staged psum.
        self.reduce_scatter = reduce_scatter
        #: let delay-tolerant transports overlap the outermost hop with
        #: the next round's compute (opt-out knob; bit-exact either way)
        self.overlap = bool(overlap)

    def _rs_active(self) -> bool:
        if self.reduce_scatter == "auto":
            return jax.default_backend() == "tpu"
        return bool(self.reduce_scatter)

    def _default_mesh(self) -> Mesh:
        return make_node_mesh()

    def _topology(self, axes, mesh) -> Topology:
        return Topology.from_mesh(axes)

    def _validate_mesh(self, mesh: Mesh) -> None:
        pass

    def resolve(self) -> ResolvedPlacement:
        mesh = self._mesh
        axes = None
        if mesh is None:
            mc = current_mesh_context()
            if mc is not None:
                mesh, axes = mc.mesh, mc.node_axes
            else:
                mesh = self._default_mesh()
        self._validate_mesh(mesh)
        if axes is None:
            axes = batch_axes(mesh)
        if not axes:
            raise ValueError(
                f"mesh {mesh} has no 'data'/'pod' axis to place nodes on"
            )
        # placement keeps the mesh's axis order (pods hold contiguous node
        # ranges); the topology orders the REDUCTION hops independently
        # (intra-pod first, inter-pod last)
        topology = self._topology(axes, mesh)
        axes = tuple(axes)
        axis = axes if len(axes) > 1 else axes[0]
        ndev = 1
        for a in axes:
            ndev *= mesh.shape[a]
        return ResolvedPlacement(
            mesh=mesh, axes=axes, axis=axis, num_shards=ndev, topology=topology
        )

    def _placement_context(self, r: ResolvedPlacement, K: int) -> ExecContext:
        return ExecContext(
            node_axis=r.axis, num_shards=r.num_shards, topology=r.topology,
            axis_sizes=tuple(r.mesh.shape[a] for a in r.axes),
            nodes_per_shard=K // r.num_shards,
            reduce_scatter=self._rs_active(),
        )

    @staticmethod
    def _mesh_fingerprint(mesh: Mesh):
        return (
            tuple(mesh.axis_names),
            tuple(int(mesh.shape[a]) for a in mesh.axis_names),
            tuple(str(d) for d in mesh.devices.flat),
        )

    def _check_divisible(self, K: int, ndev: int) -> None:
        if K % ndev != 0:
            raise ValueError(
                f"{K} nodes cannot be placed evenly on {ndev} mesh shards"
            )

    def place_update(self, *, strategy, data, carry, body, xs,
                     scenario_axis: bool = False, cache_key=None):
        """Shard-map an update-family loop body onto the resolved mesh.

        ``body(carry, shard_data, xs)`` runs per shard with the ambient
        primitive context installed — a plain scan for the bare mesh
        executor, or a scenario-vmapped scan when a ``SweepExecutor``
        composes with this placement (``scenario_axis=True``: every
        carry component then has a leading S axis, so the per-node wire
        state shards on its SECOND axis).  This is the inner-vmap hook
        ``run_update`` is built on.
        """
        from repro.api.strategy import Strategy

        r = self.resolve()
        mesh, axis, ndev = r.mesh, r.axis, r.num_shards
        if data is None:
            raise ValueError(
                "mesh executor needs data with a leading node axis to shard"
            )
        if not strategy.stacked_msgs:
            raise ValueError(
                "mesh executor needs per-node stacked messages "
                "(strategy.stacked_msgs=True)"
            )
        if type(strategy).aggregate is not Strategy.aggregate:
            raise NotImplementedError(
                f"{type(strategy).__name__} overrides aggregate(); the mesh "
                "executor only places op-based reductions (set aggregate_op "
                "to 'sum'/'mean'/'max'/'any' instead)"
            )
        K = strategy.num_nodes(data)
        self._check_divisible(K, ndev)
        ctx = self._placement_context(r, K)
        # carry = (theta, strategy state, wire state, delay line): everything
        # replicated except the per-node wire state, which lives with its node
        wspec = P(None, axis) if scenario_axis else P(axis)
        cspec = (P(), P(), wspec, P())
        # replicate-data strategies see the whole dataset on every shard
        # and slice their own nodes out via node_shard_index()
        dspec = P() if strategy.replicate_data else P(axis)

        def shard_body(c, d, x):
            with executing(ctx):
                return body(c, d, x)

        def build():
            if xs is None:
                inner = shard_map(
                    lambda c, d: shard_body(c, d, None), mesh=mesh,
                    in_specs=(cspec, dspec), out_specs=(cspec, P()),
                    check_rep=False,
                )
                return jax.jit(lambda c, d, x: inner(c, d))
            return jax.jit(shard_map(
                shard_body, mesh=mesh, in_specs=(cspec, dspec, P()),
                out_specs=(cspec, P()), check_rep=False,
            ))

        key = None if cache_key is None else (
            "mesh-update", type(self).__name__, cache_key, scenario_axis,
            xs is None, self._rs_active(), bool(strategy.replicate_data),
            self._mesh_fingerprint(mesh),
        )
        return dispatch(key, build, f"{self.name}-update", carry, data, xs)

    def run_update(
        self, *, strategy, data, carry, make_carry, make_step, xs, length,
        wire=None, cache_key=None, enter_loop=None, exit_loop=None,
        sweep_targets=(),
    ):
        if carry is None:
            carry = make_carry()

        def body(c, d, x):
            if enter_loop is not None:
                c = enter_loop(c)
            c, ys = jax.lax.scan(make_step(d, None), c, x, length=length)
            if exit_loop is not None:
                c, ys = exit_loop(c, ys)
            return c, ys

        key = None if cache_key is None else (cache_key, length)
        return self.place_update(
            strategy=strategy, data=data, carry=carry, body=body, xs=xs,
            cache_key=key,
        )

    def run_server(self, *, strategy, data, carry, make_step, schedule,
                   wire=None, cache_key=None):
        """Place the §5 sequential schedule on the mesh: data shards over
        the node axis, every contact's ``local_step`` runs masked on each
        shard (``local_node`` resolves the contacted node against the
        shard's slice) and only the owner's push survives the
        ``from_owner`` psum — bit-exact with the local walk, because
        adding the non-owners' zeros is exact in fp.

        The strategy's ``state`` stays REPLICATED here: ``local_step``
        must either pass it through or update it identically on every
        shard (true for every in-repo server strategy; per-node mutable
        state belongs in the wire state, which shards with its node and
        commits owner-only).
        """
        if data is None:
            raise ValueError(
                "mesh-placed server transports need data with a leading "
                "node axis to shard; closure-based strategies "
                "(FunctionStrategy over captured data) run executor='local'"
            )
        if strategy.replicate_data:
            raise ValueError(
                f"{type(strategy).__name__} declares replicate_data=True — "
                "its contacts read the whole dataset, so there is nothing "
                "to place; use executor='local' for server transports"
            )
        r = self.resolve()
        mesh, axis, ndev = r.mesh, r.axis, r.num_shards
        K = strategy.num_nodes(data)
        self._check_divisible(K, ndev)
        ctx = self._placement_context(r, K)
        # carry = (server state, strategy state, wire state): the server
        # and strategy state are replicated, the per-node wire state
        # (EF residuals) lives with its node's shard
        cspec = (P(), P(), P(axis))

        def body(c, d, sched):
            with executing(ctx):
                return jax.lax.scan(make_step(d), c, sched)

        def build():
            return jax.jit(shard_map(
                body, mesh=mesh, in_specs=(cspec, P(axis), P()),
                out_specs=(cspec, P()), check_rep=False,
            ))

        key = None if cache_key is None else (
            "mesh-server", type(self).__name__, cache_key,
            self._rs_active(), self._mesh_fingerprint(mesh),
        )
        return dispatch(
            key, build, f"{self.name}-server", carry, data, schedule
        )


class MultiPodExecutor(MeshExecutor):
    """The production placement: nodes on ``("pod", "data")`` of a
    multi-pod mesh, with the ledger decomposed by reduction tier.

    Execution is the same shard_map'd step as ``MeshExecutor`` on the
    same mesh — the staged intra-pod-psum + inter-pod-allreduce program
    both executors derive from the mesh's ``Topology`` — so the theta
    trajectory is bit-exact with ``executor="mesh"``.  What changes is
    the accounting: ``ledger_hops`` attributes the per-round node
    messages to tiers (K−P intra-pod pushes, P inter-pod root pushes for
    P pods), each priced per byte, so ``ledger.summary()["by_hop"]``
    reports the paper's cheap-vs-expensive link split instead of one
    lump sum.

    Mesh resolution order: explicit ``mesh=`` → the active
    ``sharding.rules.MeshContext`` → ``launch.mesh.make_multipod_mesh()``
    over the local devices (pass
    ``make_production_mesh(multi_pod=True)`` explicitly for the 512-chip
    production shape).
    """

    name = "multipod"

    def __init__(
        self,
        mesh: Mesh | None = None,
        *,
        intra_price: float | None = None,
        inter_price: float | None = None,
        calibrate: bool = False,
        reduce_scatter: bool | str = "auto",
        overlap: bool = True,
    ):
        super().__init__(mesh, reduce_scatter=reduce_scatter, overlap=overlap)
        self._intra_price = intra_price
        self._inter_price = inter_price
        #: measure per-hop prices on the actual mesh instead of the ×1/×10
        #: defaults (``core.topology.calibrate_prices`` — one-shot,
        #: memoized per device set); explicit ``*_price=`` overrides win
        self._calibrate = calibrate

    def _default_mesh(self) -> Mesh:
        return make_multipod_mesh()

    def _topology(self, axes, mesh) -> Topology:
        intra_p, inter_p = self._intra_price, self._inter_price
        if self._calibrate:
            from repro.core.topology import calibrate_prices

            prices = calibrate_prices(mesh)
            if intra_p is None:
                intra_p = prices["intra_pod"]
            if inter_p is None:
                inter_p = prices["inter_pod"]
        return Topology.from_mesh(
            axes, intra_price=intra_p, inter_price=inter_p
        )

    def _validate_mesh(self, mesh: Mesh) -> None:
        if "pod" not in mesh.axis_names:
            raise ValueError(
                f"multipod executor needs a mesh with a 'pod' axis, got "
                f"axes {mesh.axis_names} — build one with "
                "launch.mesh.make_multipod_mesh() or "
                "make_production_mesh(multi_pod=True)"
            )

    def ledger_hops(self, strategy, data):
        r = self.resolve()
        K = strategy.num_nodes(data)
        return r.topology.hop_messages(K, dict(r.mesh.shape))


class SweepExecutor(Executor):
    """Batch S scenarios into one executable with ``jax.vmap``.

    ``params`` maps names to length-S arrays:

    * a strategy attribute name (``"lr"``, ``"l2"``, ``"rho"``, …) — the
      attribute is rebound per scenario while the step is traced, so any
      scalar hyperparameter a strategy reads from ``self`` sweeps without
      the strategy knowing;
    * a WIRE attribute name (names not found on the strategy are looked
      up on the wire) — e.g. the threshold wire's ``"tau"``, which makes
      the compression ratio itself sweepable: the sparsifier is
      value-dependent but shape-static, so S thresholds share one
      executable where per-scenario top-k fractions would each need a
      different static k;
    * the reserved key ``"staleness"`` — handled by the update transport,
      which sizes one depth-max(D) delay line and reads it at a batched
      per-scenario index (``core.staleness.delay_push_read``), so D=0…D_max
      share one compiled program;
    * the reserved key ``"theta0"`` — a (S, …)-batched initial parameter.

    Structural knobs (top-k fraction, wire choice, transport identity)
    change compiled shapes and cannot ride one executable — run those as
    separate ``fit`` calls.

    ``inner=`` composes the sweep with a mesh placement: with
    ``SweepExecutor(params, inner=MeshExecutor(...))`` (spec strings
    ``"mesh+sweep"`` / ``"multipod+sweep"`` + ``fit(..., sweep=params)``)
    the scenario vmap runs INSIDE the shard_map body — each device hosts
    its node slice and trains all S scenarios on it in one executable,
    so a hyperparameter search saturates the mesh instead of idling it::

        sw = api.SweepExecutor({"lr": jnp.asarray([0.02, 0.1])},
                               inner=api.MeshExecutor(mesh))
        res = api.fit(strategy, data, transport="allreduce", steps=200,
                      executor=sw)   # == executor="mesh+sweep", sweep={...}

    Results are bit-exact with S independent fits on the same inner
    executor, and a ``MultiPodExecutor`` inner keeps its per-hop ledger
    decomposition — per scenario.

    The engine materializes one ``CommLedger`` per scenario from the
    batched byte counts; ``FitResult.theta`` / ``.trajectory`` /
    ``metrics["carry"]`` all gain a leading S axis (the carry resumes a
    later swept ``fit`` with the same executor shape).
    """

    name = "sweep"
    RESERVED = ("staleness", "theta0")

    def __init__(self, params: dict, *, inner: "Executor | str | None" = None):
        if not params:
            raise ValueError("sweep executor needs at least one swept parameter")
        # values may be pytrees (a batched theta0 for model-pytree
        # strategies); every leaf's leading axis is the scenario axis
        self.params = {
            k: jax.tree.map(jnp.asarray, v) for k, v in params.items()
        }
        counts = {}
        for k, v in self.params.items():
            leaves = jax.tree.leaves(v)
            if not leaves:
                raise ValueError(f"swept parameter {k!r} has no array leaves")
            per_leaf = {int(leaf.shape[0]) for leaf in leaves}
            if len(per_leaf) != 1:
                raise ValueError(
                    f"swept parameter {k!r} leaves disagree on scenario count"
                )
            counts[k] = per_leaf.pop()
        if len(set(counts.values())) != 1:
            raise ValueError(
                f"swept parameters disagree on scenario count: {counts}"
            )
        self.num_scenarios = next(iter(counts.values()))
        if inner is not None and not isinstance(inner, Executor):
            inner = make_executor(inner)
        if isinstance(inner, (ServingExecutor, SweepExecutor)):
            raise ValueError(
                f"sweep cannot nest a {inner.name!r} executor — inner= "
                "takes a mesh placement (MeshExecutor/MultiPodExecutor) "
                "or None/local"
            )
        if isinstance(inner, LocalExecutor):
            inner = None  # local inner ≡ the plain vmapped sweep
        if inner is not None and not isinstance(inner, MeshExecutor):
            raise ValueError(
                f"unsupported sweep inner executor {inner.name!r} — use "
                "MeshExecutor/MultiPodExecutor (or None for the local vmap)"
            )
        self.inner = inner
        if inner is not None:
            self.name = f"{inner.name}+sweep"

    def swept(self, key: str):
        return self.params.get(key)

    def scenario_template(self, tree: PyTree) -> PyTree:
        return jax.tree.map(lambda x: x[0], tree)

    def finalize(self, strategy, theta, state, data):
        from repro.api.strategy import Strategy

        if type(strategy).finalize is Strategy.finalize:
            return theta
        return jax.vmap(lambda th, st: strategy.finalize(th, st, data))(
            theta, state
        )

    def ledger_hops(self, strategy, data):
        # a multipod inner keeps its per-hop pricing — applied by the
        # engine to every scenario's ledger
        if self.inner is None:
            return None
        return self.inner.ledger_hops(strategy, data)

    def _resolve_targets(self, strategy, wire, extra=()):
        attrs = {
            k: v for k, v in self.params.items() if k not in self.RESERVED
        }
        targets = {}
        for k in attrs:
            if hasattr(strategy, k):
                targets[k] = strategy
            elif wire is not None and hasattr(wire, k):
                targets[k] = wire
            else:
                # transport-supplied extras: fault plans, chain-wire stages
                for obj in extra:
                    if obj is not None and hasattr(obj, k):
                        targets[k] = obj
                        break
                else:
                    raise ValueError(
                        f"swept parameter {k!r} is not an attribute of "
                        f"{type(strategy).__name__}, the wire, or the fault "
                        f"plan (reserved keys: {self.RESERVED})"
                    )
        return attrs, targets

    @staticmethod
    @contextmanager
    def _rebound(targets, vals):
        """Rebind swept strategy/wire attributes for the duration of one
        scenario's trace (the saved Python values are restored after)."""
        saved = {k: getattr(targets[k], k) for k in vals}
        try:
            for k, v in vals.items():
                setattr(targets[k], k, v)
            yield
        finally:
            for k, v in saved.items():
                setattr(targets[k], k, v)

    def _params_fingerprint(self):
        """Byte-level fingerprint of the swept values — the composed path
        closes over them (they become compiled constants), so they must
        key the program cache."""
        import numpy as np

        out = []
        for k in sorted(self.params):
            for leaf in jax.tree.leaves(self.params[k]):
                a = np.asarray(leaf)
                out.append((k, str(a.dtype), a.shape, a.tobytes()))
        return tuple(out)

    def run_update(
        self, *, strategy, data, carry, make_carry, make_step, xs, length,
        wire=None, cache_key=None, enter_loop=None, exit_loop=None,
        sweep_targets=(),
    ):
        attrs, targets = self._resolve_targets(strategy, wire, sweep_targets)
        stal = self.params.get("staleness")
        theta0s = self.params.get("theta0")

        # The scenario-batched carry is built OUTSIDE any cached program:
        # theta0 resolution can read data values, so baking it into a
        # memoized executable would pin the first fit's start point.
        if carry is None:
            if attrs or theta0s is not None:

                def build_carry(vals, th0):
                    with self._rebound(targets, vals):
                        return (
                            make_carry() if th0 is None
                            else make_carry(theta0=th0)
                        )

                carry = jax.vmap(
                    build_carry,
                    in_axes=(
                        {k: 0 for k in attrs},
                        None if theta0s is None else 0,
                    ),
                )(attrs, theta0s)
            else:
                # only "staleness" swept: every scenario starts from the
                # same carry; the lanes diverge through the read index
                c0 = make_carry()
                S = self.num_scenarios
                carry = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (S,) + x.shape), c0
                )

        # enter_loop is the overlap hook; sweeps never activate overlap
        # (Executor.overlap stays False here), so only the stats-completion
        # exit hook is threaded through — applied to the full (S, T, …)
        # stack, where the deferred collectives stay elementwise.
        if self.inner is None:

            def build():
                def prog(attrs_, stal_, c_, d_, x_):
                    def one(vals, st, c1):
                        with self._rebound(targets, vals):
                            return jax.lax.scan(
                                make_step(d_, st), c1, x_, length=length
                            )

                    c2, ys = jax.vmap(
                        one,
                        in_axes=(
                            {k: 0 for k in attrs},
                            None if stal is None else 0,
                            0,
                        ),
                    )(attrs_, stal_, c_)
                    if exit_loop is not None:
                        c2, ys = exit_loop(c2, ys)
                    return c2, ys

                return jax.jit(prog)

            key = None if cache_key is None else (
                "sweep-local", cache_key, tuple(sorted(attrs)),
                stal is None, xs is None, length, self.num_scenarios,
            )
            return dispatch(
                key, build, f"{self.name}-update", attrs, stal, carry, data, xs
            )

        # --- mesh-composed: scenario vmap INSIDE the shard_map body ---
        # Each shard vmaps the scan over scenarios, so the executable is
        # shard_map(vmap(scan)) — S scenarios per device.  The swept
        # values are compiled constants here, hence the fingerprint in
        # the cache key.
        def body(c, d, x):
            def one(vals, st, c1):
                with self._rebound(targets, vals):
                    return jax.lax.scan(
                        make_step(d, st), c1, x, length=length
                    )

            c2, ys = jax.vmap(
                one,
                in_axes=({k: 0 for k in attrs}, None if stal is None else 0, 0),
            )(attrs, stal, c)
            if exit_loop is not None:
                c2, ys = exit_loop(c2, ys)
            return c2, ys

        key = None if cache_key is None else (
            "sweep-composed", cache_key, tuple(sorted(attrs)),
            stal is None, length, self.num_scenarios,
            self._params_fingerprint(),
        )
        return self.inner.place_update(
            strategy=strategy, data=data, carry=carry, body=body, xs=xs,
            scenario_axis=True, cache_key=key,
        )

    def run_server(self, *, strategy, data, carry, make_step, schedule,
                   wire=None, cache_key=None):
        raise ValueError(
            "server transports walk one contact schedule sequentially — "
            "the sweep executor cannot batch them; use executor='local' "
            "(or 'mesh'/'multipod' for shard placement)"
        )


EXECUTORS = ("local", "mesh", "multipod", "sweep", "serve")
#: composed spec strings: the sweep's scenario vmap nested inside a mesh
#: placement (scenario values via ``fit(..., sweep={...})``)
COMPOSED_EXECUTORS = ("mesh+sweep", "multipod+sweep")


def make_executor(
    spec: str | Executor | None, sweep_params: dict | None = None
) -> Executor:
    """Resolve an executor spec.

    ``spec`` is an ``Executor`` instance, ``None``/``"local"``, ``"mesh"``
    (nodes over all local devices / the active mesh context),
    ``"multipod"`` (the ``("pod", "data")`` hierarchical placement with
    per-hop ledger pricing), ``"serve"`` (local fit, finalized model
    handed to a ``ServeEngine``), ``"sweep"``, or a composed
    ``"mesh+sweep"`` / ``"multipod+sweep"`` — the scenario vmap nested
    inside the shard_map body.  The sweep spec strings need their
    scenario values supplied as ``sweep_params`` (what ``fit``'s
    ``sweep=`` kwarg forwards)::

        make_executor("mesh+sweep", {"lr": jnp.asarray([0.02, 0.1])})
        # ≡ SweepExecutor({"lr": ...}, inner=MeshExecutor())

    Configured instances (``MeshExecutor(mesh)``, ``MultiPodExecutor(
    mesh, intra_price=, inter_price=)``, ``SweepExecutor(params,
    inner=)``, ``ServingExecutor(...)``) pass through unchanged.
    """
    if isinstance(spec, Executor):
        if sweep_params is not None:
            raise ValueError(
                "sweep= only applies to string executor specs — configure "
                "SweepExecutor(params, inner=...) directly instead"
            )
        return spec
    parts = tuple((spec or "local").split("+"))
    if "sweep" in parts:
        inner_parts = tuple(p for p in parts if p != "sweep")
        if len(inner_parts) + 1 != len(parts) or inner_parts not in (
            (), ("local",), ("mesh",), ("multipod",)
        ):
            raise ValueError(
                f"unknown executor {spec!r} — sweep composes as "
                f"{COMPOSED_EXECUTORS}"
            )
        if sweep_params is None:
            raise ValueError(
                "the sweep executor needs scenario parameters — pass "
                "fit(..., sweep={'lr': [...], ...}) alongside the spec "
                "string, or a configured api.SweepExecutor({...})"
            )
        inner = inner_parts[0] if inner_parts else None
        return SweepExecutor(sweep_params, inner=inner)
    if sweep_params is not None:
        base = spec or "local"
        hint = (
            f"executor='{base}+sweep' (or 'sweep')"
            if base in ("local", "mesh", "multipod")
            else f"one of {COMPOSED_EXECUTORS} or 'sweep'"
        )
        raise ValueError(
            f"sweep= scenario parameters need a sweep executor — {hint}"
        )
    if spec is None or spec == "local":
        return LocalExecutor()
    if spec == "mesh":
        return MeshExecutor()
    if spec == "multipod":
        return MultiPodExecutor()
    if spec == "serve":
        return ServingExecutor()
    raise ValueError(f"unknown executor {spec!r} — one of {EXECUTORS}")
