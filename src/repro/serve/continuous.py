"""Continuous-batching LM decode plane: slot-scheduled serving over a
paged KV cache.

The bucketed ``MicroBatcher`` path is batch-synchronous: a request that
finishes generating early stalls its bucket, and new arrivals wait for
the next one.  This module admits and retires requests independently —
the serving shape the client-side surveys (arXiv:1909.08329,
arXiv:1909.08364) identify as where production inference throughput
comes from:

* ``DecodeScheduler`` owns the host-side control plane: ``n_slots``
  decode slots, a ``PageAllocator`` over one shared ``PagedKVCache``
  arena, the slot → page **block table**, and a FIFO backlog for
  requests the arena cannot place yet.
* ``ContinuousLMEngine`` owns the data plane: ONE compiled step advances
  every slot one token against the persistent paged cache (donated on
  accelerators).  The block table, per-slot lengths and sampling seeds
  are jit *arguments* — host numpy of static shape — so joins, leaves
  and evictions are pure data changes: **the compiled step never
  retraces** (asserted via ``compiled_step_cache_size`` and
  ``program_cache_stats()``).  Joins prefill the prompt through the
  dense B=1 decode path (power-of-two prompt buckets) and scatter the
  result into the slot's pages; leaves just free the pages — freed rows
  point at the null page, so in-flight garbage writes stay invisible.

Attention on the hot path runs through ``kernels/decode_attention``
(``use_kernel="auto"``: the Pallas kernel on TPU, its bit-equal jitted
XLA reference elsewhere), with the choice reported in ``kernel_plan``
and per-token hit counts in ``kernel_hits`` — the serve-side analogue of
``wire_kernel_hits``.

Requests resolve through the same ``Ticket`` handle the batcher uses; an
evicted or errored request **fails its ticket immediately** instead of
hanging until timeout.
"""

from __future__ import annotations

import math
import threading
import time
from collections import deque
from contextlib import nullcontext
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.executor import cached_program
from repro.models import transformer as tf
from repro.models.attention import decode_kernel_plan, resolve_decode_attn
from repro.models.cache import NULL_PAGE, PageAllocator
from repro.models.config import ModelConfig
from repro.serve.batcher import Ticket
from repro.serve.metrics import ServeMetrics
from repro.telemetry import trace as _trace


class EvictedError(RuntimeError):
    """Raised from ``Ticket.result()`` when the request was evicted
    mid-generation (admin action or slot reclaim) rather than completed."""


@dataclass
class _Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new: int
    ticket: Ticket
    t_submit: float
    seed: int
    slot: int = -1
    pages: list = field(default_factory=list)
    tokens: list = field(default_factory=list)


class DecodeScheduler:
    """Host-side control plane: slots, pages, backlog.

    Admission is all-or-nothing: a request needs a free slot AND enough
    pages for its whole lifetime (``ceil((prompt + max_new) / page_size)``
    — known up front, so a placed request can never run out of pages
    mid-generation).  When either is missing the request waits in the
    FIFO backlog; it is admitted the moment a retiring request frees
    capacity.  Long and short requests draw from the same arena, so
    ``n_pages`` can be provisioned well below
    ``n_slots × pages_per_slot``.
    """

    def __init__(self, *, n_slots: int, n_pages: int, page_size: int,
                 max_seq: int):
        if max_seq < 1:
            raise ValueError(f"max_seq={max_seq}")
        self.n_slots = n_slots
        self.page_size = page_size
        self.max_seq = max_seq
        self.pages_per_slot = -(-max_seq // page_size)
        self.alloc = PageAllocator(n_pages)
        self.block = np.full(
            (n_slots, self.pages_per_slot), NULL_PAGE, np.int32
        )
        self.length = np.zeros((n_slots,), np.int32)
        self.slots: list = [None] * n_slots
        self.backlog: deque = deque()

    # -- capacity ------------------------------------------------------------

    def pages_needed(self, req: _Request) -> int:
        return -(-(len(req.prompt) + req.max_new) // self.page_size)

    def check_fits(self, req: _Request) -> None:
        """Raise if ``req`` could never be placed, even on an idle arena."""
        total = len(req.prompt) + req.max_new
        if total > self.max_seq:
            raise ValueError(
                f"request needs {total} positions > max_seq={self.max_seq}"
            )
        if self.pages_needed(req) > self.alloc.n_pages - 1:
            raise ValueError(
                f"request needs {self.pages_needed(req)} pages but the "
                f"arena only has {self.alloc.n_pages - 1} allocatable"
            )

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)

    # -- join / leave --------------------------------------------------------

    def admit(self, req: _Request) -> int | None:
        """Place ``req`` in a free slot with pages reserved, or return
        None (caller keeps it in the backlog)."""
        slot = next(
            (s for s, r in enumerate(self.slots) if r is None), None
        )
        if slot is None:
            return None
        pages = self.alloc.alloc(self.pages_needed(req))
        if pages is None:
            return None
        self.slots[slot] = req
        req.slot = slot
        req.pages = pages
        self.block[slot, :] = NULL_PAGE
        self.block[slot, : len(pages)] = pages
        self.length[slot] = 0
        return slot

    def release(self, slot: int) -> _Request:
        """Free a slot's pages and point its block row back at the null
        page (the compiled step keeps 'writing' for this slot — into
        trash memory no live sequence can see)."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"release of empty slot {slot}")
        self.alloc.free(req.pages)
        req.pages = []
        req.slot = -1
        self.slots[slot] = None
        self.block[slot, :] = NULL_PAGE
        self.length[slot] = 0
        return req


def _build_step(cfg: ModelConfig, impl: str, temperature: float,
                donate: bool):
    """The ONE compiled program of the decode plane: advance every slot a
    token and sample the next on device (no (n_slots, V) transfer).

    Sampling keys are ``fold_in(key(seed), position)`` — a pure function
    of per-request data, so a request's sampled tokens are invariant to
    which slot it landed in and who else is in flight.
    """

    def step(params, tokens, cache, block, length, seeds):
        logits, new_cache = tf.paged_decode_step(
            params, cfg, tokens, cache, block, length, decode_attn=impl
        )
        lg = logits[:, 0, : cfg.vocab_size]
        if temperature > 0:
            keys = jax.vmap(
                lambda s, p: jax.random.fold_in(jax.random.key(s), p)
            )(seeds, length)
            nxt = jax.vmap(jax.random.categorical)(keys, lg / temperature)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt.astype(jnp.int32), new_cache

    return jax.jit(step, donate_argnames=("cache",) if donate else ())


class ContinuousLMEngine:
    """Slot-scheduled LM serving over a paged KV cache.

    Args:
      cfg / params: an attention-only LM (``init_paged_cache`` rejects
        recurrent/MLA stacks) and its parameters.
      n_slots: in-flight sequences the compiled step advances together.
      page_size: tokens per physical KV page.
      max_seq: longest prompt+generation a request may need (sets the
        block-table width).
      n_pages: arena capacity; default fully provisions
        ``n_slots × max_seq`` (+ the null page).  Smaller values
        oversubscribe — admission control queues what doesn't fit.
      use_kernel: decode-attention path — True forces the Pallas kernel,
        False the jitted XLA reference, "auto" picks by backend; the
        decision is reported in ``kernel_plan``.
      temperature / seed: sampling knobs (0 → greedy argmax).
      metrics / tracer / tag: same observability surfaces as
        ``ServeEngine`` (``RunReport.from_serve`` accepts either).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        n_slots: int = 8,
        page_size: int = 16,
        max_seq: int = 256,
        n_pages: int | None = None,
        use_kernel="auto",
        temperature: float = 0.0,
        seed: int = 0,
        metrics: ServeMetrics | None = None,
        tracer=None,
        tag: str = "serve/continuous",
    ):
        self.cfg = cfg
        self.params = params
        self.tag = tag
        self.temperature = float(temperature)
        self.seed = seed
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.tracer = tracer if tracer is not None else _trace.current_tracer()
        self.kernel_plan = decode_kernel_plan(cfg, use_kernel=use_kernel)
        self._impl = resolve_decode_attn(
            use_kernel, sliding_window=cfg.sliding_window
        )
        #: tokens advanced through each decode-attention implementation —
        #: the serve-side analogue of ``wire_kernel_hits``
        self.kernel_hits = {"pallas": 0, "xla": 0}

        pages_per_slot = -(-max_seq // page_size)
        if n_pages is None:
            n_pages = 1 + n_slots * pages_per_slot
        self.sched = DecodeScheduler(
            n_slots=n_slots, n_pages=n_pages, page_size=page_size,
            max_seq=max_seq,
        )
        self._cache = tf.init_paged_cache(
            cfg, n_pages, page_size, jnp.dtype(cfg.compute_dtype)
        )
        self._last_tok = np.zeros((n_slots,), np.int32)
        self._seeds = np.zeros((n_slots,), np.int32)
        self._rid = 0
        self._lock = threading.RLock()

        donate = jax.default_backend() != "cpu"
        self._step = cached_program(
            ("serve/continuous_step", cfg, n_slots, page_size,
             pages_per_slot, n_pages, self._impl, self.temperature, donate),
            lambda: _build_step(cfg, self._impl, self.temperature, donate),
        )
        self._prefill = cached_program(
            ("serve/continuous_prefill", cfg),
            lambda: jax.jit(
                lambda p, t, c, pos: tf.decode_step(
                    p, cfg, t, c, positions=pos
                )
            ),
        )
        self._insert = cached_program(
            ("serve/continuous_insert", cfg, n_pages, page_size),
            lambda: jax.jit(
                tf.paged_insert_prompt,
                donate_argnames=("paged",) if donate else (),
            ),
        )

    # -- introspection -------------------------------------------------------

    @property
    def compiled_step_cache_size(self) -> int:
        """Distinct traces of the compiled decode step — stays 1 under
        arbitrary join/leave churn (the no-retrace contract)."""
        return self._step._cache_size()

    @property
    def ledger(self):
        return self.metrics.ledger

    def stats(self) -> dict:
        out = self.metrics.summary()
        out["slots"] = self.sched.n_slots
        out["backlog"] = len(self.sched.backlog)
        return out

    # -- request lifecycle ---------------------------------------------------

    def submit(self, prompt, *, max_new: int) -> Ticket:
        """Queue one generation request; returns a ``Ticket`` whose
        ``result()`` is the (max_new,) int32 generated ids."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError(f"max_new={max_new}")
        with self._lock:
            rid = self._rid
            self._rid += 1
            req = _Request(
                rid=rid, prompt=prompt, max_new=max_new,
                ticket=Ticket(self, rid), t_submit=time.perf_counter(),
                seed=(self.seed * 1_000_003 + rid) & 0x7FFFFFFF,
            )
            self.sched.check_fits(req)  # reject the never-servable loudly
            self.sched.backlog.append(req)
        return req.ticket

    def evict(self, ticket: Ticket, reason: str = "evicted") -> None:
        """Drop a request (in flight or queued) and fail its ticket with
        ``EvictedError`` immediately — it never hangs until timeout."""
        with self._lock:
            rid = ticket._key
            req = next(
                (r for r in self.sched.slots if r is not None and r.rid == rid),
                None,
            )
            if req is not None:
                self.sched.release(req.slot)
            else:
                req = next(
                    (r for r in self.sched.backlog if r.rid == rid), None
                )
                if req is None:
                    return  # already resolved
                self.sched.backlog.remove(req)
            self.metrics.record_eviction()
            tr = self.tracer
            if tr is not None:
                tr.count("serve/evictions")
            req.ticket._fail(
                EvictedError(f"request {rid} {reason} after "
                             f"{len(req.tokens)}/{req.max_new} tokens")
            )

    # -- the decode loop -----------------------------------------------------

    def _admit_from_backlog(self) -> int:
        """Join as many queued requests as the arena can place (FIFO — a
        stuck head request must not be starved by smaller later ones)."""
        joined = 0
        while self.sched.backlog:
            req = self.sched.backlog[0]
            slot = self.sched.admit(req)
            if slot is None:
                break
            self.sched.backlog.popleft()
            self._join(req, slot)
            joined += 1
        return joined

    def _join(self, req: _Request, slot: int) -> None:
        """Prefill the prompt (dense B=1 path, power-of-two bucket) and
        scatter the result into the slot's pages; the first generated
        token comes from the prefill logits."""
        P = len(req.prompt)
        bucket = 1 << max(0, (P - 1).bit_length())
        tr = self.tracer
        with (
            tr.span("serve/prefill", prompt=P, bucket=bucket, slot=slot)
            if tr is not None else nullcontext()
        ):
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :P] = req.prompt
            dense = tf.init_cache(
                self.cfg, 1, bucket, jnp.dtype(self.cfg.compute_dtype)
            )
            pos = jnp.broadcast_to(jnp.arange(bucket), (1, bucket))
            logits, dense = self._prefill(
                self.params, jnp.asarray(toks), dense, pos
            )
            self._cache = self._insert(
                self._cache, dense, jnp.asarray(self.sched.block[slot]),
                jnp.asarray(np.int32(P)),
            )
        first = self._sample_host(logits[0, P - 1], req.seed, P - 1)
        req.tokens.append(int(first))
        self.sched.length[slot] = P
        self._last_tok[slot] = first
        self._seeds[slot] = req.seed
        if tr is not None:
            tr.count("serve/joins")
        self._retire_if_done(slot)

    def _sample_host(self, logits_row, seed: int, position: int) -> int:
        """Same sampling math as the compiled step, for the one token that
        comes from prefill logits (key is (seed, position) — slot- and
        occupancy-invariant)."""
        lg = logits_row[: self.cfg.vocab_size]
        if self.temperature > 0:
            key = jax.random.fold_in(jax.random.key(seed), position)
            return int(jax.random.categorical(key, lg / self.temperature))
        return int(jnp.argmax(lg))

    def _retire_if_done(self, slot: int) -> None:
        req = self.sched.slots[slot]
        if req is None or len(req.tokens) < req.max_new:
            return
        self.sched.release(slot)
        e2e = time.perf_counter() - req.t_submit
        out = np.asarray(req.tokens, np.int32)
        self.metrics.record_request_stream(
            len(req.tokens), e2e, request=req.prompt, response=out,
            tag=self.tag,
        )
        tr = self.tracer
        if tr is not None:
            tr.count("serve/requests")
        req.ticket._resolve(out)

    def step(self) -> int:
        """One scheduler tick: admit what fits, advance every slot one
        token, retire finished requests.  Returns tokens produced."""
        with self._lock:
            self._admit_from_backlog()
            active = [s for s, r in enumerate(self.sched.slots) if r is not None]
            if not active:
                return 0
            n_slots = self.sched.n_slots
            tr = self.tracer
            t0 = time.perf_counter()
            try:
                with (
                    tr.span("serve/decode_step", active=len(active),
                            slots=n_slots)
                    if tr is not None else nullcontext()
                ):
                    nxt, self._cache = self._step(
                        self.params,
                        jnp.asarray(self._last_tok[:, None]),
                        self._cache,
                        jnp.asarray(self.sched.block),
                        jnp.asarray(self.sched.length),
                        jnp.asarray(self._seeds),
                    )
                    nxt = np.asarray(jax.block_until_ready(nxt))
            except BaseException as e:
                # fail every in-flight ticket NOW — a dead decode loop
                # must not leave callers hanging until their timeout
                for s in list(active):
                    req = self.sched.release(s)
                    req.ticket._fail(e)
                raise
            dt = time.perf_counter() - t0
            self.metrics.record_decode_step(len(active), n_slots, dt)
            self.kernel_hits[self._impl] += len(active)
            if tr is not None:
                tr.count("serve/decode_tokens", len(active))
                tr.gauge("serve/slot_occupancy", len(active) / n_slots)
            for s in active:
                req = self.sched.slots[s]
                req.tokens.append(int(nxt[s]))
                self.sched.length[s] += 1
                self._last_tok[s] = nxt[s]
                self._retire_if_done(s)
            return len(active)

    def flush(self, key=None) -> int:
        """Drive the loop until request ``key`` resolves (None → until
        idle).  This is the ``Ticket.result()`` hook — the same owner
        protocol the ``MicroBatcher`` implements."""
        served = 0
        while True:
            with self._lock:
                if key is not None:
                    req = self._find(key)
                    if req is None or req.ticket.done:
                        return served
                elif not (self.sched.backlog or self.sched.n_active):
                    return served
            if self.step() == 0:
                with self._lock:
                    if self.sched.backlog and not self.sched.n_active:
                        # nothing in flight frees capacity — unreachable
                        # for requests that passed check_fits, but guard
                        # against a wedged loop anyway
                        raise RuntimeError(
                            "backlog cannot be placed on an idle arena"
                        )
            else:
                served += 1

    def _find(self, rid: int) -> _Request | None:
        # resolved/evicted requests are in neither structure — their
        # tickets already hold the value/error, so flush has no work
        for r in self.sched.slots:
            if r is not None and r.rid == rid:
                return r
        for r in self.sched.backlog:
            if r.rid == rid:
                return r
        return None

    def run_until_idle(self) -> int:
        """Serve everything queued; returns decode steps taken."""
        return self.flush()
