"""Request microbatcher: ragged client traffic → a few static shapes.

Individual inference requests arrive one at a time with whatever shape
their client produced.  Recompiling a predict per batch size would defeat
serving; the batcher instead

* **groups** pending requests by exact per-request shape/dtype (each
  group is one compiled program family),
* **buckets** every flush to the smallest configured batch size that
  fits, padding the tail by repeating the last request (rows are
  independent — see ``Strategy.predict`` — so padding cannot change any
  real answer; padded rows are dropped before tickets resolve and are
  never metered),
* **flushes** a group when it reaches the largest bucket, when ``poll``
  finds its oldest request older than ``timeout_s``, or when a caller
  blocks on a ``Ticket``.

So the steady-state compiled-shape set is |shape groups| × |buckets| —
small and static, however ragged the traffic.
"""

from __future__ import annotations

import threading
import time
from contextlib import nullcontext
from typing import Any, Callable

import jax
import numpy as np

from repro.telemetry import trace as _trace

PyTree = Any


class Ticket:
    """Handle for one submitted request; ``result()`` forces service if
    the request is still queued and WAITS if its batch is already in
    flight on another thread.  A predict failure resolves every ticket of
    the batch with the error, which ``result()`` re-raises — a request is
    never silently lost.

    The owner passed at construction just needs a ``flush(key=...)``
    method serving the keyed request — the ``MicroBatcher`` here, or the
    continuous-batching ``ContinuousLMEngine`` (which additionally fails
    tickets on eviction via ``_fail``)."""

    __slots__ = ("_batcher", "_key", "_value", "_error", "_done")

    def __init__(self, batcher: "MicroBatcher", key):
        self._batcher = batcher
        self._key = key
        self._value = None
        self._error = None
        self._done = threading.Event()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def _resolve(self, value) -> None:
        self._value = value
        self._done.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._done.set()

    def result(self, timeout: float | None = None):
        if not self.done:
            # serve the group if it is still queued; if another thread
            # already popped it, this is a no-op and we wait for it
            self._batcher.flush(key=self._key)
            if not self._done.wait(timeout):
                raise TimeoutError("request still in flight")
        if self._error is not None:
            raise self._error
        return self._value


def _default_buckets(max_batch: int) -> tuple:
    b, out = 1, []
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


class MicroBatcher:
    """Bucketed-padding microbatcher in front of a predict function.

    Args:
      predict: a ``ServeEngine`` (preferred — padded slots are excluded
        from its byte metering) or any row-independent callable
        ``X -> Y``.
      max_batch: largest (and forced-flush) batch bucket.
      buckets: ascending batch buckets; default powers of two up to
        ``max_batch``.
      timeout_s: max age of a queued request before ``poll`` flushes its
        group — the latency bound batching is traded against.
      clock: injectable monotonic clock (tests).
      tracer: optional ``repro.telemetry.trace.Tracer`` recording a
        ``batcher/serve`` span per flush (tagged with bucket, valid
        count and the flushed group's max queue wait) plus padding /
        queue-wait counters; defaults to the ambient tracer at
        construction.  None → zero overhead.
    """

    def __init__(
        self,
        predict,
        *,
        max_batch: int = 8,
        buckets: tuple | None = None,
        timeout_s: float = 0.01,
        clock: Callable[[], float] = time.monotonic,
        tracer=None,
    ):
        from repro.serve.engine import ServeEngine

        if isinstance(predict, ServeEngine):
            self._call = lambda X, n: predict.predict(X, valid=n)
        else:
            self._call = lambda X, n: jax.tree.map(
                lambda y: y[:n], predict(X)
            )
        self.buckets = tuple(sorted(buckets or _default_buckets(max_batch)))
        if buckets is not None and self.buckets[-1] != max_batch:
            raise ValueError(
                f"max_batch={max_batch} must be the largest bucket "
                f"(got buckets={self.buckets}) — pass a matching max_batch"
            )
        self.max_batch = self.buckets[-1]
        self.timeout_s = timeout_s
        self._clock = clock
        self.tracer = tracer if tracer is not None else _trace.current_tracer()
        # the lock guards only the queues — predict runs OUTSIDE it, so a
        # slow decode never blocks submits/polls of other shape groups
        self._lock = threading.Lock()
        self._pending: dict = {}  # key -> list[(np.ndarray, Ticket, t_enq)]
        self.flushes = 0

    def submit(self, x) -> Ticket:
        """Queue one request (a SINGLE example, no batch axis)."""
        x = np.asarray(x)
        key = (x.shape, str(x.dtype))
        with self._lock:
            ticket = Ticket(self, key)
            self._pending.setdefault(key, []).append(
                (x, ticket, self._clock())
            )
            # pop a full group while still holding the lock so no group
            # ever exceeds max_batch (racing submits would otherwise
            # overshoot into an unbucketed shape)
            grp = (
                self._pending.pop(key)
                if len(self._pending[key]) >= self.max_batch
                else None
            )
        if grp:
            self._serve(grp)
        return ticket

    def pending(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._pending.values())

    def poll(self) -> int:
        """Flush every group whose oldest request has waited ≥ timeout_s.
        Returns the number of requests served.

        Errors are isolated per group: a failing predict resolves THAT
        group's tickets with the error (``result()`` re-raises it) and
        polling continues — one poisoned shape group must not kill the
        polling loop and leave every other group's tickets hanging until
        their timeout.
        """
        now = self._clock()
        with self._lock:
            due = [
                key for key, grp in self._pending.items()
                if grp and now - grp[0][2] >= self.timeout_s
            ]
        served = 0
        for key in due:
            try:
                served += self._flush_group(key)
            except Exception:
                pass  # delivered to the group's tickets by _serve
        return served

    def flush(self, key=None) -> int:
        """Serve everything queued (or one shape group). Returns count."""
        with self._lock:
            keys = [key] if key is not None else list(self._pending)
        return sum(self._flush_group(k) for k in keys)

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.max_batch

    def _flush_group(self, key) -> int:
        with self._lock:
            grp = self._pending.pop(key, [])
        return self._serve(grp) if grp else 0

    def _serve(self, grp) -> int:
        n = len(grp)
        bucket = self.bucket_for(n)
        tr = self.tracer
        wait_ms = 0.0
        if tr is not None:
            now = self._clock()
            wait_ms = 1e3 * max(now - t_enq for _, _, t_enq in grp)
            tr.count("batcher/requests", n)
            tr.count("batcher/padded_slots", bucket - n)
            tr.count("batcher/queue_wait_s", sum(
                now - t_enq for _, _, t_enq in grp
            ))
        X = np.stack([x for x, _, _ in grp])
        if bucket > n:
            X = np.concatenate([X, np.repeat(X[-1:], bucket - n, axis=0)])
        try:
            with (
                tr.span(
                    "batcher/serve", bucket=bucket, valid=n,
                    queue_wait_ms=round(wait_ms, 3),
                )
                if tr is not None else nullcontext()
            ):
                Y = self._call(X, n)
        except BaseException as e:
            # BaseException: a KeyboardInterrupt mid-predict must still
            # resolve the batch's tickets, or waiters hang to timeout
            for _, ticket, _ in grp:
                ticket._fail(e)
            raise
        with self._lock:
            self.flushes += 1
        for i, (_, ticket, _) in enumerate(grp):
            ticket._resolve(jax.tree.map(lambda y: y[i], Y))
        return n
