"""``ServeEngine`` — the inference half of the train→serve executor swap.

A fit produces ``FitResult.theta``; the same ``Strategy`` that trained it
knows how to answer requests with it (``Strategy.predict``).  The engine
owns everything WHERE-shaped about serving, mirroring what the training
executors own for fitting:

* **placement** — given a mesh, parameters are sharded on the model axis
  via ``sharding/rules.partition_params`` (the ROADMAP's serving-executor
  note) and request batches on the data axes; without one, everything
  stays local and replicated;
* **compilation** — jittable predicts are compiled once per request
  shape with the request buffer donated (the response reuses it);
  strategies that drive their own decode loop (``predict_jit = False``,
  e.g. LM prefill+decode) are called eagerly;
* **hot-swap** — ``swap(theta)`` atomically replaces the served
  parameters (same placement, no recompile when shapes are unchanged),
  which is what the registry's publish→activate path calls into;
* **accounting** — every answered batch is metered through
  ``ServeMetrics``/``CommLedger`` as a priced ``inference`` message
  (request features up, predictions down), extending the paper's
  client-server cost model from training to deployment traffic.
"""

from __future__ import annotations

import threading
import time
from contextlib import nullcontext
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes, data_axis_size
from repro.serve.metrics import ServeMetrics
from repro.sharding.rules import place_params
from repro.telemetry import trace as _trace

PyTree = Any


class ServeEngine:
    """Serve a finalized model through its strategy's ``predict``.

    Args:
      strategy: the Strategy that produced (or can interpret) ``theta``.
      theta: finalized parameters — ``FitResult.theta`` or a registry load.
      mesh: optional ``jax.sharding.Mesh``; parameters go on
        ``model_axis`` (+ optional ``fsdp_axis``) per the name-based
        partition rules, request batches on the mesh's data axes.
      donate: donate the request buffer to the compiled predict so XLA
        can reuse it for the response (jittable strategies only).
      metrics: a shared ``ServeMetrics`` (one per deployment); fresh by
        default.
      tag: ledger event tag for this engine's inference traffic.
      tracer: optional ``repro.telemetry.trace.Tracer`` recording
        ``serve/predict`` and ``serve/swap`` spans; defaults to the
        ambient tracer at construction (so ``fit(..., executor="serve",
        tracer=...)`` traces its engine automatically).  None → no
        tracing, zero overhead.
    """

    def __init__(
        self,
        strategy,
        theta: PyTree,
        *,
        mesh: Mesh | None = None,
        model_axis: str = "model",
        fsdp_axis: str | None = None,
        donate: bool = True,
        metrics: ServeMetrics | None = None,
        tag: str = "serve",
        tracer=None,
    ):
        self.strategy = strategy
        self.mesh = mesh
        self.model_axis = model_axis
        self.fsdp_axis = fsdp_axis
        self.tag = tag
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.tracer = tracer if tracer is not None else _trace.current_tracer()
        self._lock = threading.Lock()
        self._batch_axes = batch_axes(mesh) if mesh is not None else ()
        self._batch_mul = data_axis_size(mesh) if mesh is not None else 1
        if strategy.predict_jit:
            # CPU never reuses donated buffers and warns per compile
            donate = donate and jax.default_backend() != "cpu"
            donate_args = (1,) if donate else ()
            self._fn = jax.jit(
                lambda th, X: strategy.predict(th, X),
                donate_argnums=donate_args,
            )
            self._donate = donate
        else:
            self._fn = strategy.predict
            self._donate = False
        self.theta = None
        self.swap(theta)

    # -- constructors --------------------------------------------------------

    @classmethod
    def from_fit(cls, result, strategy, **kw) -> "ServeEngine":
        """Stand a finished ``api.fit`` up for inference (its ``theta`` is
        already finalized)."""
        return cls(strategy, result.theta, **kw)

    @classmethod
    def from_registry(
        cls, registry, name: str, strategy, *, version: int | None = None,
        like: PyTree = None, **kw,
    ) -> "ServeEngine":
        """Serve a published model; ``like`` restores non-dict pytrees
        (NamedTuple thetas) into their original structure."""
        return cls(strategy, registry.load(name, version, like=like), **kw)

    # -- placement -----------------------------------------------------------

    def _place(self, theta: PyTree) -> PyTree:
        if self.mesh is None:
            return jax.tree.map(jnp.asarray, theta)
        return place_params(
            self.mesh, theta,
            model_axis=self.model_axis, fsdp_axis=self.fsdp_axis,
        )

    def _place_request(self, X: jnp.ndarray) -> jnp.ndarray:
        if self.mesh is None or not self._batch_axes:
            return X
        axes = (
            self._batch_axes
            if len(self._batch_axes) > 1
            else self._batch_axes[0]
        )
        return jax.device_put(X, NamedSharding(self.mesh, P(axes)))

    # -- serving -------------------------------------------------------------

    def swap(self, theta: PyTree) -> None:
        """Atomically replace the served parameters (registry hot-swap).
        Same pytree structure required; same shapes reuse the compiled
        predict, changed shapes recompile on the next request."""
        if self.theta is not None:
            old = jax.tree_util.tree_structure(self.theta)
            new = jax.tree_util.tree_structure(theta)
            if old != new:
                raise ValueError(
                    f"swap() needs the served pytree structure {old}, got {new}"
                )
        tr = self.tracer
        with tr.span("serve/swap") if tr is not None else nullcontext():
            placed = self._place(theta)
            with self._lock:
                self.theta = placed

    def predict(self, X, *, valid: int | None = None) -> jnp.ndarray:
        """Answer one request batch.

        ``X`` rows are independent requests; ``valid`` marks how many
        leading rows are real (the batcher's bucket padding) — only those
        are returned and metered.  The engine may pad the batch further to
        a device multiple under a mesh; that padding never leaves it.
        """
        caller_owns = isinstance(X, jax.Array)
        X = jnp.asarray(X)
        n = X.shape[0] if valid is None else valid
        # metering needs only shapes — a struct stays valid after the
        # request buffer is donated
        req_ref = jax.ShapeDtypeStruct((n,) + X.shape[1:], X.dtype)
        Xp = X
        pad = (-Xp.shape[0]) % self._batch_mul
        if pad:
            Xp = jnp.concatenate(
                [Xp, jnp.broadcast_to(Xp[-1:], (pad,) + Xp.shape[1:])]
            )
        elif self._donate and caller_owns:
            # host inputs (the batcher path) already produced a fresh
            # device buffer via asarray; only a caller's live jax array
            # must be copied before donation invalidates it
            Xp = jnp.array(X)
        Xp = self._place_request(Xp)
        with self._lock:
            theta = self.theta
        tr = self.tracer
        t0 = time.perf_counter()
        with (
            tr.span("serve/predict", batch=int(Xp.shape[0]), valid=int(n))
            if tr is not None else nullcontext()
        ):
            Y = self._fn(theta, Xp)
            Y = jax.block_until_ready(Y)
        dt = time.perf_counter() - t0
        if tr is not None:
            tr.count("serve/requests", n)
            tr.count("serve/padded_slots", int(Xp.shape[0]) - int(n))
        Y = jax.tree.map(lambda y: y[:n], Y)
        self.metrics.record_batch(
            n, Xp.shape[0], dt, req_ref, Y, tag=self.tag
        )
        return Y

    @property
    def ledger(self):
        return self.metrics.ledger

    def stats(self) -> dict:
        return self.metrics.summary()
