"""``repro.serve`` — sharded batched-inference serving.

The deployment half of the paper's client-server model: the devices that
produced the training data come back with inference requests.  Train→serve
is an executor swap (``api.fit(..., executor="serve")``), and the pieces
compose à la carte:

* ``ServeEngine``   — compiled, mesh-sharded ``Strategy.predict`` with
  hot-swappable parameters (``repro.serve.engine``);
* ``MicroBatcher``  — bucketed-padding request batching with timeout
  flush (``repro.serve.batcher``);
* ``ModelRegistry`` — name/version store over ``checkpoint/io`` with an
  atomic LATEST pointer (``repro.serve.registry``);
* ``ServeMetrics``  — latency/throughput + ``CommLedger`` inference-byte
  metering (``repro.serve.metrics``);
* ``ContinuousLMEngine`` / ``DecodeScheduler`` — continuous-batching LM
  decode over a paged KV cache: requests join and retire independently,
  ONE compiled step advances every slot (``repro.serve.continuous``).

Quickstart (see ``docs/SERVING.md``)::

    res = api.fit(strategy, data, transport="allreduce", steps=400)
    registry = ModelRegistry("registry/")
    registry.publish("linreg", res.theta)
    engine = ServeEngine.from_registry(registry, "linreg", strategy)
    batcher = MicroBatcher(engine, max_batch=8, timeout_s=0.005)
    ticket = batcher.submit(x)          # one client request
    y = ticket.result()                 # bucketed, padded, metered
"""

from repro.serve.batcher import MicroBatcher, Ticket
from repro.serve.continuous import (
    ContinuousLMEngine,
    DecodeScheduler,
    EvictedError,
)
from repro.serve.engine import ServeEngine
from repro.serve.metrics import ServeMetrics
from repro.serve.registry import ModelRegistry

__all__ = [
    "ContinuousLMEngine",
    "DecodeScheduler",
    "EvictedError",
    "MicroBatcher",
    "ModelRegistry",
    "ServeEngine",
    "ServeMetrics",
    "Ticket",
]
