"""Serving metrics: per-request latency/throughput + inference traffic.

The paper's recurring evaluation axis is communication cost under the
strict client-server model; serving extends that model from training
messages to inference traffic — every answered batch is one `inference`
event on a ``CommLedger`` (request features up, predictions down), so a
deployed model's bytes are accounted through the same path as the fit
that produced it.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

from repro.core.allreduce import CommLedger
from repro.utils.tree import tree_bytes

PyTree = Any

#: latency percentile window — counters and bytes stay exact forever, but
#: a long-lived server must not grow a list per request
LATENCY_WINDOW = 4096


def _percentile(sorted_vals: list, q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[i]


@dataclass
class ServeMetrics:
    """Latency/throughput counters + a ``CommLedger`` for inference bytes.

    One ``record_batch`` call per answered microbatch; per-request latency
    is attributed uniformly (all requests in a batch share its wall time
    — the batching trade the batcher makes explicit).  Percentiles come
    from a bounded window of the most recent requests; everything else is
    an exact running total.
    """

    ledger: CommLedger = field(default_factory=CommLedger)
    requests: int = 0
    batches: int = 0
    padded_slots: int = 0
    busy_s: float = 0.0
    # continuous-decode accounting (zero for pure request/response serving)
    tokens: int = 0
    decode_steps: int = 0
    decode_busy_s: float = 0.0
    slot_active_acc: int = 0
    slot_cap_acc: int = 0
    evictions: int = 0
    started_at: float = field(default_factory=time.perf_counter)
    latencies_s: deque = field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW)
    )
    token_latencies_s: deque = field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW)
    )
    # batches resolve concurrently (the batcher runs predict outside its
    # lock), so counter/ledger updates must not interleave
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    # ledger events coalesce per tag — a long-lived server must not grow
    # one event tuple per answered batch
    _event_idx: dict = field(default_factory=dict, repr=False)

    def record_batch(
        self,
        n_requests: int,
        batch_size: int,
        latency_s: float,
        request: PyTree,
        response: PyTree,
        tag: str = "serve",
    ) -> None:
        with self._lock:
            self.requests += n_requests
            self.batches += 1
            self.padded_slots += batch_size - n_requests
            self.busy_s += latency_s
            self.latencies_s.extend([latency_s] * n_requests)
            # same pricing as CommLedger.record_inference, but coalesced
            # into ONE running event per tag (a long-lived server must not
            # grow the event log per batch).  Updating in place — rather
            # than append-then-pop — keeps the log consistent even when
            # other writers share this ledger (e.g. a training loop
            # merging its accounting in).
            up = tree_bytes(request)
            down = tree_bytes(response)
            self.ledger.uplink_bytes += up
            self.ledger.downlink_bytes += down
            idx = self._event_idx.get(tag)
            if idx is None:
                self.ledger.events.append(("inference", tag, up + down))
                self._event_idx[tag] = len(self.ledger.events) - 1
            else:
                kind, t, prev = self.ledger.events[idx]
                self.ledger.events[idx] = (kind, t, prev + up + down)

    def record_decode_step(
        self, n_active: int, n_slots: int, latency_s: float
    ) -> None:
        """One continuous-batching decode step: ``n_active`` of
        ``n_slots`` slots each advanced one token in ``latency_s``
        (per-token latency is the step wall time — every active slot
        shares it)."""
        with self._lock:
            self.tokens += n_active
            self.decode_steps += 1
            self.decode_busy_s += latency_s
            self.busy_s += latency_s
            self.slot_active_acc += n_active
            self.slot_cap_acc += n_slots
            if n_active:
                self.token_latencies_s.append(latency_s)

    def record_request_stream(
        self,
        n_tokens: int,
        e2e_latency_s: float,
        request: PyTree = None,
        response: PyTree = None,
        tag: str = "serve",
    ) -> None:
        """One retired generation request (continuous batching): its
        end-to-end latency enters the request-latency window and its
        prompt/generated-ids bytes are metered like ``record_batch``."""
        with self._lock:
            self.requests += 1
            self.latencies_s.append(e2e_latency_s)
            up = tree_bytes(request) if request is not None else 0
            down = tree_bytes(response) if response is not None else 0
            self.ledger.uplink_bytes += up
            self.ledger.downlink_bytes += down
            if up or down:
                idx = self._event_idx.get(tag)
                if idx is None:
                    self.ledger.events.append(("inference", tag, up + down))
                    self._event_idx[tag] = len(self.ledger.events) - 1
                else:
                    kind, t, prev = self.ledger.events[idx]
                    self.ledger.events[idx] = (kind, t, prev + up + down)

    def record_eviction(self, n: int = 1) -> None:
        with self._lock:
            self.evictions += n

    def summary(self) -> dict:
        with self._lock:
            lat = sorted(self.latencies_s)
            tok_lat = sorted(self.token_latencies_s)
            requests, batches = self.requests, self.batches
            padded, busy = self.padded_slots, self.busy_s
            tokens, steps = self.tokens, self.decode_steps
            dec_busy = self.decode_busy_s
            slot_act, slot_cap = self.slot_active_acc, self.slot_cap_acc
            evictions = self.evictions
            up, down = self.ledger.uplink_bytes, self.ledger.downlink_bytes
        slots = requests + padded
        return {
            "requests": requests,
            "batches": batches,
            "busy_s": busy,
            "wall_s": time.perf_counter() - self.started_at,
            # throughput while actually serving (busy time), so compile
            # and idle gaps don't decay the stat
            "requests_per_s": requests / max(busy, 1e-9),
            "mean_latency_ms": 1e3 * (sum(lat) / len(lat)) if lat else 0.0,
            "p50_latency_ms": 1e3 * _percentile(lat, 0.50),
            "p95_latency_ms": 1e3 * _percentile(lat, 0.95),
            "p99_latency_ms": 1e3 * _percentile(lat, 0.99),
            "pad_fraction": (padded / slots) if slots else 0.0,
            "request_bytes": up,
            "response_bytes": down,
            # continuous-decode stats (all zero for request/response serving)
            "tokens": tokens,
            "tokens_per_s": tokens / max(dec_busy, 1e-9) if tokens else 0.0,
            "decode_steps": steps,
            "slot_utilization": (slot_act / slot_cap) if slot_cap else 0.0,
            "evictions": evictions,
            "p50_token_ms": 1e3 * _percentile(tok_lat, 0.50),
            "p95_token_ms": 1e3 * _percentile(tok_lat, 0.95),
            "p99_token_ms": 1e3 * _percentile(tok_lat, 0.99),
        }
