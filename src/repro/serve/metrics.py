"""Serving metrics: per-request latency/throughput + inference traffic.

The paper's recurring evaluation axis is communication cost under the
strict client-server model; serving extends that model from training
messages to inference traffic — every answered batch is one `inference`
event on a ``CommLedger`` (request features up, predictions down), so a
deployed model's bytes are accounted through the same path as the fit
that produced it.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any

from repro.core.allreduce import CommLedger
from repro.utils.tree import tree_bytes

PyTree = Any

#: latency percentile window — counters and bytes stay exact forever, but
#: a long-lived server must not grow a list per request
LATENCY_WINDOW = 4096


def _percentile(sorted_vals: list, q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[i]


@dataclass
class ServeMetrics:
    """Latency/throughput counters + a ``CommLedger`` for inference bytes.

    One ``record_batch`` call per answered microbatch; per-request latency
    is attributed uniformly (all requests in a batch share its wall time
    — the batching trade the batcher makes explicit).  Percentiles come
    from a bounded window of the most recent requests; everything else is
    an exact running total.
    """

    ledger: CommLedger = field(default_factory=CommLedger)
    requests: int = 0
    batches: int = 0
    padded_slots: int = 0
    busy_s: float = 0.0
    started_at: float = field(default_factory=time.perf_counter)
    latencies_s: deque = field(
        default_factory=lambda: deque(maxlen=LATENCY_WINDOW)
    )
    # batches resolve concurrently (the batcher runs predict outside its
    # lock), so counter/ledger updates must not interleave
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)
    # ledger events coalesce per tag — a long-lived server must not grow
    # one event tuple per answered batch
    _event_idx: dict = field(default_factory=dict, repr=False)

    def record_batch(
        self,
        n_requests: int,
        batch_size: int,
        latency_s: float,
        request: PyTree,
        response: PyTree,
        tag: str = "serve",
    ) -> None:
        with self._lock:
            self.requests += n_requests
            self.batches += 1
            self.padded_slots += batch_size - n_requests
            self.busy_s += latency_s
            self.latencies_s.extend([latency_s] * n_requests)
            # same pricing as CommLedger.record_inference, but coalesced
            # into ONE running event per tag (a long-lived server must not
            # grow the event log per batch).  Updating in place — rather
            # than append-then-pop — keeps the log consistent even when
            # other writers share this ledger (e.g. a training loop
            # merging its accounting in).
            up = tree_bytes(request)
            down = tree_bytes(response)
            self.ledger.uplink_bytes += up
            self.ledger.downlink_bytes += down
            idx = self._event_idx.get(tag)
            if idx is None:
                self.ledger.events.append(("inference", tag, up + down))
                self._event_idx[tag] = len(self.ledger.events) - 1
            else:
                kind, t, prev = self.ledger.events[idx]
                self.ledger.events[idx] = (kind, t, prev + up + down)

    def summary(self) -> dict:
        with self._lock:
            lat = sorted(self.latencies_s)
            requests, batches = self.requests, self.batches
            padded, busy = self.padded_slots, self.busy_s
            up, down = self.ledger.uplink_bytes, self.ledger.downlink_bytes
        slots = requests + padded
        return {
            "requests": requests,
            "batches": batches,
            "busy_s": busy,
            "wall_s": time.perf_counter() - self.started_at,
            # throughput while actually serving (busy time), so compile
            # and idle gaps don't decay the stat
            "requests_per_s": requests / max(busy, 1e-9),
            "mean_latency_ms": 1e3 * (sum(lat) / len(lat)) if lat else 0.0,
            "p50_latency_ms": 1e3 * _percentile(lat, 0.50),
            "p95_latency_ms": 1e3 * _percentile(lat, 0.95),
            "p99_latency_ms": 1e3 * _percentile(lat, 0.99),
            "pad_fraction": (padded / slots) if slots else 0.0,
            "request_bytes": up,
            "response_bytes": down,
        }
