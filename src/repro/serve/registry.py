"""Name/version model registry over ``checkpoint/io`` with atomic hot-swap.

Layout (one directory per model name, versions are checkpoint steps)::

    <root>/<name>/step_00000001.npz   # checkpoint.io payload
    <root>/<name>/step_00000001.json  # checkpoint.io manifest
    <root>/<name>/meta_00000001.json  # registry metadata (publisher info)
    <root>/<name>/LATEST              # active version pointer

``publish`` writes the payload (atomic inside ``checkpoint.io.save``),
then flips ``LATEST`` with the same write-temp + ``os.replace`` pattern —
a serving process that re-resolves ``latest`` between two requests sees
either the old or the new version, never a torn state.  A finished
``fit`` can therefore be published and picked up by a live ``ServeEngine``
(``engine.swap(registry.load(name))``) without a process restart.
"""

from __future__ import annotations

import contextlib
import json
import os
import re
import tempfile
import time
from typing import Any

from repro.checkpoint import io as ckpt_io

PyTree = Any


class ModelRegistry:
    """Versioned store of finalized models, keyed by name."""

    def __init__(self, root: str):
        self.root = root

    def _dir(self, name: str) -> str:
        return os.path.join(self.root, name)

    # -- write side ----------------------------------------------------------

    def publish(
        self, name: str, theta: PyTree, *, meta: dict | None = None,
        activate: bool = True,
    ) -> int:
        """Store ``theta`` as the next version of ``name``; with
        ``activate`` (default) the LATEST pointer hot-swaps to it.
        Concurrent publishers each get their own version: the number is
        claimed with an exclusive-create sentinel before anything is
        written, so two processes can never overwrite one payload."""
        d = self._dir(name)
        os.makedirs(d, exist_ok=True)
        version = self._claim_version(name)
        ckpt_io.save(d, version, theta)
        # the payload now protects the number — drop our claim sentinel
        # so publishes don't accumulate empty files forever
        with contextlib.suppress(FileNotFoundError):
            os.unlink(os.path.join(d, f"step_{version:08d}.claim"))
        record = {
            **(meta or {}),
            # reserved manifest keys always win over user metadata
            "name": name,
            "version": version,
            "published_at": time.time(),
        }
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(record, f)
        os.replace(tmp, os.path.join(d, f"meta_{version:08d}.json"))
        if activate:
            self.set_latest(name, version)
        return version

    def _claim_version(self, name: str) -> int:
        d = self._dir(name)
        claimed = [
            int(m.group(1))
            for fn in os.listdir(d)
            if (m := re.match(r"step_(\d+)\.(npz|claim)$", fn))
        ]
        version = (max(claimed) + 1) if claimed else 1
        while True:
            try:
                fd = os.open(
                    os.path.join(d, f"step_{version:08d}.claim"),
                    os.O_CREAT | os.O_EXCL | os.O_WRONLY,
                )
                os.close(fd)
                return version
            except FileExistsError:  # another publisher got here first
                version += 1

    def set_latest(self, name: str, version: int) -> None:
        """Atomically repoint LATEST (the hot-swap primitive)."""
        d = self._dir(name)
        if not os.path.exists(os.path.join(d, f"step_{version:08d}.npz")):
            raise FileNotFoundError(
                f"{name!r} has no published version {version}"
            )
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            f.write(str(version))
        os.replace(tmp, os.path.join(d, "LATEST"))

    # -- read side -----------------------------------------------------------

    def models(self) -> list:
        if not os.path.isdir(self.root):
            return []
        return sorted(
            n for n in os.listdir(self.root)
            if os.path.isdir(self._dir(n)) and self.versions(n)
        )

    def versions(self, name: str) -> list:
        d = self._dir(name)
        if not os.path.isdir(d):
            return []
        return sorted(
            int(m.group(1))
            for fn in os.listdir(d)
            if (m := re.match(r"step_(\d+)\.npz$", fn))
        )

    def latest(self, name: str) -> int | None:
        """The ACTIVATED version — None until something is activated, so
        a model only ever staged (``activate=False``) is never served by
        default."""
        path = os.path.join(self._dir(name), "LATEST")
        try:
            with open(path) as f:
                return int(f.read().strip())
        except FileNotFoundError:
            return None

    def resolve(self, name: str, version: int | None = None) -> int:
        v = version if version is not None else self.latest(name)
        if v is None or v not in self.versions(name):
            raise FileNotFoundError(
                f"registry has no version {version!r} of model {name!r}"
            )
        return v

    def load(
        self, name: str, version: int | None = None, *, like: PyTree = None,
        shardings=None,
    ) -> PyTree:
        """Materialize a published model.  With ``like`` (and optional
        ``shardings``) this is ``checkpoint.io.restore`` — exact structure
        and placement; without it, nested-dict/bare-array thetas are
        rebuilt from the manifest keys."""
        v = self.resolve(name, version)
        if like is not None:
            return ckpt_io.restore(
                self._dir(name), v, like, shardings=shardings
            )
        return ckpt_io.restore_dict(self._dir(name), v)

    def meta(self, name: str, version: int | None = None) -> dict:
        v = self.resolve(name, version)
        with open(os.path.join(self._dir(name), f"meta_{v:08d}.json")) as f:
            return json.load(f)
