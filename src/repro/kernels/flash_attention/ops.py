"""Jit'd public wrapper for the flash-attention kernel.

Accepts the model's (B, T, H, D) layout, handles padding to block multiples
and GQA head grouping, and dispatches to the Pallas kernel (interpret mode
off-TPU).  ``flash_attention`` mirrors ``repro.models.attention._sdpa``
semantics for the cache-free train/prefill path.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # (B, T, Hq, D) — model layout
    k: jnp.ndarray,  # (B, S, Hkv, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, T, Hq, D = q.shape
    S = k.shape[1]
    bq_eff = min(bq, max(8, T))
    bk_eff = min(bk, max(8, S))
    qt = _pad_to(q.transpose(0, 2, 1, 3), 2, bq_eff)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 2, bk_eff)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 2, bk_eff)
    out = flash_attention_fwd(
        qt,
        kt,
        vt,
        seq_q=T,
        seq_k=S,
        causal=causal,
        window=window,
        q_offset=q_offset,
        bq=bq_eff,
        bk=bk_eff,
        interpret=interpret,
    )
    return out[:, :, :T].transpose(0, 2, 1, 3)
