"""Pure-jnp oracle for flash attention (GQA, causal, sliding window)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,  # (B, Hq, T, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jnp.ndarray:
    B, Hq, T, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, T, D)
    logits = jnp.einsum(
        "bhgtd,bhsd->bhgts", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * (D ** -0.5)
    qpos = jnp.arange(T)[:, None] + q_offset
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhgts,bhsd->bhgtd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, T, D).astype(q.dtype)
