"""Pallas TPU flash attention (GQA, causal, sliding-window).

TPU-native design (DESIGN.md §3): the grid's innermost dimension walks KV
blocks *sequentially* (TPU grids execute in order), carrying the online-
softmax state (m, l, acc) in VMEM scratch across iterations; q/k/v tiles
are streamed HBM→VMEM by BlockSpec index maps; tile shapes are multiples
of the 128-lane MXU width.  Grid: (B, Hq, T/bq, S/bk); GQA maps query head
h to KV head h // G in the k/v index maps.  Out-of-window blocks are
skipped with ``pl.when`` (block-level causal/window skipping — the FLOP
saving that makes causal flash ~2x over dense).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this as TPUCompilerParams; newer releases renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, bq, D)
    k_ref,  # (1, 1, bk, D)
    v_ref,  # (1, 1, bk, D)
    o_ref,  # (1, 1, bq, D)
    acc_ref,  # VMEM (bq, D) f32
    m_ref,  # VMEM (bq, 128) f32 (lane-padded)
    l_ref,  # VMEM (bq, 128) f32
    *,
    bq: int,
    bk: int,
    seq_q: int,
    seq_k: int,
    causal: bool,
    window: int,
    q_offset: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level skip: entire block out of the causal / window range
    block_q_max = iq * bq + bq - 1 + q_offset
    block_q_min = iq * bq + q_offset
    block_k_min = ik * bk
    block_k_max = ik * bk + bk - 1
    relevant = jnp.asarray(True)
    if causal:
        relevant &= block_k_min <= block_q_max
    if window > 0:
        relevant &= block_k_max > block_q_min - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * (q.shape[-1] ** -0.5)  # (bq, bk)

        mask = kpos < seq_k  # padding
        mask &= qpos < seq_q + q_offset
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]  # (bq,)
        l_prev = l_ref[:, 0]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        denom = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0, :, :] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,  # (B, Hq, Tp, D) — pre-padded to block multiples
    k: jnp.ndarray,  # (B, Hkv, Sp, D)
    v: jnp.ndarray,
    *,
    seq_q: int,
    seq_k: int,
    causal: bool,
    window: int,
    q_offset: int,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
):
    B, Hq, Tp, D = q.shape
    Hkv, Sp = k.shape[1], k.shape[2]
    G = Hq // Hkv
    grid = (B, Hq, Tp // bq, Sp // bk)

    kernel = functools.partial(
        _flash_kernel,
        bq=bq,
        bk=bk,
        seq_q=seq_q,
        seq_k=seq_k,
        causal=causal,
        window=window,
        q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Tp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
