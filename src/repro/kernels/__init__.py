"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships ``kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jit'd public wrapper, interpret-mode off-TPU) and
``ref.py`` (pure-jnp oracle used by the allclose test sweeps).

* ``flash_attention``  — train/prefill attention (GQA, causal, windows)
* ``decode_attention`` — 1-token decode vs long KV cache (flash-decode)
* ``topk_compress``    — gradient top-k for the low-comm push path (§5)
* ``int8_quant``       — symmetric int8 wire quantization, fused round-trip
* ``pdist_argmin``     — k-means / k-windows E-step (ℓ1/ℓ2/ℓ∞)
"""
