"""Pure-jnp oracle: symmetric int8 quantize→dequantize round-trip."""

from __future__ import annotations

import jax.numpy as jnp


def int8_roundtrip_ref(x: jnp.ndarray):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q.astype(x.dtype) * scale, scale
