from repro.kernels.int8_quant import ops, ref

__all__ = ["ops", "ref"]
