"""Int8 symmetric quantization round-trip, kernel-fused.

``int8_roundtrip`` mirrors ``core.compression.int8_compress`` per leaf:
scale = max(|x|, 1e-12)/127, out = clip(round(x/scale))·scale — same ops
in the same order, so the result is bit-equal to the jnp reference while
touching HBM twice (absmax + fused quant-dequant) instead of three times.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.int8_quant.kernel import absmax, quant_dequant


@partial(jax.jit, static_argnames=("interpret",))
def int8_roundtrip(x: jnp.ndarray, *, interpret: bool | None = None):
    """Returns (dequantized, scale) for one f32 leaf."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m = absmax(x, interpret=interpret)
    scale = jnp.maximum(m, 1e-12) / 127.0
    return quant_dequant(x, scale, interpret=interpret), scale
