"""Pallas TPU int8 symmetric quantization for the wire layer.

The jnp reference (``core.compression.int8_compress``) makes three full
passes over each leaf: abs-max reduction, quantize, dequantize.  Here the
same math runs as two streaming kernels:

1. ``_absmax_kernel`` — per-lane running max of |x| into a VMEM scratch
   row (max is exactly order-independent, so the blocked reduction is
   bit-equal to XLA's);
2. ``_quant_kernel`` — clip(round(x/s))·s in ONE pass, emitting the
   dequantized f32 the aggregation path consumes (the int8 intermediate
   never touches HBM).

Block shape (8, 1024) keeps f32 tiles lane-aligned; the scale rides in
(1, 1) SMEM.  All formulas match the reference op-for-op, so the kernel
path is bit-equal to the pure-jnp wire.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this as TPUCompilerParams; newer releases renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

BLOCK = 1024
ROWS = 8
LANE = 128


def _absmax_kernel(x_ref, o_ref, acc_ref):
    """Streaming per-lane max of |x|; o: (1, LANE) lane maxima (reduce
    outside for the scalar).  Tail padding is zeros and max(|x|, 0) is a
    no-op, so no validity mask is needed."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = jnp.abs(x_ref[0].astype(jnp.float32))  # (ROWS, BLOCK)
    lanes = jnp.max(x.reshape(-1, LANE), axis=0)  # (LANE,)
    acc_ref[...] = jnp.maximum(acc_ref[...], lanes[None, :])

    @pl.when(i == pl.num_programs(0) - 1)
    def _fin():
        o_ref[...] = acc_ref[...]


def _quant_kernel(x_ref, s_ref, o_ref):
    """Quantize→dequantize in one pass: clip(round(x/s), ±127)·s, exactly
    the reference formula including the int8 round-trip cast."""
    s = s_ref[0, 0]
    x = x_ref[0]
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    o_ref[...] = (q.astype(x.dtype) * s)[None]


def _pad_flat(x: jnp.ndarray):
    flat = x.reshape(-1)
    n = flat.shape[0]
    per = ROWS * BLOCK
    pad = (-n) % per
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
    return flat.reshape(-1, ROWS, BLOCK), n


def absmax(x: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """max |x| over the whole leaf (f32 scalar)."""
    blocks, _ = _pad_flat(x)
    nb = blocks.shape[0]
    lanes = pl.pallas_call(
        _absmax_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, ROWS, BLOCK), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, LANE), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, LANE), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, LANE), jnp.float32)],
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(blocks)
    return jnp.max(lanes)


def quant_dequant(
    x: jnp.ndarray, scale: jnp.ndarray, *, interpret: bool = True
) -> jnp.ndarray:
    """clip(round(x/scale))·scale, one fused pass."""
    blocks, _ = _pad_flat(x)
    nb = blocks.shape[0]
    s = scale.reshape(1, 1).astype(jnp.float32)
    out = pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, ROWS, BLOCK), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, ROWS, BLOCK), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(blocks.shape, x.dtype),
        interpret=interpret,
    )(blocks, s)
    return out.reshape(-1)[: x.size].reshape(x.shape)
