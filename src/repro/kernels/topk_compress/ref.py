"""Pure-jnp oracle: exact top-k magnitude sparsification."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_sparsify_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    flat = jnp.abs(x.reshape(-1))
    k = max(1, min(int(k), flat.size))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return x * (jnp.abs(x) >= thresh).astype(x.dtype)
