"""Pallas TPU gradient top-k sparsification, sort-free.

Global top-k by magnitude is a *selection* problem; a global sort of a
multi-GB gradient would be HBM-bandwidth disaster.  TPU-native design:

1. ``count_kernel`` — a streaming reduction: for a candidate threshold
   vector t (one lane-row, up to 128 candidates evaluated AT ONCE), count
   per block how many |x| ≥ t_j, accumulating into a VMEM scratch counter;
   one pass evaluates 128 bisection candidates simultaneously — the whole
   threshold search costs ~2 passes over the data instead of ~30.
2. host-free binary refinement picks the largest t with count ≥ k;
3. ``mask_kernel`` — one more streaming pass emits x·1{|x| ≥ t}.

Total: 3 passes over HBM (vs. sort's O(log n) passes), MXU untouched (VPU
compare+select only), block shape (8, 1024) keeps tiles lane-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this as TPUCompilerParams; newer releases renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

BLOCK = 1024
ROWS = 8
NCAND = 128


def _count_kernel(x_ref, t_ref, o_ref, acc_ref):
    """x: (ROWS, BLOCK) block; t: (1, NCAND) candidates; o: (1, NCAND) counts."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = jnp.abs(x_ref[...].astype(jnp.float32)).reshape(-1)  # (ROWS*BLOCK,)
    t = t_ref[0]  # (NCAND,)
    # count via compare-broadcast: (elements, candidates) in VMEM
    cnt = jnp.sum(
        (x[:, None] >= t[None, :]).astype(jnp.float32), axis=0
    )  # (NCAND,)
    acc_ref[...] += cnt[None, :]

    @pl.when(i == pl.num_programs(0) - 1)
    def _fin():
        o_ref[...] = acc_ref[...]


def _mask_kernel(x_ref, t_ref, o_ref):
    t = t_ref[0, 0]
    x = x_ref[...]
    o_ref[...] = jnp.where(jnp.abs(x.astype(jnp.float32)) >= t, x, 0.0).astype(
        o_ref.dtype
    )


def _valid_mask(i, n):
    """1{position < n} for block i of the padded (ROWS, BLOCK) layout, so
    the tail padding never pollutes the survivor count."""
    row = jax.lax.broadcasted_iota(jnp.int32, (ROWS, BLOCK), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (ROWS, BLOCK), 1)
    pos = i * (ROWS * BLOCK) + row * BLOCK + col
    return pos < n


def _encode_kernel(c_ref, t_ref, n_ref, o_ref, res_ref, cnt_ref, acc_ref):
    """Fused wire encode: ONE pass over c emits survivors, EF residual and
    per-lane survivor counts.

    c: (1, ROWS, BLOCK) corrected values (update + carried residual);
    t: (1, 1) SMEM threshold; n: (1, 1) SMEM true element count;
    o = c·1{|c| ≥ t} (the push), res = c − o (the next EF residual) —
    both exactly the reference formulas, so the kernel path is bit-equal
    to the pure-jnp wire including signed zeros.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    t = t_ref[0, 0]
    c = c_ref[0]  # (ROWS, BLOCK)
    keep = (jnp.abs(c) >= t).astype(c.dtype)
    o = c * keep
    o_ref[...] = o[None]
    res_ref[...] = (c - o)[None]
    counted = jnp.logical_and(keep != 0, _valid_mask(i, n_ref[0, 0]))
    lanes = jnp.sum(
        counted.reshape(-1, NCAND).astype(jnp.float32), axis=0
    )  # (NCAND,)
    acc_ref[...] += lanes[None, :]

    @pl.when(i == pl.num_programs(0) - 1)
    def _fin():
        cnt_ref[...] = acc_ref[...]


def _select_kernel(c_ref, t_ref, n_ref, o_ref, cnt_ref, acc_ref):
    """`_encode_kernel` without the EF residual output (dense-residual-free
    wires): survivors + survivor count in one pass."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    t = t_ref[0, 0]
    c = c_ref[0]
    keep = (jnp.abs(c) >= t).astype(c.dtype)
    o_ref[...] = (c * keep)[None]
    counted = jnp.logical_and(keep != 0, _valid_mask(i, n_ref[0, 0]))
    lanes = jnp.sum(counted.reshape(-1, NCAND).astype(jnp.float32), axis=0)
    acc_ref[...] += lanes[None, :]

    @pl.when(i == pl.num_programs(0) - 1)
    def _fin():
        cnt_ref[...] = acc_ref[...]


def _pad_flat(x: jnp.ndarray):
    flat = x.reshape(-1)
    n = flat.shape[0]
    per = ROWS * BLOCK
    pad = (-n) % per
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
    return flat.reshape(-1, ROWS, BLOCK), n


def count_ge(x: jnp.ndarray, thresholds: jnp.ndarray, *, interpret: bool = True):
    """Counts of |x| >= t for each of the NCAND thresholds (zero-padding is
    excluded by construction because thresholds are > 0)."""
    blocks, n = _pad_flat(x)
    nb = blocks.shape[0]
    t = thresholds.reshape(1, NCAND).astype(jnp.float32)
    counts = pl.pallas_call(
        _count_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, ROWS, BLOCK), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, NCAND), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, NCAND), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, NCAND), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, NCAND), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)
        ),
        interpret=interpret,
    )(blocks, t)
    return counts[0]


def apply_threshold(x: jnp.ndarray, thresh: jnp.ndarray, *, interpret: bool = True):
    blocks, n = _pad_flat(x)
    nb = blocks.shape[0]
    t = thresh.reshape(1, 1).astype(jnp.float32)
    out = pl.pallas_call(
        _mask_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, ROWS, BLOCK), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, ROWS, BLOCK), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(blocks.shape, x.dtype),
        interpret=interpret,
    )(blocks, t)
    return out.reshape(-1)[: x.size].reshape(x.shape)


def encode_threshold(
    c: jnp.ndarray,
    thresh: jnp.ndarray,
    *,
    with_residual: bool = True,
    interpret: bool = True,
):
    """One fused pass: (survivors, EF residual or None, survivor count).

    ``o = c·1{|c| ≥ t}`` and ``res = c − o`` — the exact reference
    formulas, so outputs are bit-equal to the jnp path (signed zeros
    included).  The count excludes tail padding.
    """
    blocks, n = _pad_flat(c)
    nb = blocks.shape[0]
    t = thresh.reshape(1, 1).astype(jnp.float32)
    n_s = jnp.full((1, 1), n, jnp.int32)
    block_spec = pl.BlockSpec((1, ROWS, BLOCK), lambda i: (i, 0, 0))
    smem_spec = pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM)
    cnt_spec = pl.BlockSpec((1, NCAND), lambda i: (0, 0))
    kernel = _encode_kernel if with_residual else _select_kernel
    out_specs = [block_spec] + ([block_spec] if with_residual else []) + [cnt_spec]
    out_shape = (
        [jax.ShapeDtypeStruct(blocks.shape, c.dtype)]
        + ([jax.ShapeDtypeStruct(blocks.shape, c.dtype)] if with_residual else [])
        + [jax.ShapeDtypeStruct((1, NCAND), jnp.float32)]
    )
    outs = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[block_spec, smem_spec, smem_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((1, NCAND), jnp.float32)],
        compiler_params=_CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(blocks, t, n_s)

    def unpad(b):
        return b.reshape(-1)[: c.size].reshape(c.shape)

    count = jnp.sum(outs[-1]).astype(jnp.int32)
    if with_residual:
        return unpad(outs[0]), unpad(outs[1]), count
    return unpad(outs[0]), None, count
