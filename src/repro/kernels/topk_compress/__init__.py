from repro.kernels.topk_compress import ops, ref

__all__ = ["ops", "ref"]
