"""Top-k sparsification: batched-candidate bisection + streaming mask.

Three rounds of 128-candidate evaluation bracket the k-th magnitude to
|range|/128³ relative precision, then the exact in-bracket threshold is
chosen from the counts — matching exact top-k whenever magnitudes are
distinct at the bracket resolution (ties keep ≥ k entries, conservative).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.topk_compress.kernel import (
    NCAND,
    apply_threshold,
    count_ge,
    encode_threshold,
)


@partial(jax.jit, static_argnames=("k", "rounds", "interpret"))
def topk_sparsify(
    x: jnp.ndarray, k: int, *, rounds: int = 3, interpret: bool | None = None
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    k = max(1, min(int(k), x.size))

    hi = jnp.max(jnp.abs(x)).astype(jnp.float32) * (1.0 + 1e-6) + 1e-30
    lo = jnp.zeros((), jnp.float32) + 1e-30

    def round_(carry, _):
        lo, hi = carry
        cand = lo + (hi - lo) * (jnp.arange(1, NCAND + 1) / NCAND)
        counts = count_ge(x, cand, interpret=interpret)  # decreasing in cand
        # largest candidate with count >= k  → new lo; its successor → new hi
        ok = counts >= k
        j = jnp.maximum(jnp.sum(ok.astype(jnp.int32)) - 1, 0)  # last True
        new_lo = jnp.where(ok[0], cand[j], lo)
        new_hi = jnp.where(
            j + 1 < NCAND, cand[jnp.minimum(j + 1, NCAND - 1)], hi
        )
        new_hi = jnp.where(ok[0], new_hi, cand[0])
        return (new_lo, new_hi), None

    (lo, hi), _ = jax.lax.scan(round_, (lo, hi), None, length=rounds)
    return apply_threshold(x, lo, interpret=interpret)


@partial(jax.jit, static_argnames=("k", "interpret"))
def topk_encode(
    u: jnp.ndarray,
    r: jnp.ndarray | None = None,
    *,
    k: int,
    interpret: bool | None = None,
):
    """Fused wire encode: (survivors, EF residual, survivor count) in one
    HBM pass over ``c = u + r``.

    The threshold is the exact k-th magnitude (``lax.top_k``), matching
    ``core.compression._leaf_topk_mask`` bit-for-bit; the fused kernel then
    emits ``o = c·1{|c| ≥ t}`` and ``res = c − o`` — the reference wire's
    mask-multiply and EF-subtract formulas — plus the actual survivor
    count (ties keep > k entries; benchmarks read it so they can't lie
    about what crossed the wire).  ``r=None`` skips the residual output
    (non-EF wires).  Unlike ``topk_sparsify`` (whose 128-candidate
    bisection approximates the threshold all on-device), this is the
    bit-equal path the wire layer flips on under mesh executors.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c = u if r is None else u + r
    k = max(1, min(int(k), c.size))
    thresh = jax.lax.top_k(jnp.abs(c.reshape(-1)), k)[0][-1]
    return encode_threshold(
        c, thresh, with_residual=r is not None, interpret=interpret
    )
