"""Top-k sparsification: batched-candidate bisection + streaming mask.

Three rounds of 128-candidate evaluation bracket the k-th magnitude to
|range|/128³ relative precision, then the exact in-bracket threshold is
chosen from the counts — matching exact top-k whenever magnitudes are
distinct at the bracket resolution (ties keep ≥ k entries, conservative).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.topk_compress.kernel import NCAND, apply_threshold, count_ge


@partial(jax.jit, static_argnames=("k", "rounds", "interpret"))
def topk_sparsify(
    x: jnp.ndarray, k: int, *, rounds: int = 3, interpret: bool | None = None
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    k = max(1, min(int(k), x.size))

    hi = jnp.max(jnp.abs(x)).astype(jnp.float32) * (1.0 + 1e-6) + 1e-30
    lo = jnp.zeros((), jnp.float32) + 1e-30

    def round_(carry, _):
        lo, hi = carry
        cand = lo + (hi - lo) * (jnp.arange(1, NCAND + 1) / NCAND)
        counts = count_ge(x, cand, interpret=interpret)  # decreasing in cand
        # largest candidate with count >= k  → new lo; its successor → new hi
        ok = counts >= k
        j = jnp.maximum(jnp.sum(ok.astype(jnp.int32)) - 1, 0)  # last True
        new_lo = jnp.where(ok[0], cand[j], lo)
        new_hi = jnp.where(
            j + 1 < NCAND, cand[jnp.minimum(j + 1, NCAND - 1)], hi
        )
        new_hi = jnp.where(ok[0], new_hi, cand[0])
        return (new_lo, new_hi), None

    (lo, hi), _ = jax.lax.scan(round_, (lo, hi), None, length=rounds)
    return apply_threshold(x, lo, interpret=interpret)
