"""Pure-jnp oracle for single-token decode attention over a KV cache."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jnp.ndarray,  # (B, Hq, D) — one query token per batch row
    k: jnp.ndarray,  # (B, S, Hkv, D) — cache
    v: jnp.ndarray,  # (B, S, Hkv, D)
    valid_len: jnp.ndarray | int,  # keys < valid_len attend
) -> jnp.ndarray:
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    logits = jnp.einsum(
        "bhgd,bshd->bhgs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * (D ** -0.5)
    mask = jnp.arange(S)[None, :] < jnp.asarray(valid_len).reshape(-1, 1)  # (B, S)
    logits = jnp.where(mask[:, None, None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)
