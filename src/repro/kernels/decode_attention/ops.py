"""Jit'd wrapper for the decode-attention kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import NEG_INF, decode_attention_fwd


@partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(
    q: jnp.ndarray,  # (B, Hq, D)
    k: jnp.ndarray,  # (B, S, Hkv, D)
    v: jnp.ndarray,
    valid_len: jnp.ndarray,  # (B,) or scalar
    *,
    bk: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bk_eff = min(bk, S)

    pad = (-S) % bk_eff
    if pad:
        widths = [(0, 0)] * 4
        widths[1] = (0, pad)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    Sp = S + pad

    vl = jnp.broadcast_to(jnp.asarray(valid_len).reshape(-1), (B,))
    bias = jnp.where(jnp.arange(Sp)[None, :] < vl[:, None], 0.0, NEG_INF).astype(
        jnp.float32
    )

    qg = q.reshape(B, Hkv, G, D)
    out = decode_attention_fwd(qg, k, v, bias, bk=bk_eff, interpret=interpret)
    return out.reshape(B, Hq, D)


@jax.jit
def decode_attention_xla(
    q: jnp.ndarray,  # (B, Hq, D)
    k: jnp.ndarray,  # (B, S, Hkv, D)
    v: jnp.ndarray,
    valid_len: jnp.ndarray,  # (B,) or scalar
) -> jnp.ndarray:
    """Jitted XLA reference for the decode kernel — the explicit
    ``use_kernel`` fallback on non-TPU backends.

    Mirrors the kernel's single-pass math exactly (additive 0/-1e30 bias,
    max → exp → masked-p @ v → divide-by-l, all f32), rather than
    ``softmax(logits) @ v``: on a single KV block (``bk ≥ S``) the two
    paths are bit-identical, so flipping ``use_kernel`` never changes a
    served token.
    """
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    vl = jnp.broadcast_to(jnp.asarray(valid_len).reshape(-1), (B,))
    bias = jnp.where(
        jnp.arange(S)[None, :] < vl[:, None], 0.0, NEG_INF
    ).astype(jnp.float32)  # (B, S)

    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * (D ** -0.5)
    s = s + bias[:, None, None, :]
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(bias[:, None, None, :] > NEG_INF / 2, p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum(
        "bhgs,bshd->bhgd", p, v.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    denom = jnp.where(l > 0.0, l, 1.0)
    out = acc / denom[..., None]
    return out.reshape(B, Hq, D).astype(q.dtype)
