"""Jit'd wrapper for the decode-attention kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import NEG_INF, decode_attention_fwd


@partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(
    q: jnp.ndarray,  # (B, Hq, D)
    k: jnp.ndarray,  # (B, S, Hkv, D)
    v: jnp.ndarray,
    valid_len: jnp.ndarray,  # (B,) or scalar
    *,
    bk: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    bk_eff = min(bk, S)

    pad = (-S) % bk_eff
    if pad:
        widths = [(0, 0)] * 4
        widths[1] = (0, pad)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    Sp = S + pad

    vl = jnp.broadcast_to(jnp.asarray(valid_len).reshape(-1), (B,))
    bias = jnp.where(jnp.arange(Sp)[None, :] < vl[:, None], 0.0, NEG_INF).astype(
        jnp.float32
    )

    qg = q.reshape(B, Hkv, G, D)
    out = decode_attention_fwd(qg, k, v, bias, bk=bk_eff, interpret=interpret)
    return out.reshape(B, Hq, D)
