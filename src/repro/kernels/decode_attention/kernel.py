"""Pallas TPU decode attention: one query token vs a long KV cache.

Flash-decode style: the grid streams the KV cache in ``bk``-row blocks
(innermost, sequential), merging partial softmax statistics (m, l, acc) in
VMEM scratch; the G=Hq/Hkv query heads sharing a KV head are processed
together as the (G, D) left operand of the MXU matmuls.  The valid cache
length arrives as an additive (B, S) bias row (0 / -inf) so the block mask
needs no scalar prefetch — portable to interpret mode.

This is the hot op of the ``decode_32k``/``long_500k`` shapes: per token it
moves the whole cache once (memory-bound; arithmetic intensity ≈ 2·G
flops/byte), so the roofline memory term of EXPERIMENTS.md is set directly
by this kernel's bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this as TPUCompilerParams; newer releases renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _decode_kernel(
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, bk, 1, D)
    v_ref,  # (1, bk, 1, D)
    bias_ref,  # (1, bk)
    o_ref,  # (1, 1, G, D)
    acc_ref,  # VMEM (G, D) f32
    m_ref,  # VMEM (G, 128) f32
    l_ref,  # VMEM (G, 128) f32
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    bias = bias_ref[0].astype(jnp.float32)  # (bk,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * (q.shape[-1] ** -0.5)  # (G, bk)
    s = s + bias[None, :]

    m_prev = m_ref[:, 0]
    l_prev = l_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(bias[None, :] > NEG_INF / 2, p, 0.0)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        denom = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(
    q: jnp.ndarray,  # (B, Hkv, G, D)
    k: jnp.ndarray,  # (B, Sp, Hkv, D)
    v: jnp.ndarray,
    bias: jnp.ndarray,  # (B, Sp) 0 / -inf additive mask
    *,
    bk: int = 512,
    interpret: bool = True,
):
    B, Hkv, G, D = q.shape
    Sp = k.shape[1]
    grid = (B, Hkv, Sp // bk)
    return pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, bk), lambda b, h, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v, bias)
