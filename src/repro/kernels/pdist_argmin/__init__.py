from repro.kernels.pdist_argmin import ops, ref

__all__ = ["ops", "ref"]
