"""Pure-jnp oracle: nearest-centroid assignment under ℓ1/ℓ2/ℓ∞."""

from __future__ import annotations

import jax.numpy as jnp


def pdist_argmin_ref(X: jnp.ndarray, C: jnp.ndarray, metric: str = "l2"):
    diff = X[:, None, :].astype(jnp.float32) - C[None, :, :].astype(jnp.float32)
    if metric == "l2":
        d = jnp.sum(diff * diff, axis=-1)  # squared — same argmin
    elif metric == "l1":
        d = jnp.sum(jnp.abs(diff), axis=-1)
    elif metric == "linf":
        d = jnp.max(jnp.abs(diff), axis=-1)
    else:
        raise ValueError(metric)
    return jnp.argmin(d, axis=1).astype(jnp.int32), jnp.min(d, axis=1)
