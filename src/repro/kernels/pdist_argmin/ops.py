"""Jit'd wrapper for the nearest-centroid kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.pdist_argmin.kernel import pdist_argmin_fwd


@partial(jax.jit, static_argnames=("metric", "bn", "interpret"))
def pdist_argmin(
    X: jnp.ndarray,
    C: jnp.ndarray,
    *,
    metric: str = "l2",
    bn: int = 128,
    interpret: bool | None = None,
):
    """Returns (assignments (N,) int32, min distance (N,) f32).

    ℓ2 distances are squared (argmin-equivalent, matches the oracle).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N, d = X.shape
    bn_eff = min(bn, max(8, N))
    pad = (-N) % bn_eff
    Xp = jnp.pad(X, ((0, pad), (0, 0))) if pad else X
    idx, dist = pdist_argmin_fwd(Xp, C, metric=metric, bn=bn_eff, interpret=interpret)
    return idx[:N], dist[:N]
