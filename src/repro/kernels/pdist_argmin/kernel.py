"""Pallas TPU nearest-centroid kernel (k-means / k-windows E-step).

Design: the centroid matrix (K, d) is small enough to stay VMEM-resident
across the whole grid (K ≤ 1024, d ≤ 512 → ≤ 2 MB); point blocks (bn, d)
stream HBM→VMEM.  For ℓ2 the cross term runs on the MXU
(‖x−c‖² = ‖x‖² − 2x·cᵀ + ‖c‖²); ℓ1/ℓ∞ are VPU compare/reduce over a
(bn, K, d) tile — the reason bn is kept at 128.  Outputs are the argmin
index and min distance per point (two (bn,) rows).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pdist_kernel(x_ref, c_ref, idx_ref, dist_ref, *, metric: str):
    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    c = c_ref[...].astype(jnp.float32)  # (K, d)
    if metric == "l2":
        x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (bn, 1)
        c2 = jnp.sum(c * c, axis=1)[None, :]  # (1, K)
        xc = jax.lax.dot_general(
            x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # MXU
        d = x2 - 2.0 * xc + c2
        d = jnp.maximum(d, 0.0)
    else:
        diff = jnp.abs(x[:, None, :] - c[None, :, :])  # (bn, K, d) VPU tile
        d = jnp.sum(diff, axis=-1) if metric == "l1" else jnp.max(diff, axis=-1)
    idx_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d, axis=1)


def pdist_argmin_fwd(
    X: jnp.ndarray,  # (Np, d) — pre-padded to bn multiple
    C: jnp.ndarray,  # (K, d)
    *,
    metric: str,
    bn: int = 128,
    interpret: bool = True,
):
    Np, d = X.shape
    K = C.shape[0]
    grid = (Np // bn,)
    kernel = functools.partial(_pdist_kernel, metric=metric)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((K, d), lambda i: (0, 0)),  # centroids VMEM-resident
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.int32),
            jax.ShapeDtypeStruct((Np,), jnp.float32),
        ],
        interpret=interpret,
    )(X, C)
