from repro.optim.optimizers import (
    Optimizer,
    adagrad,
    adam,
    clip_by_global_norm,
    momentum,
    sgd,
    warmup_cosine,
)

__all__ = [
    "Optimizer",
    "adagrad",
    "adam",
    "clip_by_global_norm",
    "momentum",
    "sgd",
    "warmup_cosine",
]
