"""Optimizers (no external deps): SGD, momentum, Adam, Adagrad.

Adagrad is here because the paper's §5 anchors on Dean et al.'s Downpour
SGD, which "made use of the adaptive learning rate procedure in [19]"
(Duchi et al.) for robustness under asynchrony — the staleness benchmark
compares plain SGD vs Adagrad under delay.

API mirrors optax minimally: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, new_state)``; apply with
``apply_updates``.  All states are pytrees (FSDP-shardable like params).
The moment dtype is configurable — bf16 moments halve optimizer HBM for
the 671B-scale dry-runs (see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], Any]
    update: Callable[[PyTree, Any, PyTree], tuple[PyTree, Any]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


# ----------------------------------------------------------------------------

def sgd(lr: float | Callable[[jnp.ndarray], jnp.ndarray]) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["count"]
        eta = lr(step) if callable(lr) else lr
        updates = jax.tree.map(lambda g: -eta * g, grads)
        return updates, {"count": step + 1}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(jnp.zeros_like, params),
        }

    def update(grads, state, params=None):
        step = state["count"]
        eta = lr(step) if callable(lr) else lr
        mu = jax.tree.map(lambda m, g: beta * m + g, state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -eta * (beta * m + g), mu, grads)
        else:
            upd = jax.tree.map(lambda m: -eta * m, mu)
        return upd, {"count": step + 1, "mu": mu}

    return Optimizer(init, update)


def adam(
    lr,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    moment_dtype: str | None = None,
) -> Optimizer:
    """AdamW.  ``moment_dtype="bfloat16"`` halves optimizer memory."""

    def _cast(x):
        return x.astype(moment_dtype) if moment_dtype else x

    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: _cast(jnp.zeros_like(p, jnp.float32)), params),
            "v": jax.tree.map(lambda p: _cast(jnp.zeros_like(p, jnp.float32)), params),
        }

    def update(grads, state, params=None):
        step = state["count"] + 1
        eta = lr(step) if callable(lr) else lr
        m = jax.tree.map(
            lambda m_, g: _cast(b1 * m_.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda v_, g: _cast(
                b2 * v_.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))
            ),
            state["v"], grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            mhat = m_.astype(jnp.float32) / bc1
            vhat = v_.astype(jnp.float32) / bc2
            u = -eta * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u - eta * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"count": step, "m": m, "v": v}

    return Optimizer(init, update)


def adagrad(lr, eps: float = 1e-10) -> Optimizer:
    """Duchi et al. [19] — the paper's cited adaptive method."""

    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "G": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params=None):
        step = state["count"]
        eta = lr(step) if callable(lr) else lr
        G = jax.tree.map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)), state["G"], grads
        )
        updates = jax.tree.map(
            lambda g, a: (-eta * g.astype(jnp.float32) / (jnp.sqrt(a) + eps)).astype(g.dtype),
            grads, G,
        )
        return updates, {"count": step + 1, "G": G}

    return Optimizer(init, update)


# ----------------------------------------------------------------------------

def clip_by_global_norm(opt: Optimizer, max_norm: float) -> Optimizer:
    def update(grads, state, params=None):
        norm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        return opt.update(grads, state, params)

    return Optimizer(opt.init, update)


def warmup_cosine(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return schedule
