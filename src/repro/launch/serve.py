"""Serving launcher — a thin CLI over the ``repro.serve`` subsystem.

Two paths, matching the two model families the repo trains:

* ``--arch`` (LM decode): batched prefill + decode through a
  ``ServeEngine``/``MicroBatcher`` pair, with per-request bytes metered
  on the engine's ``CommLedger``.  Attention architectures prefill the
  whole prompt in ONE call (the KV cache append supports T ≥ 1 tokens);
  recurrent mixers (mamba/xLSTM and hybrids) keep the token-by-token
  loop their single-step caches require.
* ``--strategy`` (classical fits): train a small ``api.fit``, publish it
  to a ``ModelRegistry``, load it back, and serve a query batch — the
  fit → publish → serve round trip on one command line.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --strategy gd \
      --registry /tmp/registry --requests 12
"""

from __future__ import annotations

import argparse
import json
import tempfile
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tf


# ----------------------------------------------------------------------------
# Prefill + decode (reused by OptimizerStrategy.predict_fn closures)
# ----------------------------------------------------------------------------

def batched_prefill_supported(cfg) -> bool:
    """True when every layer's mixer can append the whole prompt in one
    decode call (the capability is declared by the model layer:
    ``transformer.MULTI_TOKEN_MIXERS``)."""
    return all(
        spec.mixer in tf.MULTI_TOKEN_MIXERS for spec in tf.layer_specs(cfg)
    )


def _decode_fn(params, cfg, tokens, cache):
    return tf.decode_step(params, cfg, tokens, cache)


def _prefill_fn(params, cfg, tokens, cache):
    B, P = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(P), (B, P))
    return tf.decode_step(params, cfg, tokens, cache, positions=positions)


# the pre-call cache is dead after every decode step — donating it lets
# XLA update the KV buffers in place instead of copying the whole cache
# per generated token.  CPU ignores donation (and warns), so both
# variants exist and the caller picks by backend at runtime.
_decode = partial(jax.jit, static_argnames=("cfg",))(_decode_fn)
_decode_donated = partial(
    jax.jit, static_argnames=("cfg",), donate_argnames=("cache",)
)(_decode_fn)
_prefill_batched = partial(jax.jit, static_argnames=("cfg",))(_prefill_fn)
_prefill_donated = partial(
    jax.jit, static_argnames=("cfg",), donate_argnames=("cache",)
)(_prefill_fn)


def prefill_and_decode(cfg, params, prompts, *, gen: int, cache_len: int,
                       temperature: float = 0.0, seed: int = 0,
                       prefill: str = "auto"):
    """prompts: (B, P) int32 → returns (B, gen) generated ids.

    ``prefill``: "batched" (one call over the whole prompt — attention
    archs only), "loop" (token by token — every mixer family), or "auto".
    """
    B, P = prompts.shape
    cache = tf.init_cache(cfg, B, cache_len, jnp.float32)
    donate = jax.default_backend() != "cpu"
    decode = _decode_donated if donate else _decode
    prefill_step = _prefill_donated if donate else _prefill_batched

    if prefill == "auto":
        prefill = "batched" if batched_prefill_supported(cfg) else "loop"
    if prefill == "batched":
        if not batched_prefill_supported(cfg):
            raise ValueError(
                f"{cfg.name} has recurrent mixers — batched prefill needs "
                "an attention/MLA-only stack; use prefill='loop'"
            )
        logits, cache = prefill_step(params, cfg, prompts, cache)
    elif prefill == "loop":
        logits = None
        for t in range(P):
            logits, cache = decode(params, cfg, prompts[:, t : t + 1], cache)
    else:
        raise ValueError(f"unknown prefill mode {prefill!r}")

    outs = []
    key = jax.random.key(seed)
    for g in range(gen):
        lg = logits[:, -1, : cfg.vocab_size]
        if temperature > 0:
            # per-row keys: a row's sample depends only on its index, so
            # batch padding (always appended at the end) cannot change a
            # real request's tokens — the batcher's padding contract
            key, k = jax.random.split(key)
            row_keys = jax.vmap(lambda i: jax.random.fold_in(k, i))(
                jnp.arange(B)
            )
            tok = jax.vmap(jax.random.categorical)(
                row_keys, lg / temperature
            )[:, None]
        else:
            tok = jnp.argmax(lg, axis=-1)[:, None]
        outs.append(tok[:, 0])
        logits, cache = decode(params, cfg, tok.astype(jnp.int32), cache)
    return jnp.stack(outs, axis=1)


def lm_predict_fn(cfg, *, gen: int, temperature: float = 0.0, seed: int = 0):
    """The ``OptimizerStrategy.predict_fn`` closure for LM serving:
    prompts in, generated ids out, cache sized per prompt length."""

    def predict(params, prompts):
        P = prompts.shape[1]
        return prefill_and_decode(
            cfg, params, prompts, gen=gen, cache_len=P + gen + 1,
            temperature=temperature, seed=seed,
        )

    return predict


# ----------------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------------

def _serve_continuous(args):
    """Continuous-batching LM serving: requests join and retire
    independently over a paged KV cache (see docs/SERVING.md)."""
    from repro.serve import ContinuousLMEngine
    from repro.telemetry.report import RunReport
    from repro.telemetry.trace import Tracer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = tf.init_params(jax.random.key(args.seed), cfg)
    tracer = Tracer()
    engine = ContinuousLMEngine(
        cfg, params, n_slots=args.batch, page_size=args.page_size,
        max_seq=args.prompt_len + args.gen,
        temperature=args.temperature, seed=args.seed,
        tracer=tracer, tag=f"serve/{cfg.name}",
    )
    rng = np.random.default_rng(args.seed + 1)
    prompts = rng.integers(
        0, cfg.vocab_size, size=(args.requests, args.prompt_len)
    ).astype(np.int32)
    print(f"continuous serving {cfg.name} (slots={args.batch}, "
          f"page_size={args.page_size}, plan={engine.kernel_plan})")
    tickets = [engine.submit(p, max_new=args.gen) for p in prompts]
    engine.run_until_idle()
    outs = np.stack([t.result() for t in tickets])
    print(json.dumps({k: round(v, 4) if isinstance(v, float) else v
                      for k, v in engine.stats().items()}))
    print(RunReport.from_serve(engine).to_markdown())
    print("sample:", outs[0].tolist())
    return outs


def _serve_arch(args):
    from repro.api.strategy import OptimizerStrategy
    from repro.serve import MicroBatcher, ServeEngine

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("enc-dec serving: see examples/whisper_serve.py")

    params = tf.init_params(jax.random.key(args.seed), cfg)
    strategy = OptimizerStrategy(
        None, None,
        predict_fn=lm_predict_fn(
            cfg, gen=args.gen, temperature=args.temperature, seed=args.seed
        ),
    )
    mesh = _make_mesh(args)
    engine = ServeEngine(strategy, params, mesh=mesh, tag=f"serve/{cfg.name}")
    batcher = MicroBatcher(
        engine, max_batch=args.batch, timeout_s=args.timeout_ms / 1e3
    )
    prompts = jax.random.randint(
        jax.random.key(args.seed + 1),
        (args.requests, args.prompt_len),
        0,
        cfg.vocab_size,
    )
    mode = "batched" if batched_prefill_supported(cfg) else "loop"
    print(f"serving {cfg.name} ({mode} prefill, "
          f"buckets={batcher.buckets}, mesh={bool(mesh)})")
    tickets = [batcher.submit(np.asarray(p)) for p in prompts]
    _drain(batcher)
    outs = jnp.stack([t.result() for t in tickets])
    stats = engine.stats()
    print(json.dumps({k: round(v, 4) if isinstance(v, float) else v
                      for k, v in stats.items()}))
    print("sample:", np.asarray(outs[0]).tolist())
    return outs


def _serve_strategy(args):
    from repro import api
    from repro.ml.linear import lsq_loss
    from repro.serve import MicroBatcher, ModelRegistry, ServeEngine

    rng = np.random.default_rng(args.seed)
    registry = ModelRegistry(
        args.registry or tempfile.mkdtemp(prefix="registry-")
    )
    mesh = _make_mesh(args)

    if args.strategy == "gd":
        K, Nk, n = 8, 32, 16
        X = jnp.asarray(rng.normal(size=(K, Nk, n)))
        w = jnp.asarray(rng.normal(size=(n,)))
        y = jnp.einsum("kni,i->kn", X, w)
        strategy = api.GradientDescent(lsq_loss, lr=0.1)
        res = api.fit(strategy, (X, y), transport="allreduce", steps=200)
        like = None
    elif args.strategy == "kwindows":
        from repro.core.schedules import round_robin
        from repro.ml.kwindows import KWindowsStrategy

        K, Nk, d = 4, 64, 2
        centers = rng.normal(size=(3, d)) * 4.0
        Xs = jnp.asarray(
            centers[rng.integers(0, 3, size=(K, Nk))]
            + rng.normal(size=(K, Nk, d)) * 0.3
        )
        strategy = KWindowsStrategy(
            jax.random.key(args.seed), num_windows=6, r=1.0
        )
        res = api.fit(strategy, Xs, transport="sequential_server",
                      schedule=round_robin(K, 1))
        like = res.theta
    else:
        raise SystemExit(f"unknown --strategy {args.strategy!r}")

    version = registry.publish(args.strategy, res.theta,
                               meta={"transport": res.metrics["transport"]})
    engine = ServeEngine.from_registry(
        registry, args.strategy, strategy, like=like, mesh=mesh,
        tag=f"serve/{args.strategy}",
    )
    batcher = MicroBatcher(engine, max_batch=args.batch,
                           timeout_s=args.timeout_ms / 1e3)
    if args.strategy == "gd":
        dim = engine.theta.shape[0]
        queries = rng.normal(size=(args.requests, dim))
    else:
        # query near the true clusters so assignments are observable
        # (far-off points are correctly -1 / uncaptured)
        queries = (
            centers[rng.integers(0, len(centers), size=args.requests)]
            + rng.normal(size=(args.requests, centers.shape[1])) * 0.3
        )
    tickets = [
        batcher.submit(q.astype(np.float32)) for q in queries
    ]
    _drain(batcher)
    preds = [np.asarray(t.result()) for t in tickets]
    print(f"published {args.strategy} v{version} -> {registry.root}")
    print(json.dumps(engine.stats()))
    print("predictions:", np.asarray(preds)[: min(8, len(preds))].round(3).tolist())
    return preds


def _drain(batcher) -> None:
    """Serve the queue the way a real loop would: full buckets flushed on
    arrival (submit), the ragged tail by timeout — so ``--timeout-ms``
    is an observable latency bound, not just a constructor argument."""
    while batcher.pending():
        if not batcher.poll():
            time.sleep(batcher.timeout_s / 4)


def _make_mesh(args):
    if not args.mesh:
        return None
    from repro.launch.mesh import make_node_mesh

    return make_node_mesh()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--strategy", default="",
                    help="serve a classical fit instead: gd | kwindows")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="largest microbatch bucket")
    ap.add_argument("--requests", type=int, default=0,
                    help="number of synthetic requests (default: --batch)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching: slot-scheduled decode over "
                         "a paged KV cache (--batch = n_slots)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (continuous path)")
    ap.add_argument("--timeout-ms", type=float, default=10.0)
    ap.add_argument("--registry", default="",
                    help="model registry root (strategy path)")
    ap.add_argument("--mesh", action="store_true",
                    help="place the engine on a mesh over all local devices")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if not args.requests:
        args.requests = args.batch
    if args.strategy:
        return _serve_strategy(args)
    if not args.arch:
        args.arch = "qwen2-1.5b"
    if args.continuous:
        return _serve_continuous(args)
    return _serve_arch(args)


if __name__ == "__main__":
    main()
