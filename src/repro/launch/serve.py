"""Serving launcher: batched prefill + decode loop with KV/SSM caches.

CPU-runnable with reduced configs; the same ``serve_step`` is what the
decode dry-run shapes lower at production scale.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as tf


def prefill_and_decode(cfg, params, prompts, *, gen: int, cache_len: int,
                       temperature: float = 0.0, seed: int = 0):
    """prompts: (B, P) int32 → returns (B, gen) generated ids."""
    B, P = prompts.shape
    cache = tf.init_cache(cfg, B, cache_len, jnp.float32)

    decode = jax.jit(lambda p, t, c: tf.decode_step(p, cfg, t, c))

    # prefill token-by-token (keeps every mixer family exact; attention
    # archs could batch this — see examples/serving_pipeline.py)
    logits = None
    for t in range(P):
        logits, cache = decode(params, prompts[:, t : t + 1], cache)

    outs = []
    key = jax.random.key(seed)
    tok = None
    for g in range(gen):
        lg = logits[:, -1, : cfg.vocab_size]
        if temperature > 0:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, lg / temperature)[:, None]
        else:
            tok = jnp.argmax(lg, axis=-1)[:, None]
        outs.append(tok[:, 0])
        logits, cache = decode(params, tok.astype(jnp.int32), cache)
    return jnp.stack(outs, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("enc-dec serving: see examples/whisper_serve.py")

    params = tf.init_params(jax.random.key(args.seed), cfg)
    prompts = jax.random.randint(
        jax.random.key(args.seed + 1),
        (args.batch, args.prompt_len),
        0,
        cfg.vocab_size,
    )
    t0 = time.time()
    out = prefill_and_decode(
        cfg,
        params,
        prompts,
        gen=args.gen,
        cache_len=args.prompt_len + args.gen + 1,
        temperature=args.temperature,
        seed=args.seed,
    )
    dt = time.time() - t0
    toks = args.batch * (args.prompt_len + args.gen)
    print(f"served {args.batch} requests: {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("sample:", out[0].tolist())
    return out


if __name__ == "__main__":
    main()
