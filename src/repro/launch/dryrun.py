import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers and compiles.

For each combination this lowers the appropriate step (train_step /
prefill_step / serve_step) against ``input_specs`` ShapeDtypeStructs with
the production sharding specs, compiles it, and records:

* ``memory_analysis``   — per-device HBM (proves it fits / doesn't);
* ``cost_analysis``     — raw per-device FLOPs + bytes (NOTE: XLA counts
  scan bodies once; kept for reference only);
* probe-corrected costs — trip-count-correct FLOPs / bytes / collective
  bytes via ``telemetry.costprobe`` (unrolled probe lowers + extrapolation);
* the three roofline terms + dominant bottleneck (telemetry.roofline).

Results land in ``experiments/dryrun/<arch>__<shape>__<mesh>[__tag].json``.

NOTE the two lines above MUST stay the first statements in this module:
jax fixes the device count at first initialization.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import SHAPES, applicable
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as S
from repro.sharding.rules import set_mesh_context
from repro.telemetry import hlo as hlo_lib
from repro.telemetry import roofline as rl
from repro.telemetry.costprobe import probe_costs


def run_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    mla_absorb: bool = False,
    remat_override: str | None = None,
    microbatches: int | None = None,
    strategy: str = "tp",
    probes: bool = True,
    extra_tag: str = "",
    seed: int = 0,
) -> dict:
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cfg = S.shape_adapted_config(arch, shape_name)
    if remat_override is not None:
        cfg = cfg.replace(remat_policy=remat_override)

    ok, why = applicable(arch, shape_name)
    if not ok:
        return {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "skipped", "reason": why,
        }

    t0 = time.time()
    set_mesh_context(
        S.make_mesh_context_for(mesh, cfg, shape.global_batch, strategy=strategy)
    )
    if microbatches is None:
        microbatches = 4 if shape.kind == "train" else 1
    try:
        jitted, args, params_shape = S.build_jitted(
            cfg, shape.kind, mesh, shape.global_batch, shape.seq_len,
            mla_absorb=mla_absorb, microbatches=microbatches,
            strategy=strategy, seed=seed,
        )
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        coll_raw = hlo_lib.collective_stats(compiled.as_text())
        set_mesh_context(None)

        # --- trip-count-correct costs (probe lowering)
        if probes:
            t_p = time.time()
            pc = probe_costs(
                cfg, shape.kind, mesh, shape.global_batch, shape.seq_len,
                mla_absorb=mla_absorb, strategy=strategy,
            )
            t_probe = time.time() - t_p
        else:
            pc = {
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": float(coll_raw.get("total_bytes", 0)),
                "n_probes": 0,
            }
            t_probe = 0.0

        # model flops
        active = S.count_active_params(cfg, params_shape)
        if shape.kind == "train":
            tokens = shape.global_batch * (
                min(shape.seq_len, S.DECODER_CTX)
                if cfg.is_encoder_decoder
                else shape.seq_len
            )
            mf = rl.model_flops_train(active, tokens)
        elif shape.kind == "prefill":
            tokens = shape.global_batch * (
                min(shape.seq_len, S.DECODER_CTX)
                if cfg.is_encoder_decoder
                else shape.seq_len
            )
            mf = rl.model_flops_decode(active, tokens)
        else:
            mf = rl.model_flops_decode(active, shape.global_batch)

        roof = rl.roofline(
            flops_per_device=pc["flops"],
            bytes_per_device=pc["bytes"],
            collective_bytes_per_device=pc["coll"],
            chips=chips,
            model_flops=mf,
        )

        mem_d = {}
        for attr in (
            "temp_size_in_bytes",
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "alias_size_in_bytes",
        ):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_d[attr] = int(v)
        mem_d["steady_state_bytes"] = (
            mem_d.get("argument_size_in_bytes", 0)
            + mem_d.get("temp_size_in_bytes", 0)
            - mem_d.get("alias_size_in_bytes", 0)
        )

        result = {
            "arch": arch,
            "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "chips": chips,
            "status": "ok",
            "tag": extra_tag,
            "kind": shape.kind,
            "n_params": int(S.count_params(params_shape)),
            "active_params": float(active),
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "probe_s": round(t_probe, 2),
            "memory": mem_d,
            "cost_raw": {
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            },
            "cost_corrected": pc,
            "collectives_raw": coll_raw,
            "roofline": roof.to_dict(),
            "config": {
                "param_dtype": cfg.param_dtype,
                "remat": cfg.remat_policy,
                "sliding_window": cfg.sliding_window,
                "mla_absorb": mla_absorb,
                "microbatches": microbatches,
                "strategy": strategy,
            },
        }
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        result = {
            "arch": arch,
            "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "error",
            "tag": extra_tag,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
        }
    finally:
        set_mesh_context(None)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--strategy", default="tp",
                    choices=["tp", "dp", "dp_fsdp", "kvseq", "serve", "ep2d"])
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    res = run_one(
        args.arch,
        args.shape,
        multi_pod=args.multipod,
        mla_absorb=args.mla_absorb,
        remat_override=args.remat,
        microbatches=args.microbatches,
        strategy=args.strategy,
        probes=not args.no_probes and not args.multipod,
        extra_tag=args.tag,
    )
    os.makedirs(args.out, exist_ok=True)
    mesh_tag = "2x16x16" if args.multipod else "16x16"
    suffix = f"__{args.tag}" if args.tag else ""
    path = os.path.join(
        args.out, f"{args.arch}__{args.shape}__{mesh_tag}{suffix}.json"
    )
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    print(json.dumps({k: v for k, v in res.items() if k != "traceback"}, indent=2))


if __name__ == "__main__":
    main()
