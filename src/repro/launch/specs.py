"""Input specs, sharding specs and step builders for the launcher/dry-run.

``input_specs`` returns weak-type-correct ``ShapeDtypeStruct`` stand-ins for
every model input (no device allocation) — the shapes the production mesh
is proven against.  ``decode`` shapes lower ``serve_step`` (1 new token vs a
``seq_len`` cache); train/prefill lower ``train_step``/``prefill_step``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.launch.mesh import batch_axes, data_axis_size
from repro.models import transformer as tf, whisper
from repro.models.cache import KVCache, MLACache, MambaCache, MLSTMCache, SLSTMCache
from repro.models.config import ModelConfig
from repro.optim import adam, clip_by_global_norm, warmup_cosine
from repro.optim.optimizers import apply_updates
from repro.sharding.rules import MeshContext, partition_params, set_mesh_context

VISION_PREFIX = 256  # stubbed patch-embedding prefix length (qwen2-vl)
DECODER_CTX = 448  # whisper decoder context for train/prefill shapes


# ----------------------------------------------------------------------------
# Config adaptation per input shape
# ----------------------------------------------------------------------------

def shape_adapted_config(arch: str, shape_name: str) -> ModelConfig:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    # big models: bf16 params + bf16 Adam moments (HBM budget; DESIGN.md §5)
    if _approx_param_count(cfg) > 2e10:
        cfg = cfg.replace(param_dtype="bfloat16")
    if shape.kind == "decode" and shape_name == "long_500k":
        if cfg.family in ("dense", "moe", "vlm"):
            # sub-quadratic variant: sliding-window attention (DESIGN.md)
            cfg = cfg.replace(sliding_window=8192)
    if shape.kind != "train":
        cfg = cfg.replace(remat_policy="none", num_mtp_layers=0)
    else:
        # training at 4k×256 always wants activation checkpointing; "full"
        # is the memory-safe baseline ("dots" is a §Perf lever where it fits)
        cfg = cfg.replace(remat_policy="full")
    if shape.kind in ("train", "prefill") and not cfg.is_encoder_decoder:
        # query-chunked attention bounds the live softmax matrix (flash-
        # attention memory behavior for the XLA path; Pallas kernel on TPU)
        cfg = cfg.replace(attn_q_chunk=512)
    return cfg


def _approx_param_count(cfg: ModelConfig) -> float:
    d, L, f, V = cfg.d_model, cfg.num_layers, cfg.d_ff, cfg.vocab_size
    base = V * d * (1 if cfg.tie_embeddings else 2)
    attn = 4 * d * cfg.num_heads * cfg.head_dim
    per_layer = attn + 3 * d * f
    if cfg.moe is not None:
        per_layer = attn + 3 * d * cfg.moe.d_ff_expert * cfg.moe.num_experts
    return base + L * per_layer


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def count_active_params(cfg: ModelConfig, params) -> float:
    """Active parameters (MoE experts scaled by top_k/num_experts)."""
    total = 0.0
    scale = 1.0
    if cfg.moe is not None:
        scale = cfg.moe.top_k / cfg.moe.num_experts
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        pstr = "/".join(str(getattr(p, "key", p)) for p in path)
        total += leaf.size * (scale if "experts/" in pstr else 1.0)
    return total


# ----------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins)
# ----------------------------------------------------------------------------

def input_specs(arch: str, shape_name: str) -> dict[str, Any]:
    cfg = shape_adapted_config(arch, shape_name)
    shape = SHAPES[shape_name]
    return input_specs_for(cfg, shape.kind, shape.global_batch, shape.seq_len)


def input_specs_for(cfg: ModelConfig, kind: str, B: int, S: int) -> dict[str, Any]:
    i32 = jnp.int32
    cd = jnp.dtype(cfg.compute_dtype)

    if kind in ("train", "prefill"):
        if cfg.is_encoder_decoder:
            T = min(S, DECODER_CTX)
            specs = {
                "frame_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq_len, cfg.d_model), cd
                ),
                "tokens": jax.ShapeDtypeStruct((B, T), i32),
            }
            if kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, T), i32)
            return specs
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.family == "vlm":
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, VISION_PREFIX, cfg.d_model), cd
            )
            specs["mrope_positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return specs

    # decode: one new token against a seq_len cache
    specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    cache_dtype = jnp.bfloat16
    if cfg.is_encoder_decoder:
        specs["memory"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq_len, cfg.d_model), cd)
        cache = jax.eval_shape(
            lambda: whisper.init_decoder_cache(cfg, B, S, cache_dtype, index=S - 1)
        )
    else:
        cache = jax.eval_shape(
            lambda: tf.init_cache(cfg, B, S, cache_dtype, index=S - 1)
        )
    specs["cache"] = cache
    return specs


def concrete_inputs(arch: str, shape_name: str, seed: int = 0) -> dict[str, Any]:
    """Concrete (small-seeded) inputs matching ``input_specs`` — used by the
    CPU smoke tests with reduced configs, NOT by the dry-run."""
    cfg = shape_adapted_config(arch, shape_name)
    shape = SHAPES[shape_name]
    specs = input_specs(arch, shape_name)
    key = jax.random.key(seed)

    def realize(path, s):
        if s.dtype == jnp.int32:
            return jax.random.randint(key, s.shape, 0, max(2, cfg.vocab_size - 1))
        return jax.random.normal(key, s.shape, s.dtype)

    return jax.tree_util.tree_map_with_path(realize, specs)


# ----------------------------------------------------------------------------
# Sharding specs
# ----------------------------------------------------------------------------

def make_mesh_context(mesh, cfg: ModelConfig, shape_name: str) -> MeshContext:
    return make_mesh_context_for(mesh, cfg, SHAPES[shape_name].global_batch)


def make_mesh_context_for(
    mesh, cfg: ModelConfig, B: int, *, strategy: str = "tp"
) -> MeshContext:
    baxes = batch_axes(mesh)
    if strategy in ("dp", "dp_fsdp"):
        baxes = tuple(mesh.axis_names)  # batch over EVERY axis, no TP
    dsize = 1
    for a in baxes:
        dsize *= mesh.shape[a]
    logical = {} if strategy in ("dp", "dp_fsdp") else {"model": "model"}
    if strategy == "kvseq":
        # decode variant: pin the KV cache's sequence dim to the model axis
        # inside attention so XLA keeps partial-softmax locality
        logical["kvseq"] = "model"
    if B % dsize == 0 and B >= dsize:
        logical["batch"] = baxes if len(baxes) > 1 else baxes[0]
    # (seq stays unsharded for activations; cache seq sharding is separate)
    fsdp = _approx_param_count(cfg) > FSDP_THRESHOLD or strategy == "dp_fsdp"
    return MeshContext(mesh=mesh, logical=logical, fsdp=fsdp)


FSDP_THRESHOLD = 5e9  # params above this shard over the data axes too


def param_specs(cfg: ModelConfig, params, mesh, *, strategy: str = "tp"):
    if strategy == "serve":
        # decode/prefill: no optimizer state exists, so FSDP only buys
        # per-step parameter all-gathers — keep params TP-sharded instead
        return partition_params(params, model_axis="model", fsdp_axis=None)
    if strategy == "ep2d":
        # 2-D expert parallelism: experts sharded over (model × data) so
        # expert weights are never FSDP-gathered; non-expert params keep
        # TP + FSDP
        baxes = batch_axes(mesh)
        fsdp_axis = baxes if len(baxes) > 1 else baxes[0]
        return partition_params(
            params, model_axis="model", fsdp_axis=fsdp_axis,
            expert_axes=("model",) + tuple(
                a for a in mesh.axis_names if a in ("data",)
            ),
        )
    if strategy == "dp":
        # pure data parallelism: params replicated on every axis
        return partition_params(params, model_axis=None, fsdp_axis=None)
    if strategy == "dp_fsdp":
        # ZeRO-3: no tensor parallelism, params sharded over all axes
        return partition_params(
            params, model_axis=None, fsdp_axis=tuple(mesh.axis_names)
        )
    ctx_fsdp = _approx_param_count(cfg) > FSDP_THRESHOLD
    baxes = batch_axes(mesh)
    fsdp_axis = (baxes if len(baxes) > 1 else baxes[0]) if ctx_fsdp else None
    return partition_params(params, model_axis="model", fsdp_axis=fsdp_axis)


def _cache_entry_axes(mesh, B: int, n_heads: int):
    """Decide (batch, seq, heads) physical axes for cache tensors."""
    baxes = batch_axes(mesh)
    dsize = data_axis_size(mesh)
    msize = mesh.shape["model"]
    batch_ax = (baxes if len(baxes) > 1 else baxes[0]) if B % dsize == 0 and B >= dsize else None
    heads_ax = "model" if n_heads % msize == 0 else None
    if batch_ax is None and heads_ax is None:
        seq_ax = tuple(list(baxes) + ["model"])
    elif batch_ax is None:
        seq_ax = baxes if len(baxes) > 1 else baxes[0]
    elif heads_ax is None:
        seq_ax = "model"
    else:
        seq_ax = None
    return batch_ax, seq_ax, heads_ax


def layer_cache_specs(cfg: ModelConfig, spec_mixer: str, mesh, B: int):
    if spec_mixer in ("attn", "whisper"):
        n_kv = cfg.num_kv_heads if spec_mixer == "attn" else cfg.num_heads
        b, s, h = _cache_entry_axes(mesh, B, n_kv)
        kv = P(b, s, h, None)
        return KVCache(k=kv, v=kv, index=P())
    if spec_mixer == "mla":
        b, s, _ = _cache_entry_axes(mesh, B, 1)  # latent has no head dim
        return MLACache(c_kv=P(b, s, None), k_rope=P(b, s, None), index=P())
    if spec_mixer == "mamba":
        b, _, _ = _cache_entry_axes(mesh, B, 1)
        return MambaCache(conv=P(b, None, "model"), ssm=P(b, "model", None))
    if spec_mixer == "mlstm":
        msize = mesh.shape["model"]
        b, _, _ = _cache_entry_axes(mesh, B, 1)
        h_ax = "model" if cfg.num_heads % msize == 0 else None
        return MLSTMCache(C=P(b, h_ax, None, None), n=P(b, h_ax, None), m=P(b, h_ax))
    if spec_mixer == "slstm":
        msize = mesh.shape["model"]
        b, _, _ = _cache_entry_axes(mesh, B, 1)
        d_ax = "model" if cfg.d_model % msize == 0 else None
        return SLSTMCache(c=P(b, d_ax), n=P(b, d_ax), h=P(b, d_ax), m=P(b, d_ax))
    raise ValueError(spec_mixer)


def _prepend_none(spec: P) -> P:
    return P(*((None,) + tuple(spec)))


def cache_specs(cfg: ModelConfig, mesh, B: int):
    """PartitionSpec pytree mirroring ``tf.init_cache`` (stacked segments)."""
    if cfg.is_encoder_decoder:
        unit = layer_cache_specs(cfg, "whisper", mesh, B)
        return jax.tree.map(
            _prepend_none, unit, is_leaf=lambda x: isinstance(x, P)
        )
    out = {}
    for si, seg in enumerate(tf.segments(cfg)):
        unit_spec = {
            f"l{li}": layer_cache_specs(cfg, spec.mixer, mesh, B)
            for li, spec in enumerate(seg.unit)
        }
        out[f"seg{si}"] = jax.tree.map(
            _prepend_none, unit_spec, is_leaf=lambda x: isinstance(x, P)
        )
    return out


def batch_specs(specs: dict, mesh, B: int, *, strategy: str = "tp") -> dict:
    """Shardings for the input batch dict (tokens/labels/embeds/...)."""
    baxes = batch_axes(mesh)
    if strategy in ("dp", "dp_fsdp"):
        baxes = tuple(mesh.axis_names)
    dsize = 1
    for a in baxes:
        dsize *= mesh.shape[a]
    bax = (baxes if len(baxes) > 1 else baxes[0]) if B % dsize == 0 and B >= dsize else None
    out = {}
    for k, v in specs.items():
        if k == "cache":
            continue
        if k == "mrope_positions":
            out[k] = P(None, bax, None)
        elif hasattr(v, "ndim") and v.ndim >= 2:
            out[k] = P(*((bax,) + (None,) * (v.ndim - 1)))
        else:
            out[k] = P(bax)
    return out


# ----------------------------------------------------------------------------
# Step builders
# ----------------------------------------------------------------------------

def make_optimizer(cfg: ModelConfig, *, peak_lr=3e-4, warmup=100, total=10_000):
    moment_dtype = "bfloat16" if _approx_param_count(cfg) > 2e10 else None
    return clip_by_global_norm(
        adam(warmup_cosine(peak_lr, warmup, total), moment_dtype=moment_dtype), 1.0
    )


def make_train_step(cfg: ModelConfig, optimizer, *, microbatches: int = 1):
    """Data-parallel train step, optionally with gradient accumulation.

    Microbatching IS the paper's §5 round-robin schedule applied within a
    step: the global update is the sequential composition of per-shard
    first-order updates, which the paper proves equivalent to mini-batch GD
    — here made literal by summing the per-microbatch gradients before one
    optimizer application.  It is also the standard HBM lever: the live
    activation working set scales with B/microbatches.
    """
    loss = whisper.loss_fn if cfg.is_encoder_decoder else tf.loss_fn

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (l, metrics), grads = jax.value_and_grad(
                lambda p: loss(p, cfg, batch), has_aux=True
            )(params)
        else:

            def split(k, v):
                ax = 1 if k == "mrope_positions" else 0
                n = v.shape[ax]
                v = jnp.moveaxis(v, ax, 0)
                v = v.reshape(microbatches, n // microbatches, *v.shape[1:])
                return jnp.moveaxis(v, 1, ax + 1)

            mb = {k: split(k, v) for k, v in batch.items()}
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(carry, mbatch):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(
                    lambda p: loss(p, cfg, mbatch), has_aux=True
                )(params)
                g_acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / microbatches,
                    g_acc, g,
                )
                return (g_acc, l_acc + l / microbatches), None

            (grads, l), _ = jax.lax.scan(body, (g0, jnp.zeros(())), mb)
            metrics = {}
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=l)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    if cfg.is_encoder_decoder:

        def prefill_step(params, batch):
            memory = whisper.encode(params, cfg, batch["frame_embeds"])
            logits, _ = whisper.decode(params, cfg, batch["tokens"], memory)
            return logits

    else:

        def prefill_step(params, batch):
            logits, _, _ = tf.forward(
                params,
                cfg,
                batch["tokens"],
                mrope_positions=batch.get("mrope_positions"),
                vision_embeds=batch.get("vision_embeds"),
            )
            return logits

    return prefill_step


# ----------------------------------------------------------------------------
# One-stop lowering builder (used by dryrun + cost probes)
# ----------------------------------------------------------------------------

def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def opt_state_specs(opt_state_shape, params_shape, pspec_tree):
    """Optimizer-state specs: subtrees mirroring the param tree reuse the
    param specs (FSDP'd moments); everything else is replicated."""
    params_structure = jax.tree.structure(params_shape)

    def assign(sub):
        if jax.tree.structure(sub) == params_structure:
            return pspec_tree
        return jax.tree.map(lambda _: P(), sub)

    if isinstance(opt_state_shape, dict):
        return {k: assign(v) for k, v in opt_state_shape.items()}
    return jax.tree.map(lambda _: P(), opt_state_shape)


def build_jitted(cfg: ModelConfig, kind: str, mesh, B: int, S: int, *,
                 mla_absorb: bool = False, microbatches: int = 1,
                 strategy: str = "tp", seed: int = 0):
    """Build the jitted step + abstract args for (cfg, kind, B, S) on mesh.

    Returns ``(jitted, args, params_shape)``.  Caller is responsible for
    setting the mesh context (``make_mesh_context_for``) around lowering.
    """
    key = jax.random.key(seed)
    init = whisper.init_params if cfg.is_encoder_decoder else tf.init_params
    params_shape = jax.eval_shape(lambda: init(key, cfg))
    pspecs = param_specs(cfg, params_shape, mesh, strategy=strategy)
    in_specs = input_specs_for(cfg, kind, B, S)
    bspecs = batch_specs(in_specs, mesh, B, strategy=strategy)

    if kind == "train":
        optimizer = make_optimizer(cfg)
        opt_shape = jax.eval_shape(optimizer.init, params_shape)
        ospecs = opt_state_specs(opt_shape, params_shape, pspecs)
        step = make_train_step(cfg, optimizer, microbatches=microbatches)
        jitted = jax.jit(
            step,
            in_shardings=(
                _named(mesh, pspecs),
                _named(mesh, ospecs),
                _named(mesh, bspecs),
            ),
            out_shardings=(_named(mesh, pspecs), _named(mesh, ospecs), None),
        )
        args = (params_shape, opt_shape, in_specs)
    elif kind == "prefill":
        step = make_prefill_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)),
            out_shardings=None,
        )
        args = (params_shape, in_specs)
    else:  # decode
        step = make_serve_step(cfg, mla_absorb=mla_absorb)
        cspecs = cache_specs(cfg, mesh, B)
        bspecs_all = dict(bspecs)
        bspecs_all["cache"] = cspecs
        jitted = jax.jit(
            step,
            in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs_all)),
            out_shardings=(None, _named(mesh, cspecs)),
        )
        args = (params_shape, in_specs)
    return jitted, args, params_shape


def make_serve_step(cfg: ModelConfig, *, mla_absorb: bool = False):
    if cfg.is_encoder_decoder:

        def serve_step(params, batch):
            cache = batch["cache"]
            idx = jax.tree.leaves(cache)[-1].reshape(-1)[0]  # stacked index
            logits, new_cache = whisper.decode_step(
                params, cfg, batch["tokens"], batch["memory"], cache, position=idx
            )
            return logits, new_cache

    else:

        def serve_step(params, batch):
            logits, new_cache = tf.decode_step(
                params, cfg, batch["tokens"], batch["cache"], mla_absorb=mla_absorb
            )
            return logits, new_cache

    return serve_step
