"""Training launcher — end-to-end driver usable on CPU (reduced configs)
and, unchanged, on a real mesh (full configs).

The per-step update pipeline is the unified ``repro.api`` engine:

* strategy  — ``OptimizerStrategy`` (gradient of the LM loss through a
  ``repro.optim`` optimizer);
* transport — ``delay_line`` (``--staleness D``: D=0 synchronous; D=1 the
  paper's literal one-step-stale protocol);
* wire      — ``--compress-topk f`` selects ``topk:f+ef`` (top-k
  sparsified push with error feedback), otherwise dense.

The driver calls ``api.fit`` in chunks aligned to the logging /
checkpoint cadence, resuming each chunk from the previous
``FitResult.metrics["carry"]`` so the delay line, error-feedback
residuals and optimizer state flow through unchanged.

``--sweep-staleness "0,1,2,4"`` runs all listed staleness levels as ONE
vmapped scenario batch (the sweep executor): every level shares one
compiled step and one data stream, and the driver reports the loss
trajectory per scenario — the cheapest way to pick D before a long run.

``--multipod`` installs a ``("pod", "data")`` multipod ``MeshContext``
(``launch.mesh.make_multipod_mesh``) so the model's activation-sharding
constraints place the batch over pods × intra-pod data shards — the
production placement, runnable on CPU with fake devices.  The two flags
COMPOSE: ``--sweep-staleness --multipod`` nests the activation sharding
inside the scenario vmap, so every staleness level trains mesh-placed in
the one executable (the executor-composition story of
``docs/EXECUTORS.md``, driven from the CLI).

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --batch 8 --seq 128 --log-every 10
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import api
from repro.api.strategy import OptimizerStrategy
from repro.checkpoint import save
from repro.configs import get_config
from repro.data import synthetic_lm_batches
from repro.models import transformer as tf
from repro.optim import adam, clip_by_global_norm, warmup_cosine


def _chunk_end(done: int, steps: int, log_every: int, ckpt_every: int) -> int:
    """Next boundary where the driver needs control back."""
    targets = [steps, (done // log_every + 1) * log_every]
    if ckpt_every:
        targets.append((done // ckpt_every + 1) * ckpt_every)
    return min(t for t in targets if t > done)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", help="CPU smoke variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--staleness", type=int, default=0)
    ap.add_argument(
        "--sweep-staleness", default="",
        help="comma-separated staleness levels batched into one vmapped "
        "sweep (overrides --staleness; incompatible with checkpointing; "
        "composes with --multipod: the sweep then trains every level "
        "mesh-placed in one executable)",
    )
    ap.add_argument("--compress-topk", type=float, default=0.0)
    ap.add_argument(
        "--multipod", action="store_true",
        help="run under a ('pod', 'data') multipod MeshContext: activation "
        "batches shard over pods × data shards (the production placement; "
        "on CPU combine with XLA_FLAGS=--xla_force_host_platform_device_"
        "count=N for N fake devices)",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--fault-seed", type=int, default=0,
        help="seed for the FaultPlan draw streams (used when any fault "
        "flag below is set; see docs/FAULTS.md)",
    )
    ap.add_argument(
        "--dropout-p", type=float, default=0.0,
        help="per-round per-node drop probability: dead nodes are masked "
        "out of the aggregate and cost zero uplink bytes",
    )
    ap.add_argument(
        "--straggler", type=int, default=0,
        help="max per-node integer lag per round; the delay line deepens "
        "by this many slots and reads at staleness + max(live lags)",
    )
    ap.add_argument(
        "--quorum", type=int, default=0,
        help="minimum surviving responders for a round to commit "
        "(0 = no quorum gate); below quorum the round rolls back",
    )
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("use examples/whisper_train.py for enc-dec training")

    key = jax.random.key(args.seed)
    params = tf.init_params(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    optimizer = clip_by_global_norm(
        adam(warmup_cosine(args.lr, args.steps // 10 + 1, args.steps)), 1.0
    )
    strategy = OptimizerStrategy(
        lambda p, batch: tf.loss_fn(p, cfg, batch), optimizer, has_aux=True
    )
    wire = f"topk:{args.compress_topk}+ef" if args.compress_topk > 0 else "dense"

    faults = None
    if args.dropout_p or args.straggler or args.quorum:
        from repro.api.faults import FaultPlan

        faults = FaultPlan(
            seed=args.fault_seed,
            dropout_p=args.dropout_p,
            straggler=args.straggler,
            quorum=args.quorum or None,
        )

    sweep_levels = None
    executor = "local"
    if args.sweep_staleness:
        if args.ckpt_dir:
            raise SystemExit("--sweep-staleness is incompatible with --ckpt-dir")
        sweep_levels = [int(s) for s in args.sweep_staleness.split(",")]
        executor = api.SweepExecutor({"staleness": jnp.asarray(sweep_levels)})

    mesh_note = ""
    if args.multipod:
        from repro.launch.mesh import make_multipod_mesh
        from repro.sharding.rules import MeshContext, set_mesh_context

        mesh = make_multipod_mesh()
        ndev = mesh.shape["pod"] * mesh.shape["data"]
        if args.batch % ndev:
            raise SystemExit(
                f"--batch {args.batch} must divide over the "
                f"{mesh.shape['pod']}x{mesh.shape['data']} multipod mesh"
            )
        set_mesh_context(
            MeshContext(mesh=mesh, logical={"batch": ("pod", "data")})
        )
        mesh_note = (
            f", mesh=pod:{mesh.shape['pod']}x data:{mesh.shape['data']}"
        )

    data = synthetic_lm_batches(args.seed, args.batch, args.seq, cfg.vocab_size)
    fault_note = f", faults={faults!r}" if faults is not None else ""
    print(
        f"training {cfg.name} ({n_params/1e6:.1f}M params, "
        f"staleness={sweep_levels or args.staleness}, wire={wire}"
        f"{mesh_note}{fault_note})"
    )
    t0 = time.time()
    history = []
    theta, carry, done = params, None, 0
    wire_bytes = 0
    while done < args.steps:
        end = _chunk_end(done, args.steps, args.log_every, args.ckpt_every)
        batches = [next(data) for _ in range(end - done)]
        stream = jax.tree.map(lambda *xs: jnp.stack(xs), *batches)
        res = api.fit(
            strategy,
            None,
            transport="delay_line",
            staleness=args.staleness,
            wire=wire,
            executor=executor,
            stream=stream,
            theta0=theta,
            carry=carry,
            faults=faults,
            tag="train",
        )
        theta, carry = res.theta, res.metrics["carry"]
        if sweep_levels is None:
            wire_bytes += res.ledger.uplink_bytes
            losses = {"loss": float(res.trajectory[-1])}
            first = {"loss": float(res.trajectory[0])}
        else:
            wire_bytes += res.ledger[0].uplink_bytes  # identical across D
            traj = jnp.asarray(res.trajectory)
            losses = {f"loss_D{d}": float(traj[i, -1])
                      for i, d in enumerate(sweep_levels)}
            first = {f"loss_D{d}": float(traj[i, 0])
                     for i, d in enumerate(sweep_levels)}
        if done == 0:
            history.append({"step": 1, **first})
        done = end
        if done % args.log_every == 0 or done == args.steps:
            if history[-1]["step"] != done:
                history.append({"step": done, **losses})
            shown = "  ".join(f"{k} {v:.4f}" for k, v in losses.items())
            print(f"step {done:5d}  {shown}  ({(time.time()-t0)/done:.2f}s/step)")
        if args.ckpt_dir and args.ckpt_every and done % args.ckpt_every == 0:
            save(args.ckpt_dir, done, theta)
    final = {k: v for k, v in history[-1].items() if k != "step"}
    print(
        json.dumps(
            {
                "final_loss": (
                    final["loss"] if sweep_levels is None else final
                ),
                "uplink_bytes": wire_bytes,
                "history": history,
            }
        )
    )
    return history


if __name__ == "__main__":
    main()
