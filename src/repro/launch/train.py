"""Training launcher — end-to-end driver usable on CPU (reduced configs)
and, unchanged, on a real mesh (full configs).

Integrates the paper's §5 machinery as first-class training options:

* ``--staleness D``   — bounded-staleness delay-line (D=0 synchronous; D=1
  the paper's literal one-step-stale protocol);
* ``--compress-topk f`` — top-k sparsified gradient push with error
  feedback (the low-communication-overhead motif);
* gradient aggregation over the data axes is the Allreduce the paper
  simulates with its central server.

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 50 --batch 8 --seq 128 --log-every 10
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.configs import get_config
from repro.core.compression import ef_compress, ef_init, topk_compress
from repro.core.staleness import delay_init, delay_push_pop
from repro.data import synthetic_lm_batches
from repro.models import transformer as tf, whisper
from repro.optim import adam, clip_by_global_norm, warmup_cosine
from repro.optim.optimizers import apply_updates


def make_step(cfg, optimizer, *, staleness: int, compress: float):
    loss_fn = whisper.loss_fn if cfg.is_encoder_decoder else tf.loss_fn

    def step(state, batch):
        params, opt_state, delay, ef = state
        (l, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)
        wire = jnp.zeros(())
        if compress > 0:
            ef, comp = ef_compress(
                ef, grads, lambda t: topk_compress(t, compress)
            )
            grads = comp.tree
            wire = comp.wire_bytes
        if staleness > 0:
            delay, grads = delay_push_pop(delay, grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return (params, opt_state, delay, ef), dict(metrics, loss=l, wire=wire)

    return jax.jit(step, donate_argnums=(0,))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", help="CPU smoke variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--staleness", type=int, default=0)
    ap.add_argument("--compress-topk", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("use examples/whisper_train.py for enc-dec training")

    key = jax.random.key(args.seed)
    params = tf.init_params(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    optimizer = clip_by_global_norm(
        adam(warmup_cosine(args.lr, args.steps // 10 + 1, args.steps)), 1.0
    )
    opt_state = optimizer.init(params)
    delay = delay_init(params, args.staleness) if args.staleness > 0 else None
    ef = ef_init(params) if args.compress_topk > 0 else None
    step = make_step(
        cfg, optimizer, staleness=args.staleness, compress=args.compress_topk
    )

    data = synthetic_lm_batches(args.seed, args.batch, args.seq, cfg.vocab_size)
    state = (params, opt_state, delay, ef)
    print(
        f"training {cfg.name} ({n_params/1e6:.1f}M params, "
        f"staleness={args.staleness}, topk={args.compress_topk})"
    )
    t0 = time.time()
    history = []
    for i in range(args.steps):
        batch = next(data)
        state, metrics = step(state, batch)
        if (i + 1) % args.log_every == 0 or i == 0:
            l = float(metrics["loss"])
            history.append({"step": i + 1, "loss": l})
            print(
                f"step {i+1:5d}  loss {l:.4f}  "
                f"({(time.time()-t0)/(i+1):.2f}s/step)"
            )
        if args.ckpt_dir and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, i + 1, state[0])
    print(json.dumps({"final_loss": history[-1]["loss"], "history": history}))
    return history


if __name__ == "__main__":
    main()
