"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization and only then calls these.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests / examples."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_node_mesh(num_devices: int | None = None):
    """1-D ``("data",)`` mesh over the host's devices — the mesh executor's
    default placement for the paper's K logical nodes (K must be a multiple
    of the device count; each device hosts K/ndev nodes)."""
    n = num_devices if num_devices is not None else len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_multipod_mesh(num_pods: int | None = None, num_devices: int | None = None):
    """2-D ``("pod", "data")`` mesh over the host's devices — the multipod
    executor's default placement: the pod axis carries the expensive
    inter-pod tier, the data axis the cheap intra-pod reduction.  Defaults
    to 2 pods when the device count splits evenly, else 1 (every topology
    primitive degrades gracefully to a size-1 pod axis)."""
    n = num_devices if num_devices is not None else len(jax.devices())
    if num_pods is None:
        num_pods = 2 if n % 2 == 0 else 1
    if n % num_pods:
        raise ValueError(f"{n} devices do not split into {num_pods} pods")
    return jax.make_mesh((num_pods, n // num_pods), ("pod", "data"))


def batch_axes(mesh) -> tuple:
    """The axes that carry data parallelism (the paper's 'nodes')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def data_axis_size(mesh) -> int:
    s = 1
    for a in batch_axes(mesh):
        s *= mesh.shape[a]
    return s
