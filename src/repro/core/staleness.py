"""Stale-gradient training — the §5 algorithm mapped to TPU SPMD.

On a TPU pod there is no literal server; the paper's protocol becomes a
*bounded-staleness delay line* carried in the train state:

* the "push" is the data-parallel gradient (aggregated by ``psum`` — the
  server's record step);
* the "θ_{t-1} handoff" generalizes to applying the gradient that was pushed
  ``D`` steps ago (``D = 0`` → synchronous mini-batch GD, the paper's
  round-robin limit; ``D = 1`` → the paper's literal one-step-stale
  protocol; larger ``D`` models deeper pipelining / slower clients).

This keeps the whole thing one deterministic SPMD program — the functional
equivalent of asynchrony, preserving the convergence-relevant structure
(composition of local updates with bounded staleness) without wall-clock
nondeterminism.  See DESIGN.md §2.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class DelayLine(NamedTuple):
    """FIFO of the last ``D`` pushed gradients (leaves stacked on axis 0)."""

    buffer: PyTree  # each leaf: (D, *leaf_shape)
    step: jnp.ndarray


def delay_init(params: PyTree, depth: int) -> DelayLine:
    if depth < 1:
        raise ValueError("use depth >= 1; depth 0 means 'no delay line at all'")
    buf = jax.tree.map(
        lambda p: jnp.zeros((depth,) + p.shape, dtype=p.dtype), params
    )
    return DelayLine(buffer=buf, step=jnp.asarray(0, jnp.int32))


def delay_push_pop(state: DelayLine, grads: PyTree) -> tuple[DelayLine, PyTree]:
    """Push fresh ``grads``, pop the D-step-old gradient to apply.

    For the first D steps the popped gradient is the zero warm-up content of
    the buffer — matching an async cluster where the first replies have not
    yet arrived.
    """
    popped = jax.tree.map(lambda b: b[0], state.buffer)
    new_buf = jax.tree.map(
        lambda b, g: jnp.concatenate([b[1:], g[None]], axis=0),
        state.buffer,
        grads,
    )
    return DelayLine(buffer=new_buf, step=state.step + 1), popped


def delay_push_read(
    state: DelayLine, grads: PyTree, delay: jnp.ndarray
) -> tuple[DelayLine, PyTree]:
    """Dynamic-staleness variant of ``delay_push_pop``: push fresh ``grads``
    and read the value pushed ``delay`` steps ago, where ``delay`` may be a
    *traced* int32 in ``[0, D]`` (D = buffer depth).  ``delay == D``
    reproduces ``delay_push_pop`` on a depth-D buffer exactly; ``delay == 0``
    reads the fresh push (synchronous).  This is what lets a vmapped
    scenario sweep compile S different staleness levels into ONE executable:
    every scenario shares the depth-D_max buffer and differs only in the
    (batched) read index.  The read is a plain ``dynamic_index_in_dim``,
    so it batches (vmap) and shards (shard_map) freely — the composed
    ``mesh+sweep`` executor runs it inside the shard_map body with the
    buffer replicated and the index per scenario lane.
    """
    ext = jax.tree.map(
        lambda b, g: jnp.concatenate([b, g[None]], axis=0), state.buffer, grads
    )
    depth = jax.tree.leaves(state.buffer)[0].shape[0]
    idx = depth - delay  # delay=depth -> oldest slot; delay=0 -> the fresh push
    read = jax.tree.map(
        lambda e: jax.lax.dynamic_index_in_dim(e, idx, axis=0, keepdims=False),
        ext,
    )
    new_buf = jax.tree.map(lambda e: e[1:], ext)
    return DelayLine(buffer=new_buf, step=state.step + 1), read


class AsyncSGDState(NamedTuple):
    params: PyTree
    delay: DelayLine | None
    opt_state: Any


def make_stale_update(
    optimizer_update: Callable[[PyTree, Any, PyTree], tuple[PyTree, Any]],
    *,
    staleness: int = 0,
):
    """Wrap an optimizer-update fn with a staleness-D delay line.

    ``optimizer_update(grads, opt_state, params) -> (new_params, new_opt_state)``.

    Returns ``(init_fn, update_fn)`` where ``update_fn(state, grads)`` applies
    the (possibly stale) gradient.  With ``staleness == 0`` this is exactly
    the synchronous optimizer (paper's round-robin ≡ mini-batch GD limit).
    """

    def init_fn(params: PyTree, opt_state: Any) -> AsyncSGDState:
        delay = delay_init(params, staleness) if staleness > 0 else None
        return AsyncSGDState(params=params, delay=delay, opt_state=opt_state)

    def update_fn(state: AsyncSGDState, grads: PyTree) -> AsyncSGDState:
        if staleness > 0:
            delay, grads_applied = delay_push_pop(state.delay, grads)
        else:
            delay, grads_applied = None, grads
        new_params, new_opt = optimizer_update(
            grads_applied, state.opt_state, state.params
        )
        return AsyncSGDState(params=new_params, delay=delay, opt_state=new_opt)

    return init_fn, update_fn


def staleness_bound_lr(base_lr: float, staleness: int) -> float:
    """Heuristic staleness-compensated learning rate.

    The classic async-SGD analysis (and the paper's cited Downpour/[19]
    adaptive procedure) requires the step size to shrink with the maximum
    delay; ``lr / (1 + D)`` is the standard conservative choice.
    """
    return base_lr / (1.0 + float(staleness))
