"""Global-variable-consensus ADMM (Douglas-Rachford splitting) — paper §3.1/§3.2.

The paper repeatedly reduces distributed learning to the consensus problem

    minimize  Σ_k f_k(θ^(k)) + g(z)    s.t.  θ^(k) = z  for all k,

solved by ADMM ("Application of the Douglas-Rachford splitting (also known as
ADMM) to this optimization problem leads to a three stage algorithm with
several proximity functions carried in parallel at each node and two
Allreduce functions").  This module is the shared engine used by
``ml/linear.py`` (LASSO / ridge regression) and ``ml/svm.py`` (consensus SVM).

Scaled-dual form, one iteration:

    θ^(k) ← argmin_θ  f_k(θ) + (ρ/2)‖θ − z + u^(k)‖²      (parallel at nodes)
    z     ← prox_{g/(Kρ)}( mean_k(θ^(k) + u^(k)) )         (Allreduce #1)
    u^(k) ← u^(k) + θ^(k) − z                              (local)

The z-update's mean is the Allreduce; primal/dual residual norms (used for
the stopping rule) are the paper's second Allreduce.  Local θ-updates are
either a user-supplied closed form / prox, or an inner gradient loop.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# Proximal operators for the global regularizer g
# ----------------------------------------------------------------------------

def prox_l1(v: jnp.ndarray, lam: float) -> jnp.ndarray:
    """Soft threshold — g(z) = lam * ||z||_1 (LASSO)."""
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - lam, 0.0)


def prox_l2sq(v: jnp.ndarray, lam: float) -> jnp.ndarray:
    """g(z) = (lam/2) * ||z||_2^2 (ridge)."""
    return v / (1.0 + lam)


def prox_none(v: jnp.ndarray, lam: float) -> jnp.ndarray:
    return v


PROX = {"l1": prox_l1, "l2sq": prox_l2sq, "none": prox_none}


class ADMMState(NamedTuple):
    theta: jnp.ndarray  # (K, n) per-node primal variables
    z: jnp.ndarray  # (n,) global consensus variable
    u: jnp.ndarray  # (K, n) scaled duals
    primal_res: jnp.ndarray  # scalar ‖θ − z‖
    dual_res: jnp.ndarray  # scalar ρ‖z − z_prev‖
    it: jnp.ndarray


class ADMMResult(NamedTuple):
    z: jnp.ndarray
    state: ADMMState
    history: jnp.ndarray  # (iters, 2) primal/dual residuals


def consensus_admm(
    local_prox: Callable[[jnp.ndarray, jnp.ndarray, float], jnp.ndarray],
    num_nodes: int,
    dim: int,
    *,
    rho: float = 1.0,
    g: str = "none",
    g_lam: float = 0.0,
    iters: int = 100,
    theta0: jnp.ndarray | None = None,
) -> ADMMResult:
    """Run consensus ADMM.

    Args:
      local_prox: ``(k_index_onehot_free) (v, k, rho) -> argmin_θ f_k(θ) +
        (rho/2)||θ - v||²`` evaluated for all nodes at once: it receives the
        full ``(K, n)`` matrix ``v`` and must return the ``(K, n)`` matrix of
        per-node minimizers (vectorize with ``jax.vmap`` over node data).
      num_nodes: K.
      dim: n.
      g: global regularizer — "l1", "l2sq" or "none".
      g_lam: its weight λ.
      iters: fixed iteration count (lax.scan body; residuals recorded).
    """
    prox_g = PROX[g]
    K = num_nodes

    theta = jnp.zeros((K, dim)) if theta0 is None else theta0
    state0 = ADMMState(
        theta=theta,
        z=jnp.zeros((dim,)),
        u=jnp.zeros((K, dim)),
        primal_res=jnp.asarray(jnp.inf),
        dual_res=jnp.asarray(jnp.inf),
        it=jnp.asarray(0),
    )

    def step(state: ADMMState, _):
        # -- stage 1: parallel local prox at every node
        v = state.z[None, :] - state.u  # (K, n)
        theta = local_prox(v, state.u, rho)
        # -- stage 2: Allreduce #1 — averaged consensus + global prox
        avg = jnp.mean(theta + state.u, axis=0)
        z_new = prox_g(avg, g_lam / (K * rho))
        # -- stage 3: dual ascent
        u = state.u + theta - z_new[None, :]
        # -- Allreduce #2 — residual norms for the stopping diagnostic
        primal = jnp.linalg.norm(theta - z_new[None, :])
        dual = rho * jnp.sqrt(K) * jnp.linalg.norm(z_new - state.z)
        new_state = ADMMState(theta, z_new, u, primal, dual, state.it + 1)
        return new_state, jnp.stack([primal, dual])

    final, hist = jax.lax.scan(step, state0, None, length=iters)
    return ADMMResult(z=final.z, state=final, history=hist)


def gradient_local_prox(
    grad_f: Callable[[jnp.ndarray], jnp.ndarray],
    *,
    inner_iters: int = 25,
    lr: float = 0.1,
) -> Callable:
    """Build a ``local_prox`` from per-node loss gradients.

    ``grad_f(theta)``: (K, n) -> (K, n), the gradient of each node's local
    objective f_k at its own θ row.  The prox subproblem
    ``argmin f_k(θ) + (ρ/2)||θ − v||²`` is solved with ``inner_iters`` steps
    of gradient descent — the "several proximity functions carried in
    parallel at each node" of the paper.
    """

    def local_prox(v: jnp.ndarray, u: jnp.ndarray, rho: float) -> jnp.ndarray:
        def inner(theta, _):
            g = grad_f(theta) + rho * (theta - v)
            return theta - lr * g, None

        theta, _ = jax.lax.scan(inner, v, None, length=inner_iters)
        return theta

    return local_prox
