"""Reduction topologies — WHICH LINK a message crosses, and what it costs.

The paper's cost model (§3, §5) does not price communication by byte
alone: the client↔server round trip is the expensive tier and the
intra-cluster reduction the cheap one.  A ``Topology`` makes that
distinction first-class: it is an ordered list of ``Hop``s, each naming
the mesh axes reduced at that stage (innermost first), a tier name for
the ledger, and a per-byte price.  ``core.allreduce.hierarchical_allreduce``
executes the hops as staged ``psum``s; ``CommLedger`` decomposes its byte
totals by tier through ``Topology.hop_messages``.

Two canonical instances:

* ``Topology.flat(axes)`` — one hop over every node axis at once: the
  classical undifferentiated client-server accounting (today's behavior).
* ``Topology.from_mesh(axes)`` — ``pod`` split out as its own outermost
  ``inter_pod`` hop, everything else reduced first as ``intra_pod`` —
  the hierarchical aggregation (intra-pod psum, then inter-pod
  allreduce) that Verbraeken et al. and Gu et al. identify as the
  scaling mechanism for the client-server architecture.

The byte decomposition telescopes so tiers always sum to the flat total:
with K node messages and g_h aggregation groups remaining after hop h
(g_0 = K), hop h carries g_{h-1} − g_h messages (every participant except
the group roots), and the outermost hop carries all g_{H-1} root pushes
to the server.  Σ_h m_h = K — exactly the flat uplink count.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Mapping

#: default per-byte prices by tier: the inter-pod (client↔server) link is
#: priced an order of magnitude above the intra-pod reduction, the
#: paper's expensive-vs-cheap tier split (override per ``Hop``).
DEFAULT_PRICES = {"flat": 1.0, "intra_pod": 1.0, "inter_pod": 10.0}


@dataclass(frozen=True)
class Hop:
    """One reduction stage: a joint psum over ``axes``, priced per byte."""

    axes: tuple  # mesh axis name(s) reduced together at this stage
    name: str  # ledger tier ("flat" / "intra_pod" / "inter_pod" / ...)
    price_per_byte: float = 1.0

    def __post_init__(self):
        axes = (self.axes,) if isinstance(self.axes, str) else tuple(self.axes)
        object.__setattr__(self, "axes", axes)

    def size(self, axis_sizes: Mapping[str, int]) -> int:
        s = 1
        for a in self.axes:
            s *= int(axis_sizes[a])
        return s


@dataclass(frozen=True)
class Topology:
    """Ordered reduction hops, innermost (cheapest) first."""

    hops: tuple

    def __post_init__(self):
        object.__setattr__(self, "hops", tuple(self.hops))
        if not self.hops:
            raise ValueError("a Topology needs at least one hop")
        seen = set()
        for hop in self.hops:
            for a in hop.axes:
                if a in seen:
                    raise ValueError(f"axis {a!r} appears in more than one hop")
                seen.add(a)

    @property
    def axes(self) -> tuple:
        """All mesh axes the topology reduces over, hop order."""
        return tuple(a for hop in self.hops for a in hop.axes)

    @property
    def tiers(self) -> tuple:
        return tuple(h.name for h in self.hops)

    # -- construction --------------------------------------------------------

    @staticmethod
    def flat(axes, *, name: str = "flat", price_per_byte: float | None = None):
        """One undifferentiated hop over every node axis — the classical
        single-tier client-server accounting."""
        price = DEFAULT_PRICES.get(name, 1.0) if price_per_byte is None else price_per_byte
        return Topology((Hop(axes=axes, name=name, price_per_byte=price),))

    @staticmethod
    def from_mesh(
        axes,
        *,
        pod_axis: str = "pod",
        intra_price: float | None = None,
        inter_price: float | None = None,
    ):
        """Split ``pod_axis`` out as the outermost ``inter_pod`` hop; the
        remaining node axes reduce first as one ``intra_pod`` hop.  A mesh
        without a pod axis degrades to the single-hop flat topology (so
        existing 1-D node meshes keep bit-exact behavior)."""
        axes = (axes,) if isinstance(axes, str) else tuple(axes)
        intra = tuple(a for a in axes if a != pod_axis)
        if pod_axis not in axes:
            # single-tier mesh: the whole reduction is the "intra" link
            return Topology.flat(intra, price_per_byte=intra_price)
        intra_p = DEFAULT_PRICES["intra_pod"] if intra_price is None else intra_price
        inter_p = DEFAULT_PRICES["inter_pod"] if inter_price is None else inter_price
        hops = []
        if intra:
            hops.append(Hop(axes=intra, name="intra_pod", price_per_byte=intra_p))
        hops.append(Hop(axes=(pod_axis,), name="inter_pod", price_per_byte=inter_p))
        return Topology(tuple(hops))

    # -- calibration ---------------------------------------------------------

    @staticmethod
    def calibrated(mesh, *, pod_axis: str = "pod"):
        """``from_mesh`` with prices measured on ``mesh`` by
        ``calibrate_prices`` instead of the ×1/×10 defaults."""
        prices = calibrate_prices(mesh, pod_axis=pod_axis)
        return Topology.from_mesh(
            tuple(mesh.axis_names),
            pod_axis=pod_axis,
            intra_price=prices["intra_pod"],
            inter_price=prices["inter_pod"],
        )

    # -- ledger decomposition ------------------------------------------------

    def hop_messages(self, num_nodes: int, axis_sizes: Mapping[str, int]):
        """Decompose K per-round node messages across tiers.

        Returns ordered ``[(tier, messages, price_per_byte), ...]`` with
        messages summing exactly to ``num_nodes``: hop h carries
        ``g_{h-1} − g_h`` messages (g_h = aggregation groups remaining
        after hop h; g_0 = K) and the outermost hop carries all
        ``g_{H-1}`` group-root pushes to the server.
        """
        sizes = [h.size(axis_sizes) for h in self.hops]
        # groups remaining after hop h = product of the outer hop sizes
        groups = []
        g = 1
        for s in reversed(sizes[1:]):
            g *= s
            groups.append(g)
        groups = list(reversed(groups)) + [0]  # g_H unused; sentinel
        out = []
        g_prev = int(num_nodes)
        for i, hop in enumerate(self.hops):
            if i == len(self.hops) - 1:
                m = g_prev  # every top-level group root pushes to the server
            else:
                g_next = groups[i]
                if g_prev % g_next:
                    raise ValueError(
                        f"{num_nodes} nodes do not divide into {g_next} "
                        f"groups at hop {hop.name!r}"
                    )
                m = g_prev - g_next
                g_prev = g_next
            out.append((hop.name, m, hop.price_per_byte))
        return out


# -- price calibration -------------------------------------------------------

#: memoized calibration results per (device set, pod split, sample size):
#: the microbenchmark is a one-shot property of the host, not of any fit
_CALIBRATION_CACHE: dict = {}


def calibrate_prices(
    mesh,
    *,
    pod_axis: str = "pod",
    sample_kib: int = 256,
    repeats: int = 5,
    cache: bool = True,
) -> dict:
    """One-shot per-hop bandwidth microbenchmark on the actual ``mesh``.

    Times a jitted psum over the intra-pod axes and one over the pod
    axis (best of ``repeats`` over a ``sample_kib`` f32 payload),
    normalizes so the intra tier costs 1.0 per byte, and returns a price
    mapping shaped like ``DEFAULT_PRICES``::

        {"flat": 1.0, "intra_pod": 1.0, "inter_pod": <measured ratio>,
         "seconds": {...}, "sample_bytes": ..., "calibrated": True}

    Feed the prices into ``Topology.from_mesh(intra_price=...,
    inter_price=...)`` (or use ``Topology.calibrated``) so
    ``CommLedger.priced_cost()`` reflects the host that actually ran,
    not the ×1/×10 guess.  Results are memoized per device set — the
    measurement is a property of the machine, so every fit on the same
    mesh shares one calibration.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)
    key = (
        tuple(str(d) for d in mesh.devices.flatten()),
        axes,
        pod_axis,
        int(sample_kib),
    )
    if cache and key in _CALIBRATION_CACHE:
        return dict(_CALIBRATION_CACHE[key])

    n = max((int(sample_kib) * 1024) // 4, 128)
    x = jnp.zeros((n,), jnp.float32)

    def _timed(hop_axes) -> float | None:
        if not hop_axes:
            return None
        fn = jax.jit(
            shard_map(
                lambda v: jax.lax.psum(v, hop_axes),
                mesh=mesh,
                in_specs=P(),
                out_specs=P(),
                check_rep=False,
            )
        )
        jax.block_until_ready(fn(x))  # compile outside the timed region
        best = None
        for _ in range(max(int(repeats), 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(x))
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        return best

    intra = tuple(a for a in axes if a != pod_axis)
    t_intra = _timed(intra)
    t_inter = _timed((pod_axis,) if pod_axis in axes else ())

    if t_intra and t_inter:
        ratio = max(t_inter / t_intra, 1e-3)
    else:
        ratio = DEFAULT_PRICES["inter_pod"] if t_inter else 1.0
    out = {
        "flat": 1.0,
        "intra_pod": 1.0,
        "inter_pod": float(ratio),
        "seconds": {"intra_pod": t_intra, "inter_pod": t_inter},
        "sample_bytes": n * 4,
        "calibrated": True,
    }
    _CALIBRATION_CACHE[key] = dict(out)
    return out
