"""Allreduce primitives + byte-accurate communication accounting.

The paper (§3.1) observes that the MPI ``Allreduce`` used by [47] and [5]
"can be simulated by a two step communication with a central server, first
each node sends to the server the current local estimate θ^(k) and then all
of the nodes receive back from the server the optimal global parameter θ".

On TPU we invert the observation: ``jax.lax.psum`` over mesh axes *is* the
central server in its exact-aggregation limit.  Both forms are provided:

* ``psum_allreduce`` — native collective, for use inside ``shard_map``.
* ``server_allreduce`` — the literal two-phase simulation over a stacked
  node axis (gather-to-server + broadcast), used by the classical ``ml/``
  algorithms which model K logical nodes on one host.

``CommLedger`` counts bytes moved under the paper's client-server cost model
(uplink: K·|θ| to the server, downlink: K·|θ| back), so every surveyed
algorithm can report its communication overhead — the paper's recurring
evaluation axis.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_bytes

PyTree = Any


def psum_allreduce(tree: PyTree, axis_name: str | tuple[str, ...]) -> PyTree:
    """Native TPU allreduce over one or more mesh axes (inside shard_map/pjit)."""
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), tree)


def pmean_allreduce(tree: PyTree, axis_name: str | tuple[str, ...]) -> PyTree:
    return jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), tree)


def mesh_allreduce(
    tree: PyTree, axis_name: str | tuple[str, ...], op: str = "sum"
) -> PyTree:
    """Native collective with the same ``op`` vocabulary as
    ``server_allreduce`` — the §3.1 equivalence made literal: the mesh
    executor swaps one for the other without touching the algorithm."""
    if op == "sum":
        return psum_allreduce(tree, axis_name)
    if op == "mean":
        return pmean_allreduce(tree, axis_name)
    if op == "max":
        return jax.tree.map(lambda x: jax.lax.pmax(x, axis_name), tree)
    raise ValueError(f"unknown op: {op!r}")


def server_allreduce(stacked: PyTree, op: str = "sum") -> PyTree:
    """Two-phase central-server Allreduce over a leading node axis.

    ``stacked`` holds each node's local estimate along axis 0 (K nodes).
    Phase 1 (push): the server receives all K estimates — modeled by the
    stacked layout itself.  Phase 2 (aggregate + broadcast): the server
    reduces and every node receives the same global value.  Returns the
    aggregated tree (one copy; broadcasting back is a no-op on one host).
    """
    if op == "sum":
        return jax.tree.map(lambda x: jnp.sum(x, axis=0), stacked)
    if op == "mean":
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), stacked)
    if op == "max":
        return jax.tree.map(lambda x: jnp.max(x, axis=0), stacked)
    raise ValueError(f"unknown op: {op!r}")


@dataclass
class CommLedger:
    """Byte accounting under the paper's strict client-server cost model."""

    uplink_bytes: int = 0
    downlink_bytes: int = 0
    rounds: int = 0
    events: list = field(default_factory=list)

    def record_allreduce(self, tree: PyTree, num_nodes: int, tag: str = "") -> None:
        """One Allreduce = K pushes of |θ| + K pulls of |θ|."""
        nbytes = tree_bytes(tree)
        self.uplink_bytes += num_nodes * nbytes
        self.downlink_bytes += num_nodes * nbytes
        self.rounds += 1
        self.events.append(("allreduce", tag, num_nodes * nbytes * 2))

    def record_push(self, tree: PyTree, tag: str = "") -> None:
        """One node→server push (the §5 protocol is push+pull per contact)."""
        nbytes = tree_bytes(tree)
        self.uplink_bytes += nbytes
        self.events.append(("push", tag, nbytes))

    def record_pull(self, tree: PyTree, tag: str = "") -> None:
        nbytes = tree_bytes(tree)
        self.downlink_bytes += nbytes
        self.events.append(("pull", tag, nbytes))

    def record_inference(self, request: PyTree, response: PyTree, tag: str = "") -> None:
        """One served batch under the same client-server cost model as
        training: the clients upload their request features and download
        the predictions — the deployment half of the paper's traffic."""
        up = tree_bytes(request)
        down = tree_bytes(response)
        self.uplink_bytes += up
        self.downlink_bytes += down
        self.events.append(("inference", tag, up + down))

    def merge(self, other: "CommLedger") -> None:
        """Fold another ledger's accounting into this one."""
        self.uplink_bytes += other.uplink_bytes
        self.downlink_bytes += other.downlink_bytes
        self.rounds += other.rounds
        self.events.extend(other.events)

    @property
    def total_bytes(self) -> int:
        return self.uplink_bytes + self.downlink_bytes

    def summary(self) -> dict:
        return {
            "uplink_bytes": self.uplink_bytes,
            "downlink_bytes": self.downlink_bytes,
            "total_bytes": self.total_bytes,
            "rounds": self.rounds,
        }
