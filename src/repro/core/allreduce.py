"""Allreduce primitives + byte-accurate communication accounting.

The paper (§3.1) observes that the MPI ``Allreduce`` used by [47] and [5]
"can be simulated by a two step communication with a central server, first
each node sends to the server the current local estimate θ^(k) and then all
of the nodes receive back from the server the optimal global parameter θ".

On TPU we invert the observation: ``jax.lax.psum`` over mesh axes *is* the
central server in its exact-aggregation limit.  Both forms are provided:

* ``psum_allreduce`` — native collective, for use inside ``shard_map``.
* ``server_allreduce`` — the literal two-phase simulation over a stacked
  node axis (gather-to-server + broadcast), used by the classical ``ml/``
  algorithms which model K logical nodes on one host.
* ``hierarchical_allreduce`` — the topology-aware generalization: staged
  psum per reduction hop (intra-pod first, inter-pod last), following an
  ordered ``core.topology`` hop list.  A flat single-hop topology IS
  ``mesh_allreduce``.

``CommLedger`` counts bytes moved under the paper's client-server cost model
(uplink: K·|θ| to the server, downlink: K·|θ| back), so every surveyed
algorithm can report its communication overhead — the paper's recurring
evaluation axis.  Under a hierarchical topology the same totals decompose
by tier (``record_hop`` / ``attribute_hops``): which LINK a byte crossed
— the cheap intra-pod reduction or the expensive inter-pod round trip —
is the paper's §3/§5 pricing distinction.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_bytes

PyTree = Any


def psum_allreduce(tree: PyTree, axis_name: str | tuple[str, ...]) -> PyTree:
    """Native TPU allreduce over one or more mesh axes (inside shard_map/pjit)."""
    return jax.tree.map(lambda x: jax.lax.psum(x, axis_name), tree)


def pmean_allreduce(tree: PyTree, axis_name: str | tuple[str, ...]) -> PyTree:
    return jax.tree.map(lambda x: jax.lax.pmean(x, axis_name), tree)


def mesh_allreduce(
    tree: PyTree, axis_name: str | tuple[str, ...], op: str = "sum"
) -> PyTree:
    """Native collective with the same ``op`` vocabulary as
    ``server_allreduce`` — the §3.1 equivalence made literal: the mesh
    executor swaps one for the other without touching the algorithm.
    ``op="any"`` is the semantic union reduction (cascade SVM's SV-mask
    union), expressed as psum-of-bools so it runs as a native collective.
    """
    if op == "sum":
        return psum_allreduce(tree, axis_name)
    if op == "mean":
        return pmean_allreduce(tree, axis_name)
    if op == "max":
        return jax.tree.map(lambda x: jax.lax.pmax(x, axis_name), tree)
    if op == "any":
        return jax.tree.map(
            lambda x: jax.lax.psum(x.astype(jnp.int32), axis_name) > 0, tree
        )
    raise ValueError(f"unknown op: {op!r}")


def _hop_names(axes) -> tuple:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def hierarchical_allreduce(
    tree: PyTree,
    hops,
    op: str = "sum",
    *,
    reduce_scatter: bool = False,
    axis_sizes=None,
) -> PyTree:
    """Topology-aware allreduce: one staged collective per reduction hop.

    ``hops`` is an ordered sequence (innermost/cheapest first) of
    ``core.topology.Hop``s — or bare axis names / axis-name tuples — each
    reduced with its own ``psum``/``pmean``/``pmax``.  A single flat hop
    over all node axes is exactly ``mesh_allreduce``; splitting the pod
    axis into its own outermost hop is the paper's intra-pod-psum +
    inter-pod-allreduce hierarchy.

    ``op="mean"`` stages as psum-per-hop with ONE final division by the
    total fan-in, so the result is independent of how the hops split the
    axes (a staged pmean-of-pmeans would re-weight tiers).

    ``reduce_scatter=True`` restages the innermost hop as
    reduce-scatter → inter-hop reduce → all-gather
    (``psum_scatter`` + ``psum`` + ``all_gather``): each device reduces
    1/K of every leaf through the outer hops instead of the whole tree.
    Per-element this performs the exact same additions in the exact same
    order as the staged psum, so the result stays bit-identical (the
    PR-4 equivalence suite covers it).  Leaves whose leading dimension
    does not tile across the innermost hop fall back to plain staged
    psum per leaf; ``axis_sizes`` (mesh axis name → size) is required to
    decide eligibility statically, so without it the staging is skipped.
    """
    axes_per_hop = [getattr(h, "axes", h) for h in hops]

    scatter_n = None
    if reduce_scatter and op in ("sum", "mean") and axis_sizes is not None:
        n = 1
        for a in _hop_names(axes_per_hop[0]):
            n *= int(axis_sizes[a])
        if n > 1:
            scatter_n = n

    def _staged_sum_leaf(x):
        if (
            scatter_n is not None
            and x.ndim >= 1
            and x.shape[0] >= scatter_n
            and x.shape[0] % scatter_n == 0
        ):
            y = jax.lax.psum_scatter(
                x, axes_per_hop[0], scatter_dimension=0, tiled=True
            )
            for axes in axes_per_hop[1:]:
                y = jax.lax.psum(y, axes)
            return jax.lax.all_gather(
                y, axes_per_hop[0], axis=0, tiled=True
            )
        for axes in axes_per_hop:
            x = jax.lax.psum(x, axes)
        return x

    if op in ("sum", "mean"):
        tree = jax.tree.map(_staged_sum_leaf, tree)
        if op == "mean":
            denom = 1.0
            # divide once by the joint fan-in; axis sizes are trace-time static
            for axes in axes_per_hop:
                for a in _hop_names(axes):
                    denom *= jax.lax.psum(1, a)
            tree = jax.tree.map(lambda x: x / denom, tree)
        return tree
    for axes in axes_per_hop:
        tree = mesh_allreduce(tree, axes, op=op)
    return tree


def partial_allreduce(tree: PyTree, hops) -> PyTree:
    """The synchronous front of an overlapped hierarchical sum: every hop
    EXCEPT the outermost (for a flat single-hop topology: no hop at all —
    the whole reduction is deferred).  ``complete_allreduce`` over the
    outermost hop finishes the job; the two compose to exactly the same
    additions, in the same order, as ``hierarchical_allreduce(op="sum")``.
    """
    for axes in [getattr(h, "axes", h) for h in hops[:-1]]:
        tree = psum_allreduce(tree, axes)
    return tree


def complete_allreduce(tree: PyTree, hops) -> PyTree:
    """The deferred back half of an overlapped hierarchical sum: the
    outermost (most expensive) hop only.  Dataflow-independent of the
    current round's local compute, so XLA can schedule the collective
    against it — the comm/compute overlap."""
    outer = getattr(hops[-1], "axes", hops[-1])
    return psum_allreduce(tree, outer)


def server_allreduce(stacked: PyTree, op: str = "sum") -> PyTree:
    """Two-phase central-server Allreduce over a leading node axis.

    ``stacked`` holds each node's local estimate along axis 0 (K nodes).
    Phase 1 (push): the server receives all K estimates — modeled by the
    stacked layout itself.  Phase 2 (aggregate + broadcast): the server
    reduces and every node receives the same global value.  Returns the
    aggregated tree (one copy; broadcasting back is a no-op on one host).
    """
    if op == "sum":
        return jax.tree.map(lambda x: jnp.sum(x, axis=0), stacked)
    if op == "mean":
        return jax.tree.map(lambda x: jnp.mean(x, axis=0), stacked)
    if op == "max":
        return jax.tree.map(lambda x: jnp.max(x, axis=0), stacked)
    if op == "any":
        return jax.tree.map(lambda x: jnp.any(x, axis=0), stacked)
    raise ValueError(f"unknown op: {op!r}")


@dataclass
class CommLedger:
    """Byte accounting under the paper's strict client-server cost model.

    Totals optionally decompose by reduction tier (``hops``): which link a
    byte crossed — the cheap intra-pod reduction or the expensive
    inter-pod round trip — priced per byte per hop.  Tier bytes always
    sum to the undifferentiated flat totals (the decomposition is an
    attribution, never double counting).
    """

    uplink_bytes: int = 0
    downlink_bytes: int = 0
    rounds: int = 0
    events: list = field(default_factory=list)
    #: per-tier attribution: name -> {uplink_bytes, downlink_bytes,
    #: price_per_byte}; empty for flat (single-tier) accounting
    hops: dict = field(default_factory=dict)

    def record_allreduce(self, tree: PyTree, num_nodes: int, tag: str = "") -> None:
        """One Allreduce = K pushes of |θ| + K pulls of |θ|."""
        nbytes = tree_bytes(tree)
        self.uplink_bytes += num_nodes * nbytes
        self.downlink_bytes += num_nodes * nbytes
        self.rounds += 1
        self.events.append(("allreduce", tag, num_nodes * nbytes * 2))

    def _hop_add(
        self, hop: str, up: int, down: int, price_per_byte: float = 1.0
    ) -> None:
        # cost accumulates per contribution, so merging ledgers priced
        # under different link prices stays exact (the summary reports
        # the byte-weighted effective price)
        bucket = self.hops.setdefault(
            hop, {"uplink_bytes": 0, "downlink_bytes": 0, "priced_cost": 0.0}
        )
        bucket["uplink_bytes"] += up
        bucket["downlink_bytes"] += down
        bucket["priced_cost"] += (up + down) * price_per_byte

    def record_hop(
        self,
        tree: PyTree,
        hop: str,
        fanin: int,
        *,
        price_per_byte: float = 1.0,
        tag: str = "",
    ) -> None:
        """One reduction stage of a hierarchical Allreduce: ``fanin``
        messages of |tree| climb the tier (uplink) and ``fanin`` copies
        come back down — charged to the hop's own bucket AND the global
        totals, so a fully hop-recorded ledger decomposes exactly."""
        nbytes = tree_bytes(tree) * fanin
        self.uplink_bytes += nbytes
        self.downlink_bytes += nbytes
        self._hop_add(hop, nbytes, nbytes, price_per_byte)
        self.events.append(("hop", tag or hop, nbytes * 2))

    def attribute_hops(self, hop_messages) -> None:
        """Decompose the ledger's CURRENT totals across tiers.

        ``hop_messages`` is ``[(tier, messages, price_per_byte), ...]``
        (see ``core.topology.Topology.hop_messages``); each tier is
        attributed its message-weighted share, with any integer remainder
        assigned to the outermost hop so tier bytes sum bit-for-bit to
        the flat totals.
        """
        total_m = sum(m for _, m, _ in hop_messages)
        if total_m <= 0:
            # a zero-message decomposition is legal exactly when there is
            # nothing to attribute — a fault-plan scenario can drop every
            # participant of every round (dropout_p=1.0), leaving a valid
            # all-zero ledger; the tier buckets still materialize (zeroed)
            # so summaries keep a stable shape across scenarios
            if self.uplink_bytes or self.downlink_bytes:
                raise ValueError(
                    "hop attribution needs a positive message count "
                    f"({self.uplink_bytes}B up / {self.downlink_bytes}B down "
                    "unattributed)"
                )
            for name, _, price in hop_messages:
                self._hop_add(name, 0, 0, price)
            return
        up_rem, down_rem = self.uplink_bytes, self.downlink_bytes
        for i, (name, m, price) in enumerate(hop_messages):
            if i == len(hop_messages) - 1:
                up_h, down_h = up_rem, down_rem
            else:
                up_h = self.uplink_bytes * m // total_m
                down_h = self.downlink_bytes * m // total_m
                up_rem -= up_h
                down_rem -= down_h
            self._hop_add(name, up_h, down_h, price)

    def record_push(self, tree: PyTree, tag: str = "") -> None:
        """One node→server push (the §5 protocol is push+pull per contact)."""
        nbytes = tree_bytes(tree)
        self.uplink_bytes += nbytes
        self.events.append(("push", tag, nbytes))

    def record_pull(self, tree: PyTree, tag: str = "") -> None:
        nbytes = tree_bytes(tree)
        self.downlink_bytes += nbytes
        self.events.append(("pull", tag, nbytes))

    def record_inference(self, request: PyTree, response: PyTree, tag: str = "") -> None:
        """One served batch under the same client-server cost model as
        training: the clients upload their request features and download
        the predictions — the deployment half of the paper's traffic."""
        up = tree_bytes(request)
        down = tree_bytes(response)
        self.uplink_bytes += up
        self.downlink_bytes += down
        self.events.append(("inference", tag, up + down))

    def merge(self, other: "CommLedger") -> None:
        """Fold another ledger's accounting into this one."""
        self.uplink_bytes += other.uplink_bytes
        self.downlink_bytes += other.downlink_bytes
        self.rounds += other.rounds
        self.events.extend(other.events)
        for name, b in other.hops.items():
            bucket = self.hops.setdefault(
                name,
                {"uplink_bytes": 0, "downlink_bytes": 0, "priced_cost": 0.0},
            )
            bucket["uplink_bytes"] += b["uplink_bytes"]
            bucket["downlink_bytes"] += b["downlink_bytes"]
            bucket["priced_cost"] += b["priced_cost"]

    @property
    def total_bytes(self) -> int:
        return self.uplink_bytes + self.downlink_bytes

    def priced_cost(self) -> float:
        """Byte total weighted by per-hop link prices; bytes not
        attributed to any tier are priced at 1.0 (the flat model)."""
        attributed = 0
        cost = 0.0
        for b in self.hops.values():
            attributed += b["uplink_bytes"] + b["downlink_bytes"]
            cost += b["priced_cost"]
        return cost + (self.total_bytes - attributed)

    def summary(self) -> dict:
        def hop_entry(b):
            nbytes = b["uplink_bytes"] + b["downlink_bytes"]
            return {
                "uplink_bytes": b["uplink_bytes"],
                "downlink_bytes": b["downlink_bytes"],
                "total_bytes": nbytes,
                # byte-weighted effective price (exact when every
                # contribution priced this hop identically)
                "price_per_byte": b["priced_cost"] / nbytes if nbytes else 1.0,
            }

        by_hop = {name: hop_entry(b) for name, b in self.hops.items()}
        return {
            "uplink_bytes": self.uplink_bytes,
            "downlink_bytes": self.downlink_bytes,
            "total_bytes": self.total_bytes,
            "rounds": self.rounds,
            "by_hop": by_hop,
            "priced_cost": self.priced_cost(),
        }
