"""The paper's primary contribution (Ionescu 2015, §5 + §3.1 machinery):

* ``server``      — central information server with θ_{t-1} handoff
* ``schedules``   — round-robin / asynchronous contact schedules
* ``staleness``   — the §5 algorithm as a TPU-native bounded-staleness trainer
* ``admm``        — global-variable-consensus ADMM (Douglas-Rachford)
* ``allreduce``   — server-simulated + native allreduce, comm accounting
* ``compression`` — low-communication-overhead push (top-k / rand-k / int8 / EF)
"""

from repro.core import admm, allreduce, compression, schedules, server, staleness
from repro.core.server import ServerState, contact, init_server, pull, run_protocol
from repro.core.schedules import asynchronous, round_robin, work_proportional_probs
from repro.core.staleness import (
    AsyncSGDState,
    DelayLine,
    delay_init,
    delay_push_pop,
    make_stale_update,
    staleness_bound_lr,
)

__all__ = [
    "admm",
    "allreduce",
    "compression",
    "schedules",
    "server",
    "staleness",
    "ServerState",
    "contact",
    "init_server",
    "pull",
    "run_protocol",
    "asynchronous",
    "round_robin",
    "work_proportional_probs",
    "AsyncSGDState",
    "DelayLine",
    "delay_init",
    "delay_push_pop",
    "make_stale_update",
    "staleness_bound_lr",
]
