"""Low-communication-overhead push path (paper §1 motif, §5 [37]).

The paper's driving constraint for mobile/healthcare clients is "low
communication overhead"; its §5 cites Li et al.'s parameter server [37]
whose key mechanism is *filtering* the pushed updates.  This module
implements the standard update-compression family on arbitrary parameter
pytrees:

* ``topk``     — keep the k largest-magnitude entries per leaf (sparse push);
* ``randk``    — keep k uniformly random entries (unbiased when rescaled);
* ``int8``     — per-leaf symmetric linear quantization;
* error feedback — the residual of what was not transmitted is carried
  locally and added to the next update, preserving convergence (the EF-SGD
  construction).

Compressed representations stay dense-with-zeros on device (TPU-friendly);
``compressed_bytes`` reports what would cross the wire (indices + values for
sparse, 1 byte/entry + scale for int8), which is what the benchmarks and the
``CommLedger`` charge.

The per-leaf top-k selection is the compute hot spot and has a Pallas TPU
kernel (``repro.kernels.topk_compress``); this module uses the pure-jnp
reference path by default and the kernel when ``use_kernel=True``.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any

#: leaves below this element count skip the Pallas kernels — the pad to a
#: full (8, 1024) tile would dwarf the leaf.  ``kernel_plan`` reports the
#: split so the wire layer can surface which path actually ran.
_KERNEL_MIN_SIZE = 256


def _kernel_eligible(x, *, min_size: int = _KERNEL_MIN_SIZE) -> bool:
    """Kernel path gate: big enough to amortize tile padding, and f32 —
    the fused kernels carry thresholds/scales in f32 SMEM, so only f32
    leaves are bit-equal to the reference."""
    return x.size >= min_size and x.dtype == jnp.float32


def kernel_plan(tree: PyTree, *, min_size: int = _KERNEL_MIN_SIZE) -> dict:
    """Which leaves would take the Pallas kernel path vs the jnp reference
    fallback (the <``min_size``/non-f32 gate), so benchmarks and
    ``FitResult.metrics`` can record what actually ran instead of silently
    falling back."""
    hits = misses = 0
    for x in jax.tree.leaves(tree):
        if _kernel_eligible(x, min_size=min_size):
            hits += 1
        else:
            misses += 1
    return {"kernel_leaves": hits, "fallback_leaves": misses, "min_size": min_size}


class Compressed(NamedTuple):
    tree: PyTree  # dense-with-zeros (topk/randk) or dequantized (int8)
    wire_bytes: jnp.ndarray  # scalar int64-ish float: bytes on the wire


def _leaf_topk_mask(x: jnp.ndarray, k: int) -> jnp.ndarray:
    flat = jnp.abs(x.reshape(-1))
    k = max(1, min(int(k), flat.size))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def topk_compress(tree: PyTree, fraction: float, *, use_kernel: bool = False) -> Compressed:
    """Keep the top ``fraction`` of entries per leaf by magnitude."""

    def leaf(x):
        k = max(1, int(round(fraction * x.size)))
        if use_kernel and _kernel_eligible(x):
            from repro.kernels.topk_compress import ops as tk_ops

            # fused select kernel: exact top_k threshold + one-pass mask,
            # bit-equal to the reference line below (topk_sparsify's
            # all-on-device bisection stays available for huge leaves)
            return tk_ops.topk_encode(x, k=k)[0]
        return x * _leaf_topk_mask(x, k)

    out = jax.tree.map(leaf, tree)
    # wire: 4-byte index + value bytes per kept entry
    nbytes = sum(
        max(1, int(round(fraction * x.size))) * (4 + x.dtype.itemsize)
        for x in jax.tree.leaves(tree)
    )
    return Compressed(out, jnp.asarray(float(nbytes)))


def threshold_compress(tree: PyTree, tau) -> Compressed:
    """Magnitude-threshold sparsification: keep entries with |x| ≥ tau.

    Unlike ``topk_compress`` (whose kept COUNT is baked into compiled
    shapes), the threshold is a value-dependent, shape-static knob: the
    on-device representation stays dense-with-zeros, only ``wire_bytes``
    (a traced scalar counting survivors) depends on the data.  That makes
    the compression RATIO sweepable — ``tau`` can be a traced per-scenario
    scalar under the sweep executor, where per-scenario top-k fractions
    would need a different static k per scenario.
    """
    tau = jnp.asarray(tau)

    def leaf(x):
        return jnp.where(jnp.abs(x) >= tau.astype(x.dtype), x, 0)

    out = jax.tree.map(leaf, tree)
    # wire: 4-byte index + value bytes per surviving entry (data-dependent)
    nbytes = sum(
        jnp.sum(jnp.abs(x) >= tau.astype(x.dtype)).astype(jnp.float32)
        * (4 + x.dtype.itemsize)
        for x in jax.tree.leaves(tree)
    )
    return Compressed(out, jnp.asarray(nbytes, jnp.float32))


def randk_compress(key: jax.Array, tree: PyTree, fraction: float) -> Compressed:
    """Random-k sparsification, rescaled by 1/fraction to stay unbiased."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))

    def leaf(k, x):
        mask = (jax.random.uniform(k, x.shape) < fraction).astype(x.dtype)
        return x * mask / jnp.asarray(fraction, x.dtype)

    out = treedef.unflatten([leaf(k, x) for k, x in zip(keys, leaves)])
    nbytes = sum(
        max(1, int(round(fraction * x.size))) * (4 + x.dtype.itemsize)
        for x in leaves
    )
    return Compressed(out, jnp.asarray(float(nbytes)))


def int8_compress(tree: PyTree, *, use_kernel: bool = False) -> Compressed:
    """Per-leaf symmetric int8 quantization (quantize→dequantize roundtrip)."""

    def leaf(x):
        if use_kernel and _kernel_eligible(x):
            from repro.kernels.int8_quant import ops as q8_ops

            # fused absmax + quant-dequant passes; bit-equal to the
            # reference lines below (the int8 intermediate stays in VMEM)
            return q8_ops.int8_roundtrip(x)[0]
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return q.astype(x.dtype) * scale

    out = jax.tree.map(leaf, tree)
    nbytes = sum(x.size * 1 + 4 for x in jax.tree.leaves(tree))
    return Compressed(out, jnp.asarray(float(nbytes)))


class EFState(NamedTuple):
    """Error-feedback residual (one entry per parameter leaf)."""

    residual: PyTree


def ef_init(tree: PyTree) -> EFState:
    return EFState(jax.tree.map(jnp.zeros_like, tree))


def ef_compress(
    state: EFState,
    update: PyTree,
    compressor,
) -> tuple[EFState, Compressed]:
    """Error-feedback wrapper: compress (update + residual), carry the rest.

    ``compressor`` maps a pytree to a ``Compressed``; the residual keeps
    whatever the compressor dropped so nothing is ever permanently lost —
    this is what preserves the non-distributed convergence rate the paper's
    §5 argument leans on.
    """
    corrected = jax.tree.map(jnp.add, update, state.residual)
    comp = compressor(corrected)
    new_residual = jax.tree.map(jnp.subtract, corrected, comp.tree)
    return EFState(new_residual), comp


def raw_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
