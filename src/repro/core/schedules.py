"""Contact schedules for the §5 central-information-server algorithm.

The paper considers two regimes:

* **Round-robin** — ``S_t = t mod K``.  "If F(·) is a first order method
  based on a convex objective this is equivalent to a mini-batch gradient
  descent algorithm."
* **Asynchronous** — ``S_t ~ S`` i.i.d. over ``{1..K}`` with
  ``p(S = i) > 0`` for all i ("there exists no node that will never contact
  the server"), under which the paper argues convergence is preserved with
  the *same rate* as the non-distributed stochastic mini-batch algorithm.
  The contact distribution is allowed to be non-uniform — "the actual
  distribution S is dependent on the local datasets, e.g. number of
  examples" — so we expose per-node probabilities.

Schedules are plain int32 arrays so they can drive ``jax.lax.scan`` in
``repro.core.server.run_protocol``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def round_robin(num_nodes: int, num_rounds: int) -> jnp.ndarray:
    """``S_t = t mod K`` for ``num_rounds`` full passes over the K nodes."""
    return jnp.tile(jnp.arange(num_nodes, dtype=jnp.int32), num_rounds)


def asynchronous(
    key: jax.Array,
    num_nodes: int,
    num_contacts: int,
    probs: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """I.i.d. random contacts ``S_t ~ S``; ``probs`` defaults to uniform.

    Raises if any node has zero probability — the paper's convergence
    condition requires ``p(S=i) > 0`` for every node.
    """
    if probs is None:
        probs = jnp.full((num_nodes,), 1.0 / num_nodes)
    probs = jnp.asarray(probs, dtype=jnp.float32)
    if probs.shape != (num_nodes,):
        raise ValueError(f"probs must have shape ({num_nodes},), got {probs.shape}")
    # Static check where possible (concrete arrays only).
    try:
        if bool(jnp.any(probs <= 0.0)):
            raise ValueError(
                "p(S=i) must be > 0 for every node (paper §5 convergence condition)"
            )
    except jax.errors.TracerBoolConversionError:  # pragma: no cover
        pass
    return jax.random.categorical(
        key, jnp.log(probs), shape=(num_contacts,)
    ).astype(jnp.int32)


def work_proportional_probs(shard_sizes: jnp.ndarray) -> jnp.ndarray:
    """Contact probabilities ∝ 1 / shard size.

    The paper notes the contact distribution is driven by per-node compute
    time, which "at least" scales with the number of local examples: a node
    with less data finishes sooner and contacts the server more often.
    """
    sizes = jnp.asarray(shard_sizes, dtype=jnp.float32)
    rates = 1.0 / jnp.maximum(sizes, 1.0)
    return rates / jnp.sum(rates)


def coverage(schedule: jnp.ndarray, num_nodes: int) -> jnp.ndarray:
    """Fraction of nodes that appear at least once — sanity diagnostic for
    the paper's p(S=i)>0 condition on a *finite* sample."""
    hits = jnp.zeros((num_nodes,), dtype=jnp.int32).at[schedule].set(1)
    return jnp.mean(hits.astype(jnp.float32))
