"""The paper's §5 central-information-server algorithm.

    "We propose the following algorithm in which the server in iteration t
     when a node would push a computed parameter θ the server would record
     this as θ_t ← θ and would send to the node the parameter θ_{t-1} from
     memory.  Any machine learning algorithm F(·) chosen to run on each of
     the nodes would be effectively seen as running in isolation on the
     local dataset [...] ending with an equivalent update of the form
     θ_t ← F^(S_t)(… F^(S_2)(F^(S_1)(θ_0)) …)."

Two handoff semantics are implemented, because the paper's prose describes a
one-step-stale protocol while its equivalence claim states a strictly
sequential composition:

* ``handoff="sequential"`` — the node that pushes receives the *current*
  server value (i.e. its own push, which includes every predecessor's work);
  the global trajectory is exactly ``θ_t = F^(S_t)(θ_{t-1})``.  This is the
  semantics under which the paper's round-robin ≡ mini-batch-GD equivalence
  holds *bit-exactly* (tested in ``tests/test_core_server.py``).
* ``handoff="stale"`` — the literal protocol text: the pusher receives
  ``θ_{t-1}`` (the previous contact's value) and therefore next computes on a
  one-step-stale parameter while its own push is handed to the successor.
  This is the pipelined variant that lets node computation overlap.

Everything is purely functional: ``ServerState`` is a pytree, ``contact`` is
jit-able, and the whole multi-round protocol can sit inside ``jax.lax.scan``.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class ServerState(NamedTuple):
    """State of the central information server.

    ``theta`` is θ_t (the most recent push); ``theta_prev`` is θ_{t-1}.
    ``t`` counts contacts (pushes).
    """

    theta: PyTree
    theta_prev: PyTree
    t: jnp.ndarray  # scalar int32


def init_server(theta_init: PyTree) -> ServerState:
    """θ_0 (central server) is initialized to θ_init (paper §5)."""
    return ServerState(
        theta=theta_init,
        theta_prev=theta_init,
        t=jnp.asarray(0, dtype=jnp.int32),
    )


def contact(
    state: ServerState, theta_pushed: PyTree, *, handoff: str = "sequential"
) -> tuple[ServerState, PyTree]:
    """One node contact: push ``theta_pushed``, receive the handoff parameter.

    Returns ``(new_state, theta_received)``.
    """
    new_state = ServerState(
        theta=theta_pushed,
        theta_prev=state.theta,
        t=state.t + 1,
    )
    if handoff == "sequential":
        received = new_state.theta  # θ_t — build on your own (recorded) push
    elif handoff == "stale":
        received = new_state.theta_prev  # θ_{t-1} — the literal protocol
    else:  # pragma: no cover - config error
        raise ValueError(f"unknown handoff: {handoff!r}")
    return new_state, received


def pull(state: ServerState) -> PyTree:
    """A pure pull (first contact of a node before it has computed anything)."""
    return state.theta


def run_protocol(
    theta_init: PyTree,
    local_updates: Callable[[jnp.ndarray, PyTree], PyTree],
    schedule: jnp.ndarray,
    *,
    handoff: str = "sequential",
) -> tuple[ServerState, PyTree]:
    """Run the full §5 protocol under a contact ``schedule``.

    Args:
      theta_init: θ_0.
      local_updates: ``F(k, θ) -> θ_new`` — the per-node learning method
        ``F^(k)`` applied to its local dataset.  Must be traceable with a
        traced node index ``k`` (use ``jax.lax.switch`` or gather-style data
        selection inside).
      schedule: int32 array of node indices ``S_1 .. S_T`` (the contact
        order).  Round-robin or random — see ``repro.core.schedules``.
      handoff: see module docstring.

    Returns ``(final_server_state, per_contact_thetas)`` where the second
    element stacks the handed-back parameters (useful for trajectory
    analysis / convergence plots).
    """

    def step(state: ServerState, k):
        # The contacting node computes on the parameter it last received.
        # Under "sequential" handoff that is the server's current θ; under
        # "stale" handoff it is θ_{t-1}.
        theta_start = state.theta if handoff == "sequential" else state.theta_prev
        theta_new = local_updates(k, theta_start)
        state, received = contact(state, theta_new, handoff=handoff)
        return state, received

    state0 = init_server(theta_init)
    final_state, trajectory = jax.lax.scan(step, state0, schedule)
    return final_state, trajectory
