from repro.sharding.rules import (
    MeshContext,
    current_mesh_context,
    maybe_shard,
    partition_params,
    set_mesh_context,
)

__all__ = [
    "MeshContext",
    "current_mesh_context",
    "maybe_shard",
    "partition_params",
    "set_mesh_context",
]
