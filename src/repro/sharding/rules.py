"""Sharding rules: logical-axis activation constraints + name-based param specs.

Strategy (DESIGN.md §5):

* params — tensor parallel on the ``model`` axis (attention heads, FFN
  hidden, experts, vocab), optional FSDP on the ``data``/``pod`` axes for
  architectures whose parameter+optimizer state exceeds per-chip HBM;
* activations — batch on (``pod``, ``data``); sequence on ``data`` when the
  batch is too small to shard (``long_500k`` decode); hidden/heads on
  ``model``.

A ``MeshContext`` (set by the launcher) carries the mesh + logical→physical
axis mapping; model code calls ``maybe_shard(x, "batch", "seq", None)``
which becomes ``with_sharding_constraint`` under a mesh and a no-op without.
"""

from __future__ import annotations

import re
import threading
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


@dataclass
class MeshContext:
    mesh: Mesh
    # logical axis name -> physical mesh axis (or tuple of axes) or None
    logical: dict = field(default_factory=dict)
    fsdp: bool = False

    @property
    def batch_axes(self):
        return self.logical.get("batch")

    @property
    def model_axis(self):
        return self.logical.get("model")

    @property
    def node_axes(self) -> tuple:
        """Physical mesh axes that place the paper's K nodes (data
        parallelism) — what the mesh executor shards the node axis over."""
        return tuple(a for a in self.mesh.axis_names if a in ("pod", "data"))

    @property
    def pod_axis(self) -> str | None:
        """The inter-pod tier's mesh axis, when this mesh spans pods."""
        return "pod" if "pod" in self.mesh.axis_names else None

    @property
    def intra_pod_axes(self) -> tuple:
        """Node axes below the pod tier (the cheap intra-pod reduction)."""
        return tuple(a for a in self.node_axes if a != "pod")

    def topology(self, **prices):
        """The reduction ``core.topology.Topology`` this mesh implies:
        hierarchical (intra-pod psum + inter-pod allreduce) when a pod
        axis exists, flat otherwise.  ``prices`` forwards
        ``intra_price``/``inter_price`` per-byte hop prices."""
        from repro.core.topology import Topology

        return Topology.from_mesh(self.node_axes, **prices)


def set_mesh_context(ctx: MeshContext | None):
    _ctx.value = ctx


def current_mesh_context() -> MeshContext | None:
    return getattr(_ctx, "value", None)


def maybe_shard(x: jnp.ndarray, *logical_axes) -> jnp.ndarray:
    """Apply a sharding constraint if a mesh context is active.

    ``logical_axes`` entries are logical names ("batch", "seq", "model",
    "expert", ...) or None; unknown names map to None (replicated).
    """
    ctx = current_mesh_context()
    if ctx is None:
        return x
    spec = P(*[ctx.logical.get(a) if a is not None else None for a in logical_axes])
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ----------------------------------------------------------------------------
# Parameter partition specs (name-based rules)
# ----------------------------------------------------------------------------
#
# Each rule: (path regex, spec builder).  Builders receive (ndim, model, fsdp)
# where `model`/`fsdp` are the physical axis names (fsdp may be None) and must
# return a PartitionSpec of length == ndim of the *unstacked* leaf; leading
# scan/stack dims are padded with None automatically (we pad on the left to
# the leaf's actual ndim).

def _pad(spec_tail: tuple, ndim: int) -> P:
    pad = ndim - len(spec_tail)
    if pad < 0:  # leaf smaller than rule (e.g. reduced configs) — replicate
        return P()
    return P(*((None,) * pad + spec_tail))


def _rules(model, fsdp, expert_axes=None):
    # NOTE: order matters — first match wins.
    e = expert_axes if expert_axes is not None else model
    e_fsdp = None if expert_axes is not None else fsdp
    return [
        # embeddings / lm head: vocab over model, d over fsdp
        (r"embed/embedding$", (model, fsdp)),
        (r"lm_head/kernel$", (fsdp, model)),
        # MoE experts: expert dim over model (expert parallelism); with
        # ``expert_axes`` the expert dim spans several axes (2-D EP) and is
        # never FSDP-gathered
        (r"experts/w_gate$", (e, e_fsdp, None)),
        (r"experts/w_up$", (e, e_fsdp, None)),
        (r"experts/w_down$", (e, None, e_fsdp)),
        (r"router/kernel$", (None, None)),
        # attention (GQA)
        (r"\bwq/kernel$", (fsdp, model)),
        (r"\bwk/kernel$", (fsdp, model)),
        (r"\bwv/kernel$", (fsdp, model)),
        (r"\bwo/kernel$", (model, fsdp)),
        (r"\bw(q|k|v)/bias$", (model,)),
        # MLA
        (r"w_dq/kernel$", (fsdp, None)),
        (r"w_uq/kernel$", (None, model)),
        (r"w_dkv/kernel$", (fsdp, None)),
        (r"w_kr/kernel$", (fsdp, None)),
        (r"w_uk/kernel$", (None, model)),
        (r"w_uv/kernel$", (None, model)),
        (r"w_o/kernel$", (model, fsdp)),
        # dense FFN
        (r"w_gate/kernel$", (fsdp, model)),
        (r"w_up/kernel$", (fsdp, model)),
        (r"w_down/kernel$", (model, fsdp)),
        (r"w_in/kernel$", (fsdp, model)),
        (r"w_out/kernel$", (model, fsdp)),
        # mamba
        (r"in_proj/kernel$", (fsdp, model)),
        (r"conv_w$", (None, model)),
        (r"conv_b$", (model,)),
        (r"x_proj/kernel$", (model, None)),
        (r"dt_proj/kernel$", (None, model)),
        (r"dt_proj/bias$", (model,)),
        (r"A_log$", (model, None)),
        (r"\bD$", (model,)),
        (r"out_proj/kernel$", (model, fsdp)),
        # mLSTM
        (r"up_proj/kernel$", (fsdp, model)),
        (r"down_proj/kernel$", (model, fsdp)),
        (r"w_[ifzo]/kernel$", (fsdp, None)),
        (r"mh_norm/scale$", (model,)),
        # sLSTM ffn
        (r"ffn_up/kernel$", (fsdp, model)),
        (r"ffn_down/kernel$", (model, fsdp)),
        # everything else (norms, biases, small projections): replicated
    ]


def partition_params(params, *, model_axis="model", fsdp_axis=None,
                     expert_axes=None):
    """Build a PartitionSpec pytree matching ``params`` via name rules.
    ``model_axis=None`` disables tensor parallelism (pure DP/FSDP);
    ``expert_axes`` overrides the expert-dim sharding (2-D EP)."""
    rules = _rules(model_axis, fsdp_axis, expert_axes)
    compiled = [(re.compile(rx), tail) for rx, tail in rules]

    def assign(path, leaf):
        pstr = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        for rx, tail in compiled:
            if rx.search(pstr):
                return _pad(tail, leaf.ndim)
        return P()

    return jax.tree_util.tree_map_with_path(assign, params)


def make_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def place_params(mesh: Mesh, params, *, model_axis="model", fsdp_axis=None,
                 expert_axes=None):
    """Partition ``params`` by the name rules and put them on ``mesh`` in
    one step.  Axis names absent from the mesh degrade to replication, so
    callers (e.g. the serving engine) can pass any mesh — a pure-data mesh
    simply replicates every parameter."""
    model = model_axis if model_axis in mesh.axis_names else None
    fsdp = fsdp_axis if fsdp_axis and fsdp_axis in mesh.axis_names else None
    if expert_axes is not None:
        ea = (expert_axes,) if isinstance(expert_axes, str) else expert_axes
        if not all(a in mesh.axis_names for a in ea):
            expert_axes = None
    spec = partition_params(
        params, model_axis=model, fsdp_axis=fsdp, expert_axes=expert_axes
    )
    return jax.device_put(params, make_shardings(mesh, spec))
