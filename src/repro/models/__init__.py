"""Model substrate: unified decoder LM + encoder-decoder + caches."""

from repro.models import (
    attention,
    cache,
    config,
    layers,
    mamba,
    mla,
    moe,
    transformer,
    whisper,
    xlstm,
)
from repro.models.config import (
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SSMConfig,
    XLSTMConfig,
)

__all__ = [
    "attention",
    "cache",
    "config",
    "layers",
    "mamba",
    "mla",
    "moe",
    "transformer",
    "whisper",
    "xlstm",
    "MLAConfig",
    "MoEConfig",
    "ModelConfig",
    "SSMConfig",
    "XLSTMConfig",
]
