"""Mamba-1 selective SSM mixer (Jamba's recurrent layer, arXiv:2403.19887).

Training/prefill uses a **chunked parallel scan**: time is processed in
chunks of ``chunk`` tokens; within a chunk the recurrence
``h_t = a_t ⊙ h_{t-1} + b_t`` runs as an associative scan, and the carried
state crosses chunk boundaries in a ``jax.lax.scan``.  This bounds the
materialized (B, chunk, d_inner, d_state) tensor — with d_inner sharded
over the ``model`` axis it stays ~100 MB/device at Jamba scale instead of
the O(B·T·d_inner·d_state) of a naive associative scan over the full
sequence.  Decode carries ``MambaCache`` (conv tail + SSM state) — O(1) in
sequence length, which is why Jamba runs ``long_500k``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.cache import MambaCache
from repro.models.config import ModelConfig
from repro.models.layers import dense, dense_init, truncated_normal


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or max(1, -(-cfg.d_model // 16))  # ceil(d/16)
    return d_inner, dt_rank, s.d_state, s.d_conv


def mamba_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d_inner, dt_rank, d_state, d_conv = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None], (d_inner, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_inner, dtype=dtype),
        "conv_w": truncated_normal(ks[1], (d_conv, d_inner), dtype, (1.0 / d_conv) ** 0.5),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * d_state, dtype=dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, bias=True, dtype=dtype),
        "A_log": jnp.log(A),  # fp32 — recurrence numerics
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[4], d_inner, d, dtype=dtype),
    }


def _ssm_chunk(carry_h, xa_chunk):
    """One chunk of the selective scan.  carry_h: (B, di, ds) fp32."""
    a, b = xa_chunk  # each (B, L, di, ds) fp32

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = a_cum * carry_h[:, None] + b_cum  # (B, L, di, ds)
    return h[:, -1], h


def mamba_apply(
    p,
    cfg: ModelConfig,
    x: jnp.ndarray,
    *,
    cache: MambaCache | None = None,
    chunk: int = 256,
    **_,
):
    """x: (B, T, d) → (y, new_cache)."""
    d_inner, dt_rank, d_state, d_conv = _dims(cfg)
    B, T, _ = x.shape
    cd = x.dtype
    if cfg.unroll_time_scans:
        chunk = T  # cost probe: single chunk → no while loop in HLO

    xz = dense(p["in_proj"], x)  # (B, T, 2*di)
    xs, z = jnp.split(xz, 2, axis=-1)

    # --- depthwise causal conv over time
    if cache is None:
        pad = jnp.zeros((B, d_conv - 1, d_inner), cd)
        conv_tail_next = None
    else:
        pad = cache.conv.astype(cd)
        conv_tail_next = jnp.concatenate([pad, xs], axis=1)[:, -(d_conv - 1):]
    xpad = jnp.concatenate([pad, xs], axis=1)  # (B, T+dc-1, di)
    idx = jnp.arange(T)[:, None] + jnp.arange(d_conv)[None, :]  # (T, dc)
    windows = xpad[:, idx]  # (B, T, dc, di)
    xc = jnp.einsum("btcd,cd->btd", windows, p["conv_w"].astype(cd)) + p[
        "conv_b"
    ].astype(cd)
    xc = jax.nn.silu(xc)

    # --- input-dependent SSM parameters
    proj = dense(p["x_proj"], xc)  # (B, T, dtr + 2*ds)
    dt, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt)).astype(jnp.float32)  # (B,T,di)
    A = -jnp.exp(p["A_log"])  # (di, ds) fp32
    Bf = Bmat.astype(jnp.float32)
    Cf = Cmat.astype(jnp.float32)
    xf = xc.astype(jnp.float32)

    a = jnp.exp(dt[..., None] * A[None, None])  # (B, T, di, ds)
    b = (dt * xf)[..., None] * Bf[:, :, None, :]  # (B, T, di, ds)

    h0 = (
        jnp.zeros((B, d_inner, d_state), jnp.float32)
        if cache is None
        else cache.ssm
    )

    if T == 1:
        # decode fast path — single recurrent step
        h = a[:, 0] * h0 + b[:, 0]  # (B, di, ds)
        y = jnp.einsum("bds,bs->bd", h, Cf[:, 0])[:, None]  # (B, 1, di)
        h_last = h
    else:
        # chunked parallel scan
        Lc = min(chunk, T)
        npad = (-T) % Lc
        if npad:
            a = jnp.concatenate(
                [a, jnp.ones((B, npad, d_inner, d_state), jnp.float32)], axis=1
            )
            b = jnp.concatenate(
                [b, jnp.zeros((B, npad, d_inner, d_state), jnp.float32)], axis=1
            )
        nchunks = (T + npad) // Lc
        if nchunks == 1:
            h_last, hs = _ssm_chunk(h0, (a, b))
            hs = hs[:, :T]
        else:
            a = a.reshape(B, nchunks, Lc, d_inner, d_state).swapaxes(0, 1)
            b = b.reshape(B, nchunks, Lc, d_inner, d_state).swapaxes(0, 1)
            h_last, hs = jax.lax.scan(_ssm_chunk, h0, (a, b))
            hs = hs.swapaxes(0, 1).reshape(B, nchunks * Lc, d_inner, d_state)[:, :T]
        y = jnp.einsum("btds,bts->btd", hs, Cf)
        # the true final state must come from position T-1, not padding
        h_last = hs[:, -1]

    y = y + p["D"][None, None] * xf
    y = y.astype(cd) * jax.nn.silu(z)
    out = dense(p["out_proj"], y)

    new_cache = None
    if cache is not None:
        new_cache = MambaCache(conv=conv_tail_next.astype(cache.conv.dtype), ssm=h_last)
    return out, new_cache
