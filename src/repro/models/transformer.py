"""Unified decoder-only LM covering dense / MoE / MLA / SSM / hybrid / VLM.

Layer stacks are compiled as ``jax.lax.scan`` over **segments**: the
per-layer spec list (mixer type × FFN type) is factored into either

* a repeating *period* (Jamba: 8-layer pattern × 9 super-blocks;
  xLSTM: 4-block pattern × 3), scanned over the repeats with the pattern
  unrolled inside the body, or
* maximal homogeneous *runs* (DeepSeek-V3: 3 dense layers + 58 MoE layers →
  two scans),

which keeps the HLO compact enough to compile 61-layer/671B-parameter
graphs for 512 host devices in minutes (see launch/dryrun.py).

Parameters are nested dicts; per-segment leaves carry a leading stack dim.
Decode carries per-segment stacked caches through the same scans.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention, cache as cache_lib, mamba, mla, moe, xlstm
from repro.models.config import ModelConfig
from repro.models.layers import (
    cross_entropy,
    dense,
    dense_init,
    embed,
    embedding_init,
    rmsnorm,
    rmsnorm_init,
    swiglu,
    swiglu_init,
    unembed,
)
from repro.sharding.rules import maybe_shard


# ----------------------------------------------------------------------------
# Layer specs and segmentation
# ----------------------------------------------------------------------------

#: mixers whose caches accept T ≥ 1 appended tokens in ONE decode_step
#: call (keys causal-masked against idx + arange(T)); the recurrent
#: mixers (mamba / mlstm / slstm) carry single-step state and must be
#: fed token by token.  Serving uses this to pick batched vs loop prefill.
MULTI_TOKEN_MIXERS = ("attn", "mla")


@dataclass(frozen=True)
class LayerSpec:
    mixer: str  # attn | mla | mamba | mlstm | slstm
    ffn: str  # dense | moe | none


@dataclass(frozen=True)
class Segment:
    unit: tuple  # tuple[LayerSpec] — unrolled inside the scan body
    repeats: int  # scan length


def layer_specs(cfg: ModelConfig) -> list[LayerSpec]:
    specs = []
    for i in range(cfg.num_layers):
        # mixer
        if cfg.hybrid_pattern:
            mixer = cfg.hybrid_pattern[i % len(cfg.hybrid_pattern)]
        elif cfg.xlstm is not None:
            mixer = "slstm" if i in cfg.xlstm.slstm_at else "mlstm"
        else:
            mixer = cfg.mixer
        # ffn
        if cfg.xlstm is not None:
            ffn = "none"  # xLSTM blocks embed their own FFN
        elif cfg.moe is None:
            ffn = "dense"
        else:
            mode = cfg.moe.layer_mode
            if mode == "all":
                ffn = "moe"
            elif mode == "every_other":
                ffn = "moe" if i % 2 == 1 else "dense"
            elif mode == "after_first_k":
                ffn = "dense" if i < cfg.moe.first_k_dense else "moe"
            else:
                raise ValueError(mode)
        specs.append(LayerSpec(mixer=mixer, ffn=ffn))
    return specs


def segments(cfg: ModelConfig) -> list[Segment]:
    segs = _segments_base(cfg)
    if cfg.segment_repeats:
        if len(cfg.segment_repeats) != len(segs):
            raise ValueError(
                f"segment_repeats {cfg.segment_repeats} vs {len(segs)} segments"
            )
        segs = [
            Segment(unit=s.unit, repeats=r)
            for s, r in zip(segs, cfg.segment_repeats)
        ]
    return segs


def _segments_base(cfg: ModelConfig) -> list[Segment]:
    specs = layer_specs(cfg)
    L = len(specs)
    # smallest period p | L with specs[i] == specs[i % p]
    for p in range(1, L):
        if L % p == 0 and all(specs[i] == specs[i % p] for i in range(L)):
            return [Segment(unit=tuple(specs[:p]), repeats=L // p)]
    # fall back to maximal homogeneous runs
    segs = []
    i = 0
    while i < L:
        j = i
        while j < L and specs[j] == specs[i]:
            j += 1
        segs.append(Segment(unit=(specs[i],), repeats=j - i))
        i = j
    return segs


# ----------------------------------------------------------------------------
# Single layer
# ----------------------------------------------------------------------------

_MIXER_INIT = {
    "attn": attention.attn_init,
    "mla": mla.mla_init,
    "mamba": mamba.mamba_init,
    "mlstm": xlstm.mlstm_init,
    "slstm": xlstm.slstm_init,
}
_MIXER_APPLY = {
    "attn": attention.attn_apply,
    "mla": mla.mla_apply,
    "mamba": mamba.mamba_apply,
    "mlstm": xlstm.mlstm_apply,
    "slstm": xlstm.slstm_apply,
}


def init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "mixer_norm": rmsnorm_init(cfg.d_model, dtype),
        "mixer": _MIXER_INIT[spec.mixer](k1, cfg, dtype=dtype),
    }
    if spec.ffn == "dense":
        p["ffn_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["ffn"] = swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype)
    elif spec.ffn == "moe":
        p["ffn_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["ffn"] = moe.moe_init(k2, cfg, dtype=dtype)
    return p


def apply_layer(
    p,
    cfg: ModelConfig,
    spec: LayerSpec,
    h,
    *,
    cache=None,
    positions=None,
    mrope_positions=None,
    mla_absorb=False,
    pages=None,
    decode_attn="off",
):
    aux = jnp.zeros((), jnp.float32)
    hn = rmsnorm(p["mixer_norm"], h, eps=cfg.rms_eps)
    kw = {}
    if spec.mixer in ("attn", "mla"):
        kw["positions"] = positions
    if spec.mixer == "attn":
        kw["mrope_positions"] = mrope_positions
        kw["pages"] = pages
        kw["decode_attn"] = decode_attn
    if spec.mixer == "mla":
        kw["absorb"] = mla_absorb
    mix, new_cache = _MIXER_APPLY[spec.mixer](p["mixer"], cfg, hn, cache=cache, **kw)
    h = h + mix
    h = maybe_shard(h, "batch", "seq", None)
    if spec.ffn == "dense":
        h = h + swiglu(p["ffn"], rmsnorm(p["ffn_norm"], h, eps=cfg.rms_eps))
    elif spec.ffn == "moe":
        y, aux_moe = moe.moe_apply(p["ffn"], cfg, rmsnorm(p["ffn_norm"], h, eps=cfg.rms_eps))
        h = h + y
        aux = aux + aux_moe
    h = maybe_shard(h, "batch", "seq", None)
    return h, new_cache, aux


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, seq: int, dtype):
    if spec.mixer == "attn":
        return cache_lib.kv_cache_init(batch, seq, cfg.num_kv_heads, cfg.head_dim, dtype)
    if spec.mixer == "mla":
        return cache_lib.mla_cache_init(
            batch, seq, cfg.mla.kv_lora_rank, cfg.mla.qk_rope_head_dim, dtype
        )
    if spec.mixer == "mamba":
        d_inner, _, d_state, d_conv = mamba._dims(cfg)
        return cache_lib.mamba_cache_init(batch, d_conv, d_inner, d_state, dtype)
    if spec.mixer == "mlstm":
        di = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
        di = (di // cfg.num_heads) * cfg.num_heads
        dh = di // cfg.num_heads
        return cache_lib.mlstm_cache_init(batch, cfg.num_heads, dh, dh)
    if spec.mixer == "slstm":
        return cache_lib.slstm_cache_init(batch, cfg.d_model)
    raise ValueError(spec.mixer)


# ----------------------------------------------------------------------------
# Full model
# ----------------------------------------------------------------------------

def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4 + cfg.num_layers)
    params = {"embed": embedding_init(keys[0], cfg.padded_vocab, cfg.d_model, dtype)}

    segs = segments(cfg)
    ki = 1
    for si, seg in enumerate(segs):
        reps = []
        for r in range(seg.repeats):
            unit_p = {}
            for li, spec in enumerate(seg.unit):
                unit_p[f"l{li}"] = init_layer(
                    jax.random.fold_in(keys[1 + si], r * 131 + li), cfg, spec, dtype
                )
            reps.append(unit_p)
        params[f"seg{si}"] = _stack(reps)
        ki += 1

    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[2], cfg.d_model, cfg.padded_vocab, dtype=dtype)

    if cfg.num_mtp_layers > 0:
        spec = LayerSpec(mixer=cfg.mixer, ffn="dense" if cfg.moe is None else "moe")
        params["mtp"] = {
            "proj": dense_init(keys[3], 2 * cfg.d_model, cfg.d_model, dtype=dtype),
            "norm_h": rmsnorm_init(cfg.d_model, dtype),
            "norm_e": rmsnorm_init(cfg.d_model, dtype),
            "layer": init_layer(jax.random.fold_in(keys[3], 1), cfg, spec, dtype),
            "final_norm": rmsnorm_init(cfg.d_model, dtype),
        }
    return params


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype, *, index: int = 0):
    """Stacked per-segment caches (index pre-set for decode-at-position)."""
    caches = {}
    for si, seg in enumerate(segs_of(cfg)):
        reps = []
        for _ in range(seg.repeats):
            unit_c = {
                f"l{li}": init_layer_cache(cfg, spec, batch, seq, dtype)
                for li, spec in enumerate(seg.unit)
            }
            reps.append(unit_c)
        stacked = _stack(reps)
        if index:
            # the only int32 leaves in caches are the fill indices
            stacked = jax.tree.map(
                lambda l: jnp.full_like(l, index) if l.dtype == jnp.int32 else l,
                stacked,
            )
        caches[f"seg{si}"] = stacked
    return caches


def segs_of(cfg: ModelConfig) -> list[Segment]:
    return segments(cfg)


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    if cfg.remat_policy == "full":
        return jax.checkpoint(fn)
    raise ValueError(cfg.remat_policy)


def forward(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    positions: jnp.ndarray | None = None,
    mrope_positions: jnp.ndarray | None = None,
    vision_embeds: jnp.ndarray | None = None,
    cache=None,
    mla_absorb: bool = False,
    return_hidden: bool = False,
    skip_logits: bool = False,
    pages: tuple | None = None,
    decode_attn: str = "off",
):
    """Returns (logits, aux_loss, new_cache[, hidden])."""
    cd = jnp.dtype(cfg.compute_dtype)
    B, T = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    h = embed(params["embed"], tokens, compute_dtype=cd)
    if vision_embeds is not None:
        # VLM stub frontend: the first Tv positions are precomputed patch
        # embeddings (projector output) — replace the placeholder tokens.
        Tv = vision_embeds.shape[1]
        h = jnp.concatenate([vision_embeds.astype(cd), h[:, Tv:]], axis=1)
    h = maybe_shard(h, "batch", "seq", None)

    aux = jnp.zeros((), jnp.float32)
    new_caches = {} if cache is not None else None

    for si, seg in enumerate(segments(cfg)):
        seg_params = params[f"seg{si}"]

        def body(carry, xs, seg=seg):
            h, aux = carry
            if cache is not None:
                p_step, c_step = xs
            else:
                p_step, c_step = xs, None
            new_c = {}
            for li, spec in enumerate(seg.unit):
                c_in = c_step[f"l{li}"] if c_step is not None else None
                h, c_out, a = apply_layer(
                    p_step[f"l{li}"],
                    cfg,
                    spec,
                    h,
                    cache=c_in,
                    positions=positions,
                    mrope_positions=mrope_positions,
                    mla_absorb=mla_absorb,
                    pages=pages,
                    decode_attn=decode_attn,
                )
                aux = aux + a
                if c_out is not None:
                    new_c[f"l{li}"] = c_out
            return (h, aux), (new_c if cache is not None else None)

        body = _remat_wrap(body, cfg) if cache is None else body

        if not cfg.scan_layers:
            # probe path: unroll so XLA cost_analysis counts every repeat
            new_slices = []
            for r in range(seg.repeats):
                p_r = jax.tree.map(lambda x: x[r], seg_params)
                if cache is not None:
                    c_r = jax.tree.map(lambda x: x[r], cache[f"seg{si}"])
                    (h, aux), c_out = body((h, aux), (p_r, c_r))
                    new_slices.append(c_out)
                else:
                    (h, aux), _ = body((h, aux), p_r)
            if cache is not None:
                new_caches[f"seg{si}"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *new_slices
                )
        elif cache is not None:
            (h, aux), seg_new_cache = jax.lax.scan(
                body, (h, aux), (seg_params, cache[f"seg{si}"])
            )
            new_caches[f"seg{si}"] = seg_new_cache
        else:
            (h, aux), _ = jax.lax.scan(body, (h, aux), seg_params)

    h = rmsnorm(params["final_norm"], h, eps=cfg.rms_eps)
    if skip_logits:
        logits = None
    else:
        logits = _head_logits(params, cfg, h)
        logits = maybe_shard(logits, "batch", "seq", "model")

    out = (logits, aux, new_caches)
    if return_hidden:
        out = out + (h,)
    return out


# ----------------------------------------------------------------------------
# Multi-token prediction (DeepSeek-V3 MTP, depth 1)
# ----------------------------------------------------------------------------

def mtp_hidden(params, cfg: ModelConfig, hidden, tokens, positions):
    """Depth-1 MTP trunk: h'_t = Layer(W [norm(h_t); norm(E(tok_{t+1}))]);
    the caller applies the shared head (chunked) to predict token t+2."""
    p = params["mtp"]
    cd = jnp.dtype(cfg.compute_dtype)
    e_next = embed(params["embed"], tokens, compute_dtype=cd)  # caller pre-shifts
    x = jnp.concatenate(
        [
            rmsnorm(p["norm_h"], hidden, eps=cfg.rms_eps),
            rmsnorm(p["norm_e"], e_next, eps=cfg.rms_eps),
        ],
        axis=-1,
    )
    x = dense(p["proj"], x)
    spec = LayerSpec(mixer=cfg.mixer, ffn="dense" if cfg.moe is None else "moe")
    x, _, aux = apply_layer(p["layer"], cfg, spec, x, positions=positions)
    x = rmsnorm(p["final_norm"], x, eps=cfg.rms_eps)
    return x, aux


# ----------------------------------------------------------------------------
# Losses / steps
# ----------------------------------------------------------------------------

def _head_logits(params, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], h)
    else:
        logits = dense(params["lm_head"], h).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:  # mask pad columns (never predicted)
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


def chunked_ce(params, cfg: ModelConfig, hidden, labels, *, mask=None, chunk=512):
    """Cross entropy computed from (pre-norm-applied) hidden states in
    sequence chunks, so only (B, chunk, V) logits are ever live — the full
    (B, T, V) fp32 logits tensor (the dominant fixed memory cost at large
    vocab) is never materialized.  jax.checkpoint recomputes per-chunk
    logits in the backward pass."""
    B, T, _ = hidden.shape
    if mask is None:
        mask = jnp.ones((B, T), jnp.float32)
    c = min(chunk, T)
    if T % c:
        c = T  # fall back to single chunk for odd lengths (smoke tests)
    nch = T // c

    @jax.checkpoint
    def piece(h_c, l_c, m_c):
        logits = _head_logits(params, cfg, h_c).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * m_c), jnp.sum(m_c)

    def body(acc, xs):
        h_c, l_c, m_c = xs
        s, n = piece(h_c, l_c, m_c)
        return (acc[0] + s, acc[1] + n), None

    hs = hidden.reshape(B, nch, c, -1).swapaxes(0, 1)
    ls = labels.reshape(B, nch, c).swapaxes(0, 1)
    ms = mask.reshape(B, nch, c).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg: ModelConfig, batch):
    """batch: tokens (B,T), labels (B,T); optional mrope_positions,
    vision_embeds, loss_mask."""
    _, aux, _, hidden = forward(
        params,
        cfg,
        batch["tokens"],
        mrope_positions=batch.get("mrope_positions"),
        vision_embeds=batch.get("vision_embeds"),
        return_hidden=True,
        skip_logits=True,
    )
    loss = chunked_ce(
        params, cfg, hidden, batch["labels"], mask=batch.get("loss_mask")
    )
    total = loss + aux
    metrics = {"ce": loss, "aux": aux}

    if cfg.num_mtp_layers > 0:
        B, T = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
        # tokens shifted by one feed the MTP stream; labels shifted by two
        tok_next = jnp.roll(batch["tokens"], -1, axis=1)
        lbl_next = jnp.roll(batch["labels"], -1, axis=1)
        h_mtp, aux_mtp = mtp_hidden(params, cfg, hidden, tok_next, positions)
        mask = jnp.ones((B, T), jnp.float32).at[:, -2:].set(0.0)
        mtp_loss = chunked_ce(params, cfg, h_mtp, lbl_next, mask=mask)
        total = total + cfg.mtp_loss_coef * mtp_loss + aux_mtp
        metrics["mtp"] = mtp_loss

    return total, metrics


def decode_step(params, cfg: ModelConfig, tokens, cache, *, positions=None,
                mla_absorb: bool = False, decode_attn: str = "off"):
    """One serve step: tokens (B, 1) + cache → (logits (B,1,V), new_cache)."""
    if positions is None:
        # position = current cache fill index (same for all layers); pure
        # SSM/xLSTM caches carry no index (state is position-free)
        idx_leaves = [l for l in jax.tree.leaves(cache) if l.dtype == jnp.int32]
        if idx_leaves:
            positions = jnp.broadcast_to(idx_leaves[0].reshape(-1)[0], tokens.shape)
        else:
            positions = jnp.zeros(tokens.shape, jnp.int32)
    logits, aux, new_cache = forward(
        params, cfg, tokens, positions=positions, cache=cache,
        mla_absorb=mla_absorb, decode_attn=decode_attn,
    )
    return logits, new_cache


# ----------------------------------------------------------------------------
# Paged decode plane (continuous-batching serving)
# ----------------------------------------------------------------------------

def _is_paged(x) -> bool:
    return isinstance(x, cache_lib.PagedKVCache)


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int, dtype):
    """Stacked per-segment ``PagedKVCache`` arenas (same tree shape as
    ``init_cache`` so ``forward`` scans them identically).  Only pure-attn
    stacks have a paged decode path — recurrent/MLA mixers keep their own
    cache families."""
    for spec in layer_specs(cfg):
        if spec.mixer != "attn":
            raise ValueError(
                f"paged decode supports attn-only stacks, got mixer "
                f"{spec.mixer!r}"
            )
    caches = {}
    for si, seg in enumerate(segs_of(cfg)):
        reps = []
        for _ in range(seg.repeats):
            unit_c = {
                f"l{li}": cache_lib.paged_kv_cache_init(
                    n_pages, page_size, cfg.num_kv_heads, cfg.head_dim, dtype
                )
                for li in range(len(seg.unit))
            }
            reps.append(unit_c)
        caches[f"seg{si}"] = _stack(reps)
    return caches


def paged_decode_step(params, cfg: ModelConfig, tokens, cache, block, length,
                      *, decode_attn: str = "xla"):
    """One continuous-batching step: advance every slot one token.

    tokens: (n_slots, 1); block: (n_slots, pages_per_slot) physical page
    ids; length: (n_slots,) tokens already cached per slot.  Returns
    (logits (n_slots, 1, V), new_cache).  Inactive slots (block row all
    NULL_PAGE, length 0) compute garbage harmlessly — rows are
    independent and their writes land in the null page.
    """
    positions = jnp.broadcast_to(length[:, None], tokens.shape)
    logits, _, new_cache = forward(
        params, cfg, tokens, positions=positions, cache=cache,
        pages=(block, length), decode_attn=decode_attn,
    )
    return logits, new_cache


def paged_insert_prompt(paged, dense, block_row, n_valid):
    """Scatter a B=1 prefilled dense cache into one slot's pages (join).

    ``paged``: tree from ``init_paged_cache``; ``dense``: tree from
    ``init_cache(batch=1)`` after prefill, same segment structure.
    ``block_row``: (pages_per_slot,) page ids for the joining slot;
    ``n_valid``: prompt length (rows ≥ n_valid go to the null page, so
    bucket padding in the prefilled cache never becomes visible).
    """

    def insert_one(pg, dn):
        def per_rep(pk, pv, dk, dv):
            return cache_lib.paged_write(
                cache_lib.PagedKVCache(k=pk, v=pv), block_row,
                dk[0], dv[0], n_valid,
            )
        return jax.vmap(per_rep)(pg.k, pg.v, dn.k, dn.v)

    return jax.tree.map(insert_one, paged, dense, is_leaf=_is_paged)
