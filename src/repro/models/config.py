"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` drives the unified decoder in ``transformer.py`` plus the
encoder-decoder (whisper) and VLM (qwen2-vl) assemblies.  Every assigned
architecture is expressed as an instance of this dataclass in
``repro/configs/<arch>.py``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal

Mixer = Literal["attn", "mla", "mamba", "mlstm", "slstm"]


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3, MiniCPM3)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 2048
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    aux_loss_coef: float = 0.01
    capacity_factor: float = 1.25
    # which layers use MoE FFN: "all", "every_other" (odd layers), or
    # "after_first_k" (dense for the first `first_k_dense` layers)
    layer_mode: str = "all"
    first_k_dense: int = 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM (Jamba's mixer)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 → ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack (sLSTM + mLSTM)."""

    slstm_at: tuple = ()  # layer indices using sLSTM; the rest are mLSTM
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 1.3333333333333333


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio

    # transformer trunk
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 1024
    head_dim: int = 0  # 0 → d_model // num_heads

    # attention details
    mixer: Mixer = "attn"  # default mixer for attention-family layers
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    sliding_window: int = 0  # 0 → full causal attention
    mrope_sections: tuple = ()  # e.g. (16, 24, 24) → M-RoPE (qwen2-vl)
    tie_embeddings: bool = False
    rms_eps: float = 1e-6

    # optional sub-configs
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    xlstm: XLSTMConfig | None = None

    # hybrid (jamba): per-super-block layer pattern; the model is
    # scan(num_layers // len(pattern)) copies of the pattern
    hybrid_pattern: tuple = ()  # e.g. ("mamba",)*3 + ("attn",) + ("mamba",)*4

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500  # whisper: 30 s of audio at 50 Hz

    # multi-token prediction (deepseek-v3)
    num_mtp_layers: int = 0
    mtp_loss_coef: float = 0.3

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat_policy: str = "none"  # none | dots | full

    # memory: query-chunked attention for the XLA (non-Pallas) path — the
    # softmax matrix is materialized (B, H, q_chunk, S) instead of
    # (B, H, T, S).  0 = off.  On TPU the Pallas flash kernel replaces this.
    attn_q_chunk: int = 0

    # cost-probe controls (telemetry.costprobe): scan-over-layers bodies are
    # counted ONCE by XLA cost_analysis, so probes lower small unrolled
    # variants and extrapolate.  Not used in production lowering.
    scan_layers: bool = True  # False → unroll segments (probe only)
    segment_repeats: tuple = ()  # override per-segment repeats (probe only)
    unroll_time_scans: bool = False  # single-chunk mamba/mLSTM (probe only)

    # citation for the config values (model card / paper)
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError("num_heads must be divisible by num_kv_heads")
        if self.hybrid_pattern and self.num_layers % len(self.hybrid_pattern) != 0:
            raise ValueError("num_layers must be a multiple of the hybrid pattern")

    # ---- derived ----
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows, padded to a multiple of 256 so the vocab
        dim shards over the model axis (padded logit columns are masked to
        -inf; standard production practice)."""
        return -(-self.vocab_size // 256) * 256

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: ≤2 pattern-lengths of layers, d_model ≤ 512,
        ≤4 experts — same family and code paths, CPU-runnable."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        # keep GQA ratio where it exists
        if self.num_kv_heads < self.num_heads:
            num_kv = max(1, num_heads // max(1, self.q_per_kv))
        layers = len(self.hybrid_pattern) if self.hybrid_pattern else 2
        kw = dict(
            num_layers=max(2, layers),
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=64,
            num_encoder_layers=2 if self.is_encoder_decoder else 0,
            encoder_seq_len=16 if self.is_encoder_decoder else self.encoder_seq_len,
            compute_dtype="float32",
            param_dtype="float32",
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 256),
                d_ff_shared=min(self.moe.d_ff_shared, 256),
                first_k_dense=min(self.moe.first_k_dense, 1),
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                q_lora_rank=64,
                kv_lora_rank=32,
                qk_nope_head_dim=32,
                qk_rope_head_dim=16,
                v_head_dim=32,
            )
        if self.xlstm is not None:
            kw["xlstm"] = dataclasses.replace(
                self.xlstm, slstm_at=tuple(i for i in self.xlstm.slstm_at if i < 2) or (0,)
            )
        if self.num_mtp_layers:
            kw["num_mtp_layers"] = 1
        if self.mrope_sections:
            kw["mrope_sections"] = (8, 12, 12)  # sums to reduced head_dim/2
        return self.replace(**kw)
