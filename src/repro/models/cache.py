"""Decode caches for every mixer family (pytree NamedTuples).

``serve_step`` lowers ONE new token against a cache of ``seq_len`` — these
structures are what gets sharded by the decode sharding rules (KV sequence
dim over the data axis for `long_500k`, heads over the model axis).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, S, H_kv, D)
    v: jnp.ndarray  # (B, S, H_kv, D)
    index: jnp.ndarray  # scalar int32 — number of valid positions


class MLACache(NamedTuple):
    """DeepSeek MLA latent cache: compressed KV + shared rope key."""

    c_kv: jnp.ndarray  # (B, S, kv_lora_rank)
    k_rope: jnp.ndarray  # (B, S, qk_rope_head_dim)
    index: jnp.ndarray


class MambaCache(NamedTuple):
    conv: jnp.ndarray  # (B, d_conv - 1, d_inner) — conv tail window
    ssm: jnp.ndarray  # (B, d_inner, d_state)


class MLSTMCache(NamedTuple):
    C: jnp.ndarray  # (B, H, Dk, Dv) matrix memory
    n: jnp.ndarray  # (B, H, Dk) normalizer
    m: jnp.ndarray  # (B, H) gate stabilizer


class SLSTMCache(NamedTuple):
    c: jnp.ndarray  # (B, d)
    n: jnp.ndarray  # (B, d)
    h: jnp.ndarray  # (B, d)
    m: jnp.ndarray  # (B, d)


def kv_cache_init(batch: int, seq: int, n_kv: int, head_dim: int, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, seq, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, seq, n_kv, head_dim), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def mla_cache_init(batch: int, seq: int, kv_lora: int, rope_dim: int, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, seq, kv_lora), dtype),
        k_rope=jnp.zeros((batch, seq, rope_dim), dtype),
        index=jnp.zeros((), jnp.int32),
    )


def mamba_cache_init(batch: int, d_conv: int, d_inner: int, d_state: int, dtype) -> MambaCache:
    return MambaCache(
        conv=jnp.zeros((batch, d_conv - 1, d_inner), dtype),
        ssm=jnp.zeros((batch, d_inner, d_state), jnp.float32),
    )


def mlstm_cache_init(batch: int, heads: int, dk: int, dv: int) -> MLSTMCache:
    return MLSTMCache(
        C=jnp.zeros((batch, heads, dk, dv), jnp.float32),
        n=jnp.zeros((batch, heads, dk), jnp.float32),
        m=jnp.full((batch, heads), -1e30, jnp.float32),
    )


def slstm_cache_init(batch: int, d: int) -> SLSTMCache:
    return SLSTMCache(
        c=jnp.zeros((batch, d), jnp.float32),
        n=jnp.zeros((batch, d), jnp.float32),
        h=jnp.zeros((batch, d), jnp.float32),
        m=jnp.full((batch, d), -1e30, jnp.float32),
    )
